"""Eclipse-orbit serving driver — the power envelope through one orbit.

A LEO spacecraft's power budget is periodic: full solar input in
sunlight, a reduced bus allocation in penumbra, battery-only in eclipse.
This example serves two instruments (the MMS plasma classifier and the
ESPERTA warning model) through ONE energy-budget-aware scheduler while
the envelope steps through a sunlight -> penumbra -> eclipse -> penumbra
-> sunlight cycle, pre-scheduled on the envelope exactly as a real ops
plan would be (the orbit is known in advance).

What to watch in the output:

* in sunlight the dispatcher favors the lowest-energy backend (the DPU
  analog for the classifier — high power, short draws, duty-cycled);
* entering eclipse the peak cap excludes the 6.75 W DPU outright and
  dispatch degrades gracefully to the cpu/flex fallbacks;
* nothing is ever dropped, and the envelope ledger audits to ZERO
  violations — admission-time checking makes that true by construction.

The virtual clock is the scheduler's ``modeled`` clock (plan-time cost
signatures), so the timeline is deterministic and the phase durations
are scaled to the models' modeled service times rather than wall-clock
orbit minutes.

Run:  PYTHONPATH=src python examples/eclipse_orbit.py [--requests 600]
"""
import argparse
from typing import List, Tuple

import jax

from repro.core.energy import PowerEnvelope
from repro.core.engine import Engine
from repro.core.radiation import ORBIT_PHASES, RadiationEnvironment
from repro.core.scheduler import (ContinuousBatchingScheduler,
                                  poisson_arrivals)
from repro.models import SPACE_MODELS, synthetic_requests

USE_CASES = ("logistic_net", "multi_esperta")
BACKENDS = ("accel", "flex", "cpu")     # primary first; envelope fallbacks

# Per-phase power budget (sustained W, peak W). The phase NAMES and
# DURATIONS come from `core/radiation.py`'s canonical ORBIT_PHASES —
# one source of truth, so the radiation model's upset-rate modulation
# and this power envelope stay synced to the same orbit by construction.
_POWER: dict = {
    "sunlight": (6.0, float("inf")),
    "penumbra": (3.0, 7.0),
    "eclipse": (2.0, 3.0),              # peak 3 W: the 6.75 W DPU is out
}

# (phase, duration s, sustained W, peak W) — one orbit, virtual seconds.
PHASES: List[Tuple[str, float, float, float]] = [
    (phase, dur, *_POWER[phase]) for phase, dur in ORBIT_PHASES
]
WINDOW_S = 0.01


def build_envelope() -> Tuple[PowerEnvelope, List[Tuple[str, float]]]:
    env = PowerEnvelope(PHASES[0][2], peak_w=PHASES[0][3],
                        window_s=WINDOW_S)
    bounds, t = [], 0.0
    for phase, dur, sus, peak in PHASES:
        if t > 0:
            env.set_budget(t, sustained_w=sus, peak_w=peak)
        bounds.append((phase, t))
        t += dur
    return env, bounds + [("end", t)]


def phase_of(t: float, bounds) -> str:
    name = bounds[0][0]
    for phase, start in bounds[:-1]:
        if t >= start:
            name = phase
    return name


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=600,
                    help="requests per instrument across the orbit")
    args = ap.parse_args()

    env, bounds = build_envelope()
    orbit_s = bounds[-1][1]
    print("== one orbit under a stepped power envelope ==")
    for (phase, start), (_, end) in zip(bounds[:-1], bounds[1:]):
        _, _, sus, peak = PHASES[[b[1] for b in bounds].index(start)]
        cap = "-" if peak == float("inf") else f"{peak:.0f} W"
        print(f"  {start:5.2f}-{end:5.2f} s  {phase:9s} "
              f"sustained={sus:.0f} W  peak={cap}")
    renv = RadiationEnvironment()       # same ORBIT_PHASES by construction
    saa = renv.saa_window
    print(f"  radiation: GCR base {renv.base_rate:g} upsets/s "
          f"(eclipse x{dict(renv.phase_factors)['eclipse']:g}), SAA pass "
          f"{saa[0]:.2f}-{saa[1]:.2f} s x{renv.saa_factor:g} -> peak "
          f"{renv.rate_bound():g}/s")

    sched = ContinuousBatchingScheduler(envelope=env, clock="modeled")
    trace = []
    rate = args.requests / orbit_s
    for mi, name in enumerate(USE_CASES):
        m = SPACE_MODELS[name]
        engine = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        reqs = synthetic_requests(m, args.requests, seed=1 + mi)
        engine.calibrate(reqs[:4])
        sched.register(name, engine, backend=BACKENDS, warmup_sample=reqs[0])
        arrivals = poisson_arrivals(rate, args.requests, seed=mi)
        trace += [(t % orbit_s, name, r)      # wrap the poisson tail
                  for t, r in zip(arrivals, reqs)]

    end = sched.serve_trace(trace)
    print(f"\n[orbit] {len(trace)} requests over {orbit_s:.2f} s of orbit "
          f"(finished at {end:.3f} s virtual)")
    print(sched.summary())

    # per-phase backend mix + energy (dispatches bucketed by start time)
    print(f"\n{'phase':9s} {'disp':>5s} {'backend mix':28s} "
          f"{'energy J':>9s} {'defer':>6s}")
    for (phase, start), (_, stop) in zip(bounds[:-1], bounds[1:]):
        disps = [d for d in sched.dispatches if start <= d.started < stop]
        defers = sum(1 for r in sched.deferrals if start <= r.time < stop)
        mix = {}
        for d in disps:
            mix[d.backend] = mix.get(d.backend, 0) + 1
        mix_s = " ".join(f"{b}:{c}" for b, c in sorted(mix.items())) or "-"
        e = sum(d.energy_j for d in disps)
        print(f"{phase:9s} {len(disps):5d} {mix_s:28s} {e:9.4f} "
              f"{defers:6d}")

    rids = [c.rid for c in sched.completions]
    audit = sched.envelope_report()
    ok = (len(rids) == len(trace) and len(set(rids)) == len(rids)
          and audit["n_violations"] == 0)
    print(f"\n[invariants] served {len(set(rids))}/{len(trace)} exactly "
          f"once; envelope violations={audit['n_violations']}; "
          f"max window power={audit['max_window_w']:.2f} W  "
          f"-> {'OK' if ok else 'FAILED'}")
    raise SystemExit(0 if ok else 1)


if __name__ == "__main__":
    main()
