"""QAT vs PTQ — implementing the paper's stated mitigation.

The paper: *"PTQ caused noticeable degradation that QAT could mitigate."*
This example measures that degradation on a space model and then runs
quantization-aware fine-tuning (straight-through-estimator fake-quant,
core/quantize.py) against the fp32 model's outputs (self-distillation — no
mission data needed on-board), showing the INT8 output error shrink.

Run:  PYTHONPATH=src python examples/qat_finetune.py [--model logistic_net]
      [--steps 150]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core.engine import OP_IMPLS, Engine
from repro.core.quantize import qat_quantize_params
from repro.models import SPACE_MODELS


def forward(graph, params, inputs, rng):
    """Differentiable graph execution (same op impls as the flex path)."""
    vals = {k: jnp.asarray(inputs[k], jnp.float32)
            for k in graph.graph_inputs}
    for name in graph.order:
        node = graph.nodes[name]
        if node.op == "input":
            continue
        rng, sub = jax.random.split(rng)
        vals[name] = OP_IMPLS[node.op]([vals[i] for i in node.inputs],
                                       params.get(name, {}), node.attrs, sub)
    return {o: vals[o] for o in graph.outputs}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="vae_encoder",
                    choices=sorted(SPACE_MODELS))
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    m = SPACE_MODELS[args.model]
    graph = m.build_graph()
    params = m.init_params(jax.random.PRNGKey(0))
    # logits are what decisions read; skip integer outputs like argmax
    float_outs = [o for o in graph.outputs
                  if graph.nodes[o].op not in ("argmax", "greater")]

    def sample_batch(key):
        keys = jax.random.split(key, args.batch)
        return [m.synthetic_input(k) for k in keys]

    teacher0 = jax.tree.map(lambda x: x, params)

    def quant_err(p, samples):
        """(rms, max) INT8-vs-fp32-teacher output error over samples."""
        sq, mx, n = 0.0, 0.0, 0
        for s in samples:
            rng = jax.random.PRNGKey(0)
            ref = forward(graph, teacher0, s, rng)
            q = forward(graph, qat_quantize_params(p, graph), s, rng)
            for o in float_outs:
                d = ref[o] - q[o]
                sq += float(jnp.sum(d * d))
                n += d.size
                mx = max(mx, float(jnp.max(jnp.abs(d))))
        return (sq / n) ** 0.5, mx

    eval_samples = sample_batch(jax.random.PRNGKey(99))
    rms0, max0 = quant_err(params, eval_samples)
    print(f"[ptq] INT8 output error before QAT: rms={rms0:.4e} max={max0:.4e}")

    # QAT: minimize ||quantized(params)(x) - fp32_teacher(x)||^2 with STE
    teacher = jax.tree.map(lambda x: x, params)

    def loss_fn(p, sample):
        rng = jax.random.PRNGKey(0)
        ref = forward(graph, teacher, sample, rng)
        out = forward(graph, qat_quantize_params(p, graph), sample, rng)
        return sum(jnp.mean((out[o] - ref[o]) ** 2) for o in float_outs)

    @jax.jit
    def step(p, sample):
        loss, g = jax.value_and_grad(loss_fn)(p, sample)
        p = jax.tree.map(lambda w, gw: w - args.lr * gw, p, g)
        return p, loss

    key = jax.random.PRNGKey(3)
    for i in range(args.steps):
        key, sub = jax.random.split(key)
        for s in sample_batch(sub):
            params, loss = step(params, s)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"  qat step {i:4d}  distill loss {float(loss):.3e}")

    rms1, max1 = quant_err(params, eval_samples)
    print(f"[qat] INT8 output error after {args.steps} QAT steps: "
          f"rms={rms1:.4e} max={max1:.4e} "
          f"(rms {rms0/max(rms1,1e-12):.1f}x better)")

    # confirm the fine-tuned weights still run through the INT8 engine path
    engine = Engine(graph, params)
    engine.calibrate(eval_samples[:4])
    out = engine.run(eval_samples[0], "accel")
    print(f"[engine] accel outputs after QAT: {sorted(out)}")


if __name__ == "__main__":
    main()
