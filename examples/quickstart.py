"""Quickstart — the paper's dual-toolchain workflow on one model in ~60 s.

Mirrors Section III of the paper end-to-end:
  1. build a space use-case network as an op graph (Netron analog),
  2. run the operator-coverage *inspector* (Vitis-AI inspector analog),
  3. execute on all three backends — cpu (ARM baseline), flex (HLS
     analog: jitted fp32, every op), accel (DPU analog: INT8 PTQ +
     Pallas MXU kernels),
  4. check the two fidelity properties the paper reports,
  5. print a Table-III-style row (measured-host + modeled-TPU).

Run:  PYTHONPATH=src python examples/quickstart.py [--model vae_encoder]
"""
import argparse
import time

import jax

from repro.core import inspector
from repro.core.energy import TPU_V5E, model_graph
from repro.core.engine import Engine
from repro.models import SPACE_MODELS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="vae_encoder",
                    choices=sorted(SPACE_MODELS))
    ap.add_argument("--trace", action="store_true",
                    help="build the op graph by tracing the model's "
                         "plain JAX function through the jaxpr front-end "
                         "(DESIGN.md §14) instead of the hand-built "
                         "builder — bit-exact same graph")
    args = ap.parse_args()
    m = SPACE_MODELS[args.model]

    # 1. graph (hand-built, or traced from the jaxpr — same result)
    graph = m.build_graph()
    params = m.init_params(jax.random.PRNGKey(0))
    if args.trace:
        import functools
        from repro.frontend import trace
        tm = trace(functools.partial(m.jax_forward, params),
                   dict(graph.graph_inputs), name=m.name)
        graph, params = tm.graph, tm.params
        print(f"[trace] rebuilt {m.name} from its jaxpr: "
              f"{len(graph.order)} nodes")
    print(f"[graph] {graph.name}: {graph.n_params:,} params, "
          f"{graph.n_ops:,} ops (paper: {m.paper_params:,} / "
          f"{m.paper_ops:,})")

    # 2. inspect — which path can take it?
    report = inspector.inspect(graph)
    print(f"[inspect]\n{report.summary()}")

    # 3. execute on the three backends
    engine = Engine(graph, params)
    inputs = m.synthetic_input(jax.random.PRNGKey(1))
    engine.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                      for i in range(4)])

    # 3b. the plan the graph compiler built (DESIGN.md §10): fusion
    # groups, int8 requant chains, and the BRAM/DDR activation arena
    # (Engine(..., fuse=False) is the op-by-op escape hatch)
    print(f"[plan]\n{engine.planned('accel').summary()}")

    outs, lat = {}, {}
    for backend in ("cpu", "flex", "accel"):
        rng = jax.random.PRNGKey(0)
        out = engine.run(inputs, backend, rng)        # compile/warmup
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = engine.run(inputs, backend, rng)
        jax.block_until_ready(out)
        lat[backend] = time.perf_counter() - t0
        outs[backend] = out
        print(f"[run:{backend:5s}] {lat[backend]*1e3:8.3f} ms   "
              f"outputs: {sorted(out)}")

    # 4. fidelity (paper: HLS matches CPU <=1e-10; PTQ is 'noticeable')
    import jax.numpy as jnp
    fid = max(float(jnp.max(jnp.abs(outs['cpu'][k].astype(jnp.float32)
                                    - outs['flex'][k].astype(jnp.float32))))
              for k in outs["cpu"])
    ptq = max(float(jnp.max(jnp.abs(outs['flex'][k].astype(jnp.float32)
                                    - outs['accel'][k].astype(jnp.float32))))
              for k in outs["cpu"])
    print(f"[fidelity] flex vs cpu max|delta| = {fid:.2e}   "
          f"PTQ (accel vs flex) = {ptq:.2e}")

    # 5. Table-III-style summary
    print(f"[speedup] flex {lat['cpu']/lat['flex']:.2f}x over cpu "
          f"(accel is interpret-mode on CPU — correctness only)")
    rep = model_graph(graph, TPU_V5E, "accel")
    print(f"[modeled tpu_v5e accel] {rep.row()}")


if __name__ == "__main__":
    main()
