"""End-to-end on-board serving driver — the paper's mission scenario.

Simulates one orbit segment of a spacecraft running two concurrent
use cases through the continuous-batching scheduler — both models served
from ONE process, round-robin, each with its own request queue, batch
ladder, and mission-cadence deadline:

  * **event detection / selective downlink** — the MMS plasma-region
    classifier scans FPI ion-energy distributions and keeps only
    region-of-interest crossings (the paper's ROI use case), and
  * **compression** — the VAE encoder turns 128x256 magnetogram tiles
    into 6-float latents for downlink (1:16,384).

Requests arrive on interleaved Poisson traces (the instruments sample
independently); the scheduler fills batches up to the ladder and flushes
ragged tails when a deadline approaches. Reports per-model telemetry
(p50/p99 latency vs deadline, batch fill, fps) and the end-to-end
downlink-budget reduction.

Run:  PYTHONPATH=src python examples/onboard_serving.py \
          [--requests 256] [--backend flex]
"""
import argparse

import jax
import numpy as np

from repro.core.engine import Engine
from repro.core.scheduler import (ContinuousBatchingScheduler, capped_ladder,
                                  poisson_arrivals)
from repro.models import SPACE_MODELS, synthetic_requests

FP32 = 4

USE_CASES = ("baseline_net", "vae_encoder")


def keep_mms(out):
    # MMS ROI policy: keep MSH/MSP crossings (paper's region-of-interest
    # trigger) PLUS low-margin (uncertain) classifications for ground
    # verification — the standard conservative on-board filter.
    head = np.sort(np.asarray(out["head"]).ravel())
    margin = float(head[-1] - head[-2])
    return int(out["region"]) >= 2 or margin < 0.113


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256,
                    help="requests per use case")
    ap.add_argument("--backend", default="flex",
                    choices=["cpu", "flex", "accel"])
    ap.add_argument("--batch", type=int, default=32,
                    help="top batch-ladder rung")
    # both conv-heavy use cases together saturate the CPU emulation host
    # above ~20 req/s each; real accelerator hardware takes far more
    ap.add_argument("--rate", type=float, default=16.0,
                    help="per-instrument Poisson arrival rate (req/s)")
    args = ap.parse_args()

    print("== on-board inference: one orbit segment ==")
    ladder = capped_ladder(args.batch)
    sched = ContinuousBatchingScheduler()
    graphs, trace = {}, []
    for mi, name in enumerate(USE_CASES):
        m = SPACE_MODELS[name]
        graphs[name] = m.build_graph()
        engine = Engine(graphs[name], m.init_params(jax.random.PRNGKey(0)))
        reqs = synthetic_requests(m, args.requests, seed=1 + mi)
        if args.backend == "accel":
            engine.calibrate(reqs[:4])
        # compression keeps everything (the latent IS the downlink product)
        keep = keep_mms if name == "baseline_net" else None
        # Mission-cadence deadlines for THIS host: BaselineNet gets the
        # FPI *fast-survey* cadence (4.5 s) — the default burst-mode
        # deadline (150 ms) budgets for the paper's FPGA latency, which
        # this CPU emulation host can't match for the 3-D conv net — and
        # the VAE gets the SHARP product cadence (45 s): compressed
        # latents only downlink once per product anyway.
        deadline = 4.5 if name == "baseline_net" else 45.0
        sched.register(name, engine, backend=args.backend, ladder=ladder,
                       deadline_s=deadline, keep_predicate=keep,
                       warmup_sample=reqs[0])
        trace += [(t, name, r) for t, r in
                  zip(poisson_arrivals(args.rate, args.requests, seed=mi),
                      reqs)]

    end = sched.serve_trace(trace)
    tel = sched.telemetry()
    print(f"\n[schedule] {len(trace)} requests co-served in {end:.3f} s "
          f"(virtual)\n" + sched.summary())

    totals = [0, 0]
    for name in USE_CASES:
        t = tel[name]
        in_bytes = sum(int(np.prod(s))
                       for s in graphs[name].graph_inputs.values()) * FP32
        if name == "vae_encoder":
            downlinked = t.n_completed * 6 * FP32   # latent downlink
        else:
            downlinked = t.n_kept * in_bytes        # kept raw samples
        raw = t.n_completed * in_bytes
        print(f"[{name}] downlink: raw={raw/1e6:.2f} MB -> "
              f"sent={downlinked/1e6:.4f} MB "
              f"({(1 - downlinked/raw)*100:.2f}% reduction)")
        totals[0] += raw
        totals[1] += downlinked
    print(f"\n[mission] total raw {totals[0]/1e6:.2f} MB -> downlinked "
          f"{totals[1]/1e6:.4f} MB "
          f"({(1 - totals[1]/totals[0])*100:.2f}% downlink reduction)")


if __name__ == "__main__":
    main()
