"""End-to-end on-board serving driver — the paper's mission scenario.

Simulates one orbit segment of a spacecraft running two concurrent
use cases through the batched, double-buffered serving pipeline:

  * **event detection / selective downlink** — the MMS plasma-region
    classifier scans FPI ion-energy distributions and keeps only
    region-of-interest crossings (the paper's ROI use case), and
  * **compression** — the VAE encoder turns 128x256 magnetogram tiles
    into 6-float latents for downlink (1:16,384).

Reports per-phase times (staging vs compute — Fig 11's observation),
achieved FPS, and the end-to-end downlink-budget reduction.

Run:  PYTHONPATH=src python examples/onboard_serving.py \
          [--requests 256] [--backend flex]
"""
import argparse

import jax
import numpy as np

from repro.core.engine import Engine
from repro.core.pipeline import ServingPipeline
from repro.models import SPACE_MODELS

FP32 = 4


def run_use_case(name: str, n_requests: int, backend: str, batch: int):
    m = SPACE_MODELS[name]
    graph = m.build_graph()
    engine = Engine(graph, m.init_params(jax.random.PRNGKey(0)))
    key = jax.random.PRNGKey(1)
    reqs = []
    for _ in range(n_requests):
        key, sub = jax.random.split(key)
        reqs.append({k: np.asarray(v) for k, v in m.synthetic_input(sub).items()})
    if backend == "accel":
        engine.calibrate(reqs[:4])

    if name == "vae_encoder":
        keep = None                 # compression: every latent downlinks
    else:
        # MMS ROI policy: keep MSH/MSP crossings (paper's region-of-interest
        # trigger) PLUS low-margin (uncertain) classifications for ground
        # verification — the standard conservative on-board filter.
        def keep(out):
            head = np.sort(np.asarray(out["head"]).ravel())
            margin = float(head[-1] - head[-2])
            return int(out["region"]) >= 2 or margin < 0.113

    pipe = ServingPipeline(engine, backend=backend, batch_size=batch,
                           keep_predicate=keep)
    stats = pipe.run(reqs)

    in_bytes = sum(int(np.prod(s)) for s in graph.graph_inputs.values()) * FP32
    if name == "vae_encoder":
        out_bytes = 6 * FP32                       # latent downlink
        downlinked = stats.n_requests * out_bytes
    else:
        out_bytes = in_bytes                       # kept raw samples downlink
        downlinked = stats.n_kept * out_bytes
    raw = stats.n_requests * in_bytes

    ph = stats.phases
    print(f"\n[{name}] {stats.n_requests} requests @ backend={backend}")
    print(f"  fps={stats.fps:9.1f}   kept={stats.n_kept}")
    print(f"  phases: stage_in={ph.stage_in*1e3:7.1f} ms  "
          f"compute={ph.compute*1e3:7.1f} ms  "
          f"overlapped={ph.overlapped*1e3:7.1f} ms  "
          f"wall={ph.wall*1e3:7.1f} ms")
    print(f"  downlink: raw={raw/1e6:.2f} MB -> sent={downlinked/1e6:.4f} MB "
          f"({(1 - downlinked/raw)*100:.2f}% reduction)")
    return raw, downlinked


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--backend", default="flex",
                    choices=["cpu", "flex", "accel"])
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    print("== on-board inference: one orbit segment ==")
    totals = [0, 0]
    for uc in ("baseline_net", "vae_encoder"):
        raw, sent = run_use_case(uc, args.requests, args.backend, args.batch)
        totals[0] += raw
        totals[1] += sent
    print(f"\n[mission] total raw {totals[0]/1e6:.2f} MB -> downlinked "
          f"{totals[1]/1e6:.4f} MB "
          f"({(1 - totals[1]/totals[0])*100:.2f}% downlink reduction)")


if __name__ == "__main__":
    main()
