"""Training driver with checkpoint/restart — fault tolerance demonstrated.

Trains a small llama-family LM (same code path as the production configs)
on the synthetic task, kills itself at a configurable step to simulate a
node failure, then the rerun resumes from the last committed async
checkpoint. Shows: loss goes down, resume is exact (same data order via
the step-seeded pipeline), and the StepGuard's straggler detection.

Run:
  PYTHONPATH=src python examples/train_driver.py --steps 200            # run 1
  PYTHONPATH=src python examples/train_driver.py --steps 200            # rerun: resumes
  PYTHONPATH=src python examples/train_driver.py --steps 200 --crash-at 120

Delegates to repro.launch.train (the production launcher) — this file
just picks CPU-friendly sizes.
"""
import argparse
import os
import sys

from repro.launch import train as train_launcher


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    if args.crash_at is not None:
        os.environ["REPRO_CRASH_AT_STEP"] = str(args.crash_at)

    sys.exit(train_launcher.main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--save-every", "25",
        "--log-every", "20",
    ]))


if __name__ == "__main__":
    main()
