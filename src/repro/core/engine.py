"""Dual-backend inference engine — the paper's toolchain trade-off as code.

Three execution backends for an op graph (DESIGN.md §2):

* ``cpu``   — the ARM-CPU baseline analog: pure-jnp ops, ``jax.disable_jit``
              at call time, fp32. Slow on purpose; it is the measured "1x".
* ``flex``  — the Vitis-HLS analog: the same fp32 math, jit-compiled by
              XLA. Supports *every* operator (sigmoid, 3-D conv/pool,
              comparators, sampling) at IEEE-754 fp32 — the paper's
              "numerical fidelity <= 1e-10" property is tested against cpu.
* ``accel`` — the Vitis-AI/DPU analog: INT8 PTQ weights, Pallas MXU kernels
              for conv2d (im2col) and dense, fused ReLU epilogues; only a
              restricted operator set (core/inspector.py). Models with
              unsupported ops are *partitioned*: supported segments run
              accel, the rest falls back to flex — exactly the paper's
              VAE-tail (sampling/exp on CPU) arrangement.

Weight residency mirrors the paper's BRAM policy: quantized weights are
device-resident arrays (VMEM residency on real TPU is the kernels' block
lifetime); the energy model charges HBM traffic for anything that spills.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import inspector as inspector_mod
from repro.core.opgraph import Graph, Node
from repro.core.quantize import QuantizedLayer
from repro.kernels import ops as kops

# ---------------------------------------------------------------------------
# fp32 op implementations (cpu + flex backends)
# ---------------------------------------------------------------------------


def _conv2d_xla(x, p, a):
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(a.get("stride", 1),) * 2,
        padding=a.get("padding", "SAME"),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return out + p["b"]


def _conv3d_xla(x, p, a):
    out = jax.lax.conv_general_dilated(
        x[None].astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(a.get("stride", 1),) * 3,
        padding=a.get("padding", "SAME"),
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"))[0]
    return out + p["b"]


def _pool(x, a, ndim, op):
    k, s = a["kernel"], a.get("stride", a["kernel"])
    window = (k,) * ndim + (1,)
    strides = (s,) * ndim + (1,)
    if op == "max":
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, window, strides,
                                     "VALID")
    out = jax.lax.reduce_window(x, 0.0, jax.lax.add, window, strides, "VALID")
    return out / (k ** ndim)


OP_IMPLS: Dict[str, Callable] = {
    "conv2d": lambda x, p, a, rng: _conv2d_xla(x[0], p, a),
    "conv3d": lambda x, p, a, rng: _conv3d_xla(x[0], p, a),
    "maxpool2d": lambda x, p, a, rng: _pool(x[0], a, 2, "max"),
    "avgpool2d": lambda x, p, a, rng: _pool(x[0], a, 2, "avg"),
    "maxpool3d": lambda x, p, a, rng: _pool(x[0], a, 3, "max"),
    "avgpool3d": lambda x, p, a, rng: _pool(x[0], a, 3, "avg"),
    "dense": lambda x, p, a, rng: x[0].reshape(-1) @ p["w"] +
    (p["b"] if "b" in p else 0.0),
    "flatten": lambda x, p, a, rng: x[0].reshape(-1),
    "relu": lambda x, p, a, rng: jnp.maximum(x[0], 0.0),
    "leaky_relu": lambda x, p, a, rng: jnp.where(
        x[0] > 0, x[0], a.get("alpha", 0.01) * x[0]),
    "sigmoid": lambda x, p, a, rng: jax.nn.sigmoid(x[0]),
    "tanh": lambda x, p, a, rng: jnp.tanh(x[0]),
    "softplus": lambda x, p, a, rng: jax.nn.softplus(x[0]),
    "exp": lambda x, p, a, rng: jnp.exp(x[0]),
    "concat": lambda x, p, a, rng: jnp.concatenate(x, axis=a.get("axis", -1)),
    "add": lambda x, p, a, rng: x[0] + x[1],
    "sub": lambda x, p, a, rng: x[0] - x[1],
    "mul": lambda x, p, a, rng: x[0] * x[1],
    "greater": lambda x, p, a, rng: (x[0] > a["threshold"]).astype(jnp.float32),
    "sample_normal": lambda x, p, a, rng: x[0] + jnp.exp(0.5 * x[1])
    * jax.random.normal(rng, x[0].shape),
    "argmax": lambda x, p, a, rng: jnp.argmax(x[0]).astype(jnp.int32),
}


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EnginePlan:
    graph: Graph
    assignment: Dict[str, str]          # node -> 'accel' | 'flex'
    coverage: float                     # fraction of MACs on the accel path


class Engine:
    """Executes an op graph on a chosen backend (or a partitioned mix)."""

    def __init__(self, graph: Graph, params: Dict[str, Dict[str, jax.Array]]):
        self.graph = graph
        self.params = params
        self._quant: Optional[Dict[str, QuantizedLayer]] = None
        self._calib: Dict[str, float] = {}

    # -- planning (paper: run the inspector, then choose the toolchain) -----

    def plan(self) -> EnginePlan:
        assignment = inspector_mod.assign_backends(self.graph)
        macs = self.graph.n_macs or 1
        accel_macs = sum(n.macs for n in self.graph.nodes.values()
                         if assignment[n.name] == "accel")
        return EnginePlan(self.graph, assignment, accel_macs / macs)

    # -- PTQ ----------------------------------------------------------------

    def calibrate(self, sample_inputs: List[Dict[str, np.ndarray]]) -> None:
        """Post-training quantization: record per-node activation absmax over
        a calibration set, then quantize weights per-output-channel."""
        from repro.core.quantize import calibrate_graph, quantize_weights
        self._calib = calibrate_graph(self, sample_inputs)
        self._quant = quantize_weights(self.graph, self.params)

    # -- execution ----------------------------------------------------------

    def run(self, inputs: Dict[str, jax.Array], backend: str = "flex",
            rng: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
        """Single-sample execution (the paper measures per-inference)."""
        if backend == "cpu":
            with jax.disable_jit():
                return self._execute(inputs, "flex",
                                     rng if rng is not None
                                     else jax.random.PRNGKey(0))
        if backend in ("flex", "accel"):
            return self._execute_jit(inputs, backend,
                                     rng if rng is not None
                                     else jax.random.PRNGKey(0))
        raise ValueError(backend)

    @functools.lru_cache(maxsize=8)
    def _jitted(self, backend: str):
        def f(inputs, rng):
            return self._execute(inputs, backend, rng)
        return jax.jit(f)

    def _execute_jit(self, inputs, backend, rng):
        return self._jitted(backend)(inputs, rng)

    def _execute(self, inputs: Dict[str, jax.Array], backend: str,
                 rng: Optional[jax.Array]) -> Dict[str, jax.Array]:
        if backend == "accel" and self._quant is None:
            raise RuntimeError("accel backend needs calibrate() first (PTQ)")
        assignment = (inspector_mod.assign_backends(self.graph)
                      if backend == "accel" else None)
        vals: Dict[str, jax.Array] = {}
        for name, shape in self.graph.graph_inputs.items():
            x = jnp.asarray(inputs[name], jnp.float32)
            assert x.shape == shape, (name, x.shape, shape)
            vals[name] = x
        for name in self.graph.order:
            node = self.graph.nodes[name]
            if node.op == "input":
                continue
            xs = [vals[i] for i in node.inputs]
            p = self.params.get(name, {})
            if rng is not None:
                rng, sub = jax.random.split(rng)
            else:
                sub = jax.random.PRNGKey(0)
            if backend == "accel" and assignment[name] == "accel" \
                    and name in (self._quant or {}):
                vals[name] = self._run_quantized(node, xs)
            else:
                vals[name] = OP_IMPLS[node.op](xs, p, node.attrs, sub)
        return {o: vals[o] for o in self.graph.outputs}

    def _run_quantized(self, node: Node, xs) -> jax.Array:
        """INT8 path: quantize activation per-tensor, run the Pallas MXU
        kernel, dequant in the fused epilogue."""
        q = self._quant[node.name]
        x = xs[0]
        if node.op == "dense":
            xf = x.reshape(1, -1)
        else:  # conv2d via im2col
            xf, out_spatial = _im2col(x, node.attrs, q.w_q.shape)
        xs_scale = jnp.max(jnp.abs(xf), axis=1) / 127.0 + 1e-12
        x_q = jnp.clip(jnp.round(xf / xs_scale[:, None]), -127, 127
                       ).astype(jnp.int8)
        m, k = x_q.shape
        n = q.w_q.shape[1]
        bm = _pick_block(m)
        bk = _pick_block(k)
        bn = _pick_block(n)
        out = kops.int8_matmul(x_q, q.w_q, xs_scale, q.w_scale, q.bias,
                               relu=bool(node.attrs.get("fused_relu")),
                               bm=bm, bn=bn, bk=bk)
        if node.op == "dense":
            return out.reshape(-1)
        return out.reshape(*out_spatial, n)


def _pick_block(n: int, target: int = 128) -> int:
    """Largest divisor of n that is <= target (MXU-aligned when possible)."""
    if n % target == 0:
        return target
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return n


def _im2col(x: jax.Array, attrs: dict, wq_shape) -> tuple:
    """[H,W,Cin] -> patch matrix [Ho*Wo, KH*KW*Cin] (+ out spatial dims)."""
    kh, kw = attrs["kernel"]
    stride = attrs.get("stride", 1)
    pad = attrs.get("padding", "SAME")
    h, w, cin = x.shape
    if pad == "SAME":
        ho, wo = -(-h // stride), -(-w // stride)
        ph = max((ho - 1) * stride + kh - h, 0)
        pw = max((wo - 1) * stride + kw - w, 0)
        x = jnp.pad(x, ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2),
                        (0, 0)))
    else:
        ho, wo = (h - kh) // stride + 1, (w - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(x, (i, j, 0),
                               (i + (ho - 1) * stride + 1,
                                j + (wo - 1) * stride + 1, cin),
                               (stride, stride, 1))
            cols.append(sl.reshape(ho * wo, cin))
    return jnp.concatenate(cols, axis=1), (ho, wo)
