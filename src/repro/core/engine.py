"""Dual-backend inference engine — compile once, serve batches.

Three execution backends for an op graph (DESIGN.md §2):

* ``cpu``   — the ARM-CPU baseline analog: the same batched program run
              op-by-op under ``jax.disable_jit``, fp32. Slow on purpose;
              it is the measured "1x".
* ``flex``  — the Vitis-HLS analog: fp32 math, jit-compiled by XLA.
              Supports *every* operator (sigmoid, 3-D conv/pool,
              comparators, sampling) at IEEE-754 fp32.
* ``accel`` — the Vitis-AI/DPU analog: INT8 PTQ weights, Pallas MXU
              kernels for conv2d (shift-and-matmul, no HBM im2col) and
              dense, fused ReLU + dequant epilogues; only a restricted
              operator set (core/inspector.py). Unsupported — or
              PTQ-infidelity-demoted — nodes fall back to flex, exactly
              the paper's partial-offload arrangement.

Execution is staged (core/plan.py, DESIGN.md §7): ``compile(backend,
batch_size)`` runs the inspector once, rewrites the graph through the
graph-compiler pass pipeline (core/passes.py, DESIGN.md §10: constant
folding, DCE, epilogue fusion, int8 requant chains — disable with
``Engine(..., fuse=False)``), partitions it into contiguous accel/flex
segments, folds PTQ weight/activation scales into per-node constants,
plans the static BRAM/DDR activation arena (core/memory.py), and emits
ONE jitted batched callable — inputs carry a leading batch dim
end-to-end. Compiled plans are cached per instance keyed by (backend,
batch size), so steady-state serving never re-traces; ``run``/
``run_batch`` are thin wrappers over the cache. Weight residency mirrors
the paper's BRAM policy: quantized weights are device-resident plan
constants (VMEM residency on real TPU is the kernels' block lifetime).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import inspector as inspector_mod
from repro.core.opgraph import Graph
from repro.core.plan import (BATCHED_OP_IMPLS, CompiledPlan, EagerPlan,
                             ExecutionPlan)
from repro.core.quantize import QuantizedLayer

# ---------------------------------------------------------------------------
# Single-sample fp32 op implementations (calibration tracing + references) —
# derived from the batched table so the math executed at calibration time
# can never drift from the math the plans serve.
# ---------------------------------------------------------------------------


def _single_sample(op_impl: Callable) -> Callable:
    def f(xs, p, a, rng):
        sub = None if rng is None else _raw_keys(rng)[None]
        return op_impl([x[None] for x in xs], p, a, sub)[0]
    return f


OP_IMPLS: Dict[str, Callable] = {
    op: _single_sample(impl) for op, impl in BATCHED_OP_IMPLS.items()}


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EnginePlan:
    graph: Graph
    assignment: Dict[str, str]          # node -> 'accel' | 'flex'
    coverage: float                     # fraction of MACs on the accel path


class Engine:
    """Executes an op graph on a chosen backend (or a partitioned mix)."""

    def __init__(self, graph: Graph, params: Dict[str, Dict[str, jax.Array]],
                 ptq_demote_threshold: float = 0.2, fuse: bool = True,
                 autotune: bool = False, tuning_cache=None,
                 autotune_measure: bool = False,
                 autotune_pack_batch: int = 32):
        self.graph = graph
        self.params = params
        self.ptq_demote_threshold = ptq_demote_threshold
        # fuse=False is the escape hatch: skip the graph-compiler pass
        # pipeline (DESIGN.md §10) and build the pre-pass per-node plans
        self.fuse = fuse
        # autotune=False (the default) reproduces the heuristic kernel
        # blocks bit-for-bit; autotune=True runs the plan-time tile
        # search + weight prepack (DESIGN.md §11). ``tuning_cache`` is a
        # JSON path (or a TuningCache) — warm caches skip ALL candidate
        # evaluations; ``autotune_measure`` additionally wall-clocks the
        # model's top-K picks (opt-in: on this host it measures the
        # Pallas interpreter, on a TPU the compiled Mosaic kernels).
        self.autotune = autotune
        self.autotune_pack_batch = autotune_pack_batch
        self._tuner = None
        if autotune:
            from repro.core.autotune import Autotuner, TuningCache
            cache = (tuning_cache if isinstance(tuning_cache, TuningCache)
                     else TuningCache(tuning_cache))
            self._tuner = Autotuner(cache, measure=autotune_measure)
        elif tuning_cache is not None or autotune_measure:
            # silently dropping these would serve heuristic plans while
            # the caller believes a warm cache is in play
            raise ValueError(
                "tuning_cache/autotune_measure require autotune=True")
        self._quant: Optional[Dict[str, QuantizedLayer]] = None
        self._calib: Dict[str, float] = {}
        self._ptq_err: Dict[str, float] = {}
        # per-instance plan caches (an lru_cache on a bound method would pin
        # `self` — and its quantized weights — for the process lifetime)
        self._planned: Dict[str, ExecutionPlan] = {}
        self._compiled: Dict[tuple, object] = {}

    @property
    def tuner(self):
        """The engine's Autotuner (None when ``autotune=False``) — its
        ``stats``/``cache`` are the re-search observability surface."""
        return self._tuner

    # -- planning (paper: run the inspector, then choose the toolchain) -----

    def plan(self) -> EnginePlan:
        assignment = inspector_mod.assign_backends(self.graph)
        macs = self.graph.n_macs or 1
        accel_macs = sum(n.macs for n in self.graph.nodes.values()
                         if assignment[n.name] == "accel")
        return EnginePlan(self.graph, assignment, accel_macs / macs)

    # -- PTQ ----------------------------------------------------------------

    def calibrate(self, sample_inputs: List[Dict[str, np.ndarray]]) -> None:
        """Post-training quantization: record per-node activation absmax
        over a calibration set, quantize weights per-output-channel, and
        measure per-node PTQ error (the plan-time demotion gate)."""
        from repro.core.quantize import (_trace, calibrate_graph,
                                         ptq_error_ratios, quantize_weights)
        traces = [_trace(self, s) for s in sample_inputs]   # one fp32 pass
        self._calib = calibrate_graph(self, sample_inputs, traces=traces)
        self._quant = quantize_weights(self.graph, self.params)
        self._ptq_err = ptq_error_ratios(self, sample_inputs, self._quant,
                                         self._calib, traces=traces)
        # new scales/weights invalidate any previously folded accel plan
        self._planned.pop("accel", None)
        self._compiled = {k: v for k, v in self._compiled.items()
                          if k[0] != "accel"}

    def share_calibration(self, other: "Engine") -> None:
        """Adopt ``other``'s PTQ calibration state (same graph topology
        and the same params): activation absmax, quantized weights, and
        the per-node PTQ error map. The twin-engine idiom the benchmarks
        and tests use to pay interpret-mode calibration once per model
        instead of once per engine variant."""
        self._quant = other._quant
        self._calib = other._calib
        self._ptq_err = other._ptq_err
        self._planned.pop("accel", None)
        self._compiled = {k: v for k, v in self._compiled.items()
                          if k[0] != "accel"}

    # -- staged compilation --------------------------------------------------

    def planned(self, backend: str = "flex") -> ExecutionPlan:
        """The **Planned** stage for a backend (inspector + PTQ folding run
        exactly once; cached per instance)."""
        key = "accel" if backend == "accel" else "flex"
        if key not in self._planned:
            self._planned[key] = ExecutionPlan(
                self.graph, self.params, key,
                quant=self._quant, act_absmax=self._calib,
                ptq_err=self._ptq_err,
                ptq_demote_threshold=self.ptq_demote_threshold,
                fuse=self.fuse, tuner=self._tuner,
                pack_batch=self.autotune_pack_batch)
        return self._planned[key]

    def compile(self, backend: str = "flex", batch_size: int = 1):
        """The **Compiled** stage: one batched executable per (backend,
        batch-size), cached — calling it never re-traces."""
        if backend not in ("cpu", "flex", "accel"):
            raise ValueError(backend)
        key = (backend, batch_size)
        if key not in self._compiled:
            planned = self.planned(backend)
            if backend == "cpu":
                self._compiled[key] = EagerPlan(planned, batch_size)
            else:
                self._compiled[key] = planned.lower(batch_size).compile()
        return self._compiled[key]

    # -- execution ----------------------------------------------------------

    def run(self, inputs: Dict[str, jax.Array], backend: str = "flex",
            rng: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
        """Single-sample execution (the paper measures per-inference) —
        a batch-1 view over the compiled plan."""
        if rng is None:
            rng = jax.random.PRNGKey(0)
        batched = self.run_batch(
            {k: jnp.asarray(v, jnp.float32)[None] for k, v in inputs.items()},
            backend, rngs=_raw_keys(rng)[None])
        return {k: v[0] for k, v in batched.items()}

    def run_batch(self, inputs: Dict[str, jax.Array], backend: str = "flex",
                  rngs: Optional[jax.Array] = None) -> Dict[str, jax.Array]:
        """Batched execution: every input carries a leading batch dim;
        ``rngs`` is one PRNG key per sample ([B, 2])."""
        staged = {}
        batch = None
        for name, shape in self.graph.graph_inputs.items():
            x = jnp.asarray(inputs[name], jnp.float32)
            assert x.ndim == len(shape) + 1 and x.shape[1:] == shape, \
                (name, x.shape, shape)
            if batch is None:
                batch = x.shape[0]
            assert x.shape[0] == batch, (name, x.shape, batch)
            staged[name] = x
        if rngs is None:
            rngs = jax.random.split(jax.random.PRNGKey(0), batch)
        rngs = _raw_keys(rngs)
        assert rngs.shape == (batch, 2), rngs.shape
        return self.compile(backend, batch)(staged, rngs)


def _raw_keys(rng: jax.Array) -> jax.Array:
    """Accept both old-style uint32 keys and new-style typed keys."""
    if jax.dtypes.issubdtype(rng.dtype, jax.dtypes.prng_key):
        rng = jax.random.key_data(rng)
    return jnp.asarray(rng, jnp.uint32)
