"""Energy / power / throughput model — the paper's E = P x t, on TPU terms.

The paper measures the ZCU104's 12 V rail (board) and INT rail (MPSoC) and
reports per-inference energy. This container has no power rails, so we do
both of what's honest:

* **measured-host** numbers: wall-clock latency of the cpu/flex/accel
  backends on THIS host. Speedups and *relative* energy ratios reproduce
  the paper's Table III structure (CPU 1x baseline).
* **modeled-TPU** numbers: an analytic roofline-style model with public
  TPU v5e constants. Per op: t = max(FLOPs/peak, bytes/HBM_bw);
  E = P_busy * t + leakage share. Weight residency mirrors the paper's
  BRAM policy — params that fit the VMEM budget are charged HBM traffic
  once (first load), spilled params are charged per inference
  (the BaselineNet effect in the paper's Table III).

Both are reported side by side in benchmarks/table3_performance.py and are
never conflated.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.opgraph import Graph, Node, base_op, node_param_bytes

# ---------------------------------------------------------------------------
# Hardware models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops_f32: float
    peak_flops_bf16: float
    peak_ops_int8: float
    hbm_bw: float                  # bytes/s
    onchip_bytes: float            # VMEM budget for weight residency
    power_busy: float              # W during compute
    power_idle: float              # W static
    ici_bw: float = 0.0            # per-link bytes/s
    util: float = 1.0              # achievable fraction of peak compute
    overhead_s: float = 0.0        # fixed per-DISPATCH overhead (staging:
                                   # one AXI/DMA setup per batch, amortized
                                   # across the batch)
    dispatch_s: float = 0.0        # per-node, per-SAMPLE framework dispatch
                                   # overhead (the eager per-layer baseline;
                                   # 0 for compiled/streaming backends)
    ddr_pj_per_byte: float = 0.0   # off-chip access energy (J/byte): what
                                   # makes DDR traffic cost JOULES even
                                   # when the roofline is compute-bound —
                                   # the lever operator fusion pulls
    grid_step_s: float = 0.0       # per-tile sequencer overhead (s): one
                                   # instruction fetch / DMA descriptor per
                                   # kernel grid step. Only the autotuner's
                                   # kernel-level pricer charges it (the
                                   # coarse roofline has no tile notion),
                                   # so default cost signatures are
                                   # unchanged by this field.
    stage_bw: float = 0.0          # host->device staging bandwidth (B/s):
                                   # PS-side batch assembly + AXI-DMA into
                                   # the accelerator's DDR window. Only the
                                   # pipelined stage decomposition
                                   # (`stage_costs`) charges it — the
                                   # serial roofline folds staging into
                                   # `overhead_s`, so latency_s/energy_j
                                   # are unchanged by this field. 0 means
                                   # no separate staging channel (cpu).


# Public TPU v5e figures: 197 TFLOP/s bf16 / 394 TOP/s int8, 819 GB/s HBM,
# ~50 GB/s/link ICI (assignment constants). fp32 on the MXU runs at ~1/4
# bf16 rate. VMEM ~64 MiB; chip power ~170 W busy / ~60 W idle (board-level
# figures from public v5e efficiency reports; used consistently, only
# ratios matter for the Table III reproduction).
TPU_V5E = HardwareModel(
    name="tpu_v5e",
    peak_flops_f32=197e12 / 4,
    peak_flops_bf16=197e12,
    peak_ops_int8=394e12,
    hbm_bw=819e9,
    onchip_bytes=64 * 2**20,
    power_busy=170.0,
    power_idle=60.0,
    ici_bw=50e9,
)

# The paper's ZCU104 (for cross-checking our model against their CPU/DPU
# measurements): A53 CPU ~ 6 GFLOP/s fp32; DPU B4096 @300 MHz = 1.2 TOP/s
# int8; DDR4 ~19.2 GB/s; BRAM+URAM ~ 4.75 MB; PS ~2-2.75 W, DPU adds ~4 W.
# DDR4 system-level access energy ≈ 20 pJ/bit device+PHY+controller →
# ~150 pJ/B, shared by every ZCU104 path (one memory subsystem).
_ZCU104_DDR_PJ = 150e-12

ZCU104_CPU = HardwareModel(
    name="zcu104_arm_a53",
    peak_flops_f32=6e9, peak_flops_bf16=6e9, peak_ops_int8=12e9,
    hbm_bw=19.2e9, onchip_bytes=1 * 2**20,
    power_busy=2.75, power_idle=2.0,
    ddr_pj_per_byte=_ZCU104_DDR_PJ,
    # The paper's CPU baseline runs PyTorch per-sample in the instrument
    # loop; its small-model Table III rows are dispatch-bound, not
    # FLOP-bound (LogisticNet: 3.13 ms measured vs ~5 us roofline). The
    # implied per-layer eager-dispatch cost spans ~7-780 us across models;
    # 30 us/node/sample is the geometric middle and reproduces the
    # dispatch-dominated regime without over-fitting any one row.
    dispatch_s=30e-6)
ZCU104_DPU = HardwareModel(
    name="zcu104_dpu_b4096",
    peak_flops_f32=0.1e12, peak_flops_bf16=0.1e12, peak_ops_int8=1.2e12,
    hbm_bw=19.2e9, onchip_bytes=4.75 * 2**20,
    power_busy=6.75, power_idle=5.0,
    ddr_pj_per_byte=_ZCU104_DDR_PJ,
    # Paper Table III implies the DPU sustains 4-13% of its 1.2 TOP/s peak
    # on these small CNNs (50.6 / 150.1 GOP/s measured); 0.125 calibrated
    # to CNetPlusScalar, the DPU-friendliest workload. Each tile op costs
    # one DPU instruction fetch + DMA descriptor (~10 us at 300 MHz with
    # the AXI round-trip) — the term the tile autotuner trades against
    # padding waste (DESIGN.md §11).
    util=0.125, overhead_s=2e-4, grid_step_s=1e-5,
    # PYNQ-style PS staging: NumPy batch assembly + fp32 buffer fill over
    # AXI-DMA sustains a few hundred MB/s, well under the 19.2 GB/s DDR
    # peak — the regime behind the paper's Fig 11, where input staging
    # DOMINATES inference for the small models. 0.6 GB/s is the staging
    # channel both FPGA paths share (one PS, one DMA engine).
    stage_bw=0.6e9)

# The paper's *naive* HLS designs (no perf pragmas): each layer maps to a
# sequential 100 MHz dataflow stage; Table III's HLS rows imply ~15-25
# effective MOP/s plus ~27 us of AXI staging per inference. This model
# reproduces all four HLS rows within ~35% (see table3 cross-check).
ZCU104_HLS_NAIVE = HardwareModel(
    name="zcu104_hls_naive",
    peak_flops_f32=20e6, peak_flops_bf16=20e6, peak_ops_int8=20e6,
    hbm_bw=19.2e9, onchip_bytes=4.75 * 2**20,
    power_busy=1.75, power_idle=1.5,
    ddr_pj_per_byte=_ZCU104_DDR_PJ,
    util=1.0, overhead_s=27e-6, stage_bw=0.6e9)


# ---------------------------------------------------------------------------
# Per-graph energy model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EnergyReport:
    hw: str
    backend: str
    latency_s: float
    energy_j: float
    fps: float
    mops: float                     # throughput in MOP/s (paper's metric)
    weights_resident: bool
    bound: str                      # 'compute' | 'memory'
    bytes_moved: float = 0.0        # modeled DDR/HBM traffic per inference

    def row(self) -> str:
        return (f"{self.hw:14s} {self.backend:6s} "
                f"lat={self.latency_s*1e3:8.3f} ms  fps={self.fps:10.1f}  "
                f"thr={self.mops:12.1f} MOP/s  E={self.energy_j*1e3:9.4f} mJ  "
                f"bound={self.bound}")


def _peak(hw: HardwareModel, backend: str) -> float:
    if backend == "accel":
        return hw.peak_ops_int8
    return hw.peak_flops_f32


def _quantized_set(graph: Graph, backend: str,
                   quantized: Optional[Set[str]]) -> Set[str]:
    """Which nodes carry int8 weights. Without an explicit set, the
    accel backend assumes its quantizable ops (conv2d/dense) do — the
    graph-only approximation the benchmarks use."""
    if quantized is not None:
        return quantized
    if backend != "accel":
        return set()
    return {n.name for n in graph.nodes.values()
            if base_op(n) in ("conv2d", "dense")}


def _node_weight_bytes(node: Node, quantized: Set[str],
                       packed_bytes: Optional[Dict[str, int]] = None) -> int:
    """Per-node parameter footprint at actual post-PTQ widths: int8
    weights + fp32 biases for quantized nodes, fp32 everywhere else
    (the `opgraph.node_param_bytes` split — one definition). A node in
    ``packed_bytes`` is charged its prepacked (tile-padded) footprint
    instead — the bytes the weight arena actually keeps resident."""
    if packed_bytes and node.name in packed_bytes:
        return packed_bytes[node.name]
    return node_param_bytes(node, 1 if node.name in quantized else 4)


def weight_bytes(graph: Graph, backend: str,
                 quantized: Optional[Set[str]] = None,
                 packed_bytes: Optional[Dict[str, int]] = None) -> int:
    """Whole-graph parameter footprint at per-node dtype widths (what
    BRAM residency and the cost signatures charge) — delegates to
    `Graph.param_bytes` with a per-node weight-width map. Nodes with a
    prepacked weight arena entry (``packed_bytes``: node -> bytes) are
    charged the packed tile-padded footprint instead."""
    q = _quantized_set(graph, backend, quantized)
    if not packed_bytes:
        return graph.param_bytes(4, node_dtype_bytes={n: 1 for n in q})
    return sum(_node_weight_bytes(n, q, packed_bytes)
               for n in graph.nodes.values())


def _act_bytes(graph: Graph, name: str) -> int:
    """fp32 wire footprint of one node's value (per sample)."""
    shape = graph.nodes[name].out_shape or ()
    n = 1
    for d in shape:
        n *= d
    return n * 4


def _compute_cost(graph: Graph, hw: HardwareModel, backend: str,
                  batch: int,
                  node_times: Optional[Dict[str, float]] = None
                  ) -> Tuple[float, int]:
    """(compute_t, n_compute_nodes) — the one definition of per-op
    arithmetic time both the op-by-op and the arena cost paths share
    (fusion moves bytes, never FLOPs). ``node_times`` (node -> seconds,
    whole batch) replaces the coarse roofline term for nodes the
    autotuner priced with its kernel-level model — those times already
    include util, padding waste, and per-tile sequencer overhead."""
    compute_t = 0.0
    tuned_t = 0.0
    n_compute_nodes = 0
    peak = _peak(hw, backend)
    for node in graph.nodes.values():
        if node.op in ("input", "const"):
            continue
        n_compute_nodes += 1
        if node_times and node.name in node_times:
            tuned_t += node_times[node.name]
        else:
            compute_t += node.ops * batch / peak
    return compute_t / hw.util + tuned_t, n_compute_nodes


def _graph_cost(graph: Graph, hw: HardwareModel, backend: str, batch: int,
                quantized: Optional[Set[str]] = None,
                node_times: Optional[Dict[str, float]] = None,
                extra_bytes: float = 0.0,
                packed_bytes: Optional[Dict[str, int]] = None
                ) -> Tuple[float, float, float, bool, int]:
    """Shared roofline core for one dispatched batch.

    Returns ``(compute_t, memory_t, bytes_moved, resident, latency)``-style
    tuple: (compute_t, memory_t, bytes_moved, resident, n_compute_nodes) —
    callers combine the roofline terms with the hw overhead model.

    Weight residency mirrors the paper's BRAM policy: params that fit the
    on-chip budget are charged DDR traffic once (the first load, amortized
    away in steady-state serving); spilled params stream per inference
    (the BaselineNet effect in the paper's Table III). Parameter bytes use
    ACTUAL per-node widths (int8 weights + fp32 bias on quantized nodes).

    This is the pre-pass op-by-op bytes model: every value round-trips
    DDR — written once by its producer and read back by each consuming
    node (graph inputs are read too). Same units as the arena model in
    `plan_cost_signature` (which fused plans use instead), so the two are
    directly comparable: the fused delta is the traffic the arena keeps
    on-chip.
    """
    q = _quantized_set(graph, backend, quantized)
    param_bytes = weight_bytes(graph, backend, q, packed_bytes)
    resident = param_bytes <= hw.onchip_bytes

    compute_t, n_compute_nodes = _compute_cost(graph, hw, backend, batch,
                                               node_times)
    bytes_moved = float(extra_bytes)
    for name in graph.order:
        node = graph.nodes[name]
        if node.op in ("input", "const"):
            continue
        reads = sum(_act_bytes(graph, i) for i in node.inputs
                    if graph.nodes[i].op != "const")   # consts are plan
        w_bytes = 0 if resident else _node_weight_bytes(node, q,
                                                        packed_bytes)
        bytes_moved += (_act_bytes(graph, name) + reads + w_bytes) * batch
    memory_t = bytes_moved / hw.hbm_bw
    return compute_t, memory_t, bytes_moved, resident, n_compute_nodes


def _batch_latency(hw: HardwareModel, compute_t: float, memory_t: float,
                   batch: int, n_nodes: int) -> float:
    """Roofline max + overheads: staging (`overhead_s`) is paid once per
    dispatched batch; eager per-layer dispatch (`dispatch_s`) is paid per
    node per sample (the paper's per-sample CPU baseline loop)."""
    return (max(compute_t, memory_t) + hw.overhead_s
            + hw.dispatch_s * n_nodes * batch)


def model_graph(graph: Graph, hw: HardwareModel, backend: str = "flex",
                batch: int = 1) -> EnergyReport:
    """Analytic latency/energy for one inference (batch amortizes the
    per-dispatch staging overhead and, via residency, the weight loads)."""
    compute_t, memory_t, bytes_moved, resident, n_nodes = _graph_cost(
        graph, hw, backend, batch)
    latency = _batch_latency(hw, compute_t, memory_t, batch, n_nodes)
    bound = "compute" if compute_t >= memory_t else "memory"
    energy = hw.power_busy * latency + bytes_moved * hw.ddr_pj_per_byte
    return EnergyReport(
        hw=hw.name, backend=backend,
        latency_s=latency / batch,
        energy_j=energy / batch,
        fps=batch / latency,
        mops=graph.n_ops * batch / latency / 1e6,
        weights_resident=resident,
        bound=bound,
        bytes_moved=bytes_moved / batch,
    )


# ---------------------------------------------------------------------------
# Plan-time cost signatures (DESIGN.md §9)
# ---------------------------------------------------------------------------

# The deployment analog each engine backend prices at (the paper's ZCU104):
# cpu = the ARM A53 eager baseline, flex = the (naive) Vitis-HLS dataflow
# path, accel = the Vitis-AI DPU int8 path. Partial-offload flex tails of
# an accel plan are priced at the accel hw's fp32 rate — a documented
# simplification (the signature prices the backend's nominal hardware).
BACKEND_HW: Dict[str, HardwareModel] = {
    "cpu": ZCU104_CPU,
    "flex": ZCU104_HLS_NAIVE,
    "accel": ZCU104_DPU,
}


# ---------------------------------------------------------------------------
# Recovery pricing (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryCost:
    """Modeled cost of one fault-recovery action (an arena re-pack from
    host copies): what the fault controller advances the virtual clock by
    and charges to its energy ledger."""
    seconds: float
    energy_j: float


def repack_cost(hw: HardwareModel, packed_bytes: int) -> RecoveryCost:
    """Price restoring ``packed_bytes`` of prepacked weights from host
    copies: one dispatch-overhead setup plus the bytes over the staging
    channel (the same PS->DDR path batch staging uses; DDR bandwidth when
    the backend has no separate staging channel), busy power plus the
    per-byte DDR access energy."""
    bw = hw.stage_bw or hw.hbm_bw
    t = hw.overhead_s + packed_bytes / bw
    e = hw.power_busy * t + packed_bytes * hw.ddr_pj_per_byte
    return RecoveryCost(seconds=t, energy_j=e)


# ---------------------------------------------------------------------------
# Protection pricing: ECC scrub / TMR vote (DESIGN.md §16)
# ---------------------------------------------------------------------------

PROTECTION_MODES: Tuple[str, ...] = ("none", "ecc", "tmr")

# SEC-DED ECC on 64-bit words: 8 check bits per 64 data bits.
ECC_FOOTPRINT_OVERHEAD = 0.125
# On-the-fly syndrome decode in the weight-fetch path: a pipeline stage
# on every access, a small constant drag on the whole dispatch.
ECC_LATENCY_OVERHEAD = 0.02
# Spatial TMR: three live copies of the packed arena feeding a majority
# voter. Footprint and busy power triple; the voter adds latency.
TMR_COPIES = 3
TMR_VOTE_OVERHEAD = 0.06


@dataclasses.dataclass(frozen=True)
class ProtectionCost:
    """Modeled standing cost of one protection mode on one packed weight
    arena: the footprint inflation, the per-dispatch latency factor, and
    (for ECC/TMR) the periodic scrub pass that sweeps the protected
    bytes over the staging channel to catch error accumulation."""
    mode: str
    weight_bytes: int               # unprotected packed footprint
    protected_bytes: int            # footprint with check bits / copies
    latency_factor: float           # per-dispatch compute drag (>= 1)
    power_copies: int               # live compute instances (TMR = 3)
    scrub_period_s: float
    scrub_s: float                  # one scrub pass, modeled seconds
    scrub_energy_j: float           # one scrub pass, modeled joules

    @property
    def scrub_power_w(self) -> float:
        """Standing power of the periodic scrubber."""
        if self.scrub_period_s <= 0.0 or self.scrub_s <= 0.0:
            return 0.0
        return self.scrub_energy_j / self.scrub_period_s


def protection_cost(hw: HardwareModel, packed_bytes: int, mode: str,
                    scrub_period_s: float = 0.05) -> ProtectionCost:
    """Price ``mode`` protection for ``packed_bytes`` of packed weights.

    The scrub pass reads every protected byte back over the staging
    channel (the memory controller's scrubber shares the PS DMA path),
    at busy power plus per-byte DDR access energy — the same pricing
    basis as :func:`repack_cost`, minus the dispatch setup (scrubbing is
    a background burst, not a fresh dispatch)."""
    from repro.core.memory import protected_weight_bytes
    if mode not in PROTECTION_MODES:
        raise ValueError(f"unknown protection mode {mode!r}; expected one "
                         f"of {PROTECTION_MODES}")
    pb = protected_weight_bytes(packed_bytes, mode)
    if mode == "none" or packed_bytes == 0:
        return ProtectionCost(mode, packed_bytes, pb, 1.0, 1,
                              scrub_period_s, 0.0, 0.0)
    bw = hw.stage_bw or hw.hbm_bw
    scrub_s = pb / bw
    scrub_j = hw.power_busy * scrub_s + pb * hw.ddr_pj_per_byte
    if mode == "ecc":
        return ProtectionCost(mode, packed_bytes, pb,
                              1.0 + ECC_LATENCY_OVERHEAD, 1,
                              scrub_period_s, scrub_s, scrub_j)
    return ProtectionCost(mode, packed_bytes, pb,
                          1.0 + TMR_VOTE_OVERHEAD, TMR_COPIES,
                          scrub_period_s, scrub_s, scrub_j)


def protected_signature(sig: "CostSignature", hw: HardwareModel,
                        prot: ProtectionCost) -> "CostSignature":
    """Re-price a plan's cost signature under a protection mode: the
    dispatcher ranks THESE when protection is on, so the ECC decode
    drag, the TMR power tripling, and any residency flip from the
    inflated footprint all flow into (backend, rung) selection and the
    power envelope.

    Residency recheck: check bits / TMR copies count against the same
    BRAM budget as the data bits. A previously-resident arena whose
    protected footprint spills streams its protected bytes per sample —
    the §9 spill rule applied to the inflated footprint."""
    if prot.mode == "none":
        return sig
    latency = sig.latency_s * prot.latency_factor
    bytes_moved = sig.bytes_moved
    ddr_j = sig.ddr_energy_j
    resident = sig.weights_resident and prot.protected_bytes <= hw.onchip_bytes
    if sig.weights_resident and not resident:
        extra = float(prot.protected_bytes) * sig.batch
        bytes_moved += extra
        latency += extra / hw.hbm_bw
        ddr_j += extra * hw.ddr_pj_per_byte
    power = hw.power_busy * prot.power_copies
    energy = power * latency + ddr_j
    return dataclasses.replace(
        sig, latency_s=latency, bytes_moved=bytes_moved,
        ddr_energy_j=ddr_j, energy_j=energy,
        j_per_inference=energy / sig.batch, power_w=power,
        weights_resident=resident, protection=prot.mode)


@dataclasses.dataclass(frozen=True)
class CostSignature:
    """Plan-time cost of ONE dispatched batch of a compiled plan: what the
    dispatcher needs to rank (backend, rung) candidates and to charge the
    power envelope — no serving-time measurement involved.

    ``energy_j = power_w * latency_s + ddr_energy_j``: off-chip traffic
    costs joules even when the roofline is compute-bound, so a fused plan
    that keeps intermediates on-chip is measurably cheaper per inference
    than the op-by-op plan of the same graph."""
    backend: str
    batch: int
    hw: str
    flops: float                    # arithmetic ops, whole batch
    bytes_moved: float              # modeled DDR traffic, whole batch
    latency_s: float                # whole-batch modeled latency
    energy_j: float                 # whole-batch modeled energy
    j_per_inference: float
    power_w: float                  # busy power while the batch runs
    weights_resident: bool
    ddr_energy_j: float = 0.0       # the off-chip-access share of energy_j
    kv_resident_bytes: float = 0.0  # packed KV-cache arena footprint (LM
                                    # decode slots — charged like
                                    # prepacked weights, DESIGN.md §15)
    pipelined_latency_s: float = 0.0
    # ^ steady-state per-batch interval of the PIPELINED runtime: the
    # longest stage of the plan's stage decomposition (`stage_costs`) —
    # with staging, per-segment compute, and readback overlapped across
    # batches, a saturated stream completes one batch per longest stage.
    # 0.0 when the plan was priced without a stage decomposition;
    # latency_s (the serial whole-batch latency) is unchanged either way.
    protection: str = "none"        # arena protection mode priced into this
                                    # signature ('none' | 'ecc' | 'tmr' —
                                    # DESIGN.md §16); 'none' everywhere the
                                    # radiation layer is off

    def row(self) -> str:
        return (f"{self.backend:6s} b={self.batch:<3d} "
                f"lat={self.latency_s*1e3:9.4f} ms  "
                f"E/inf={self.j_per_inference*1e3:9.5f} mJ  "
                f"P={self.power_w:5.2f} W  "
                f"resident={self.weights_resident}")


def _make_signature(graph: Graph, backend: str, batch: int,
                    hw: HardwareModel, compute_t: float, memory_t: float,
                    bytes_moved: float, resident: bool,
                    n_nodes: int) -> CostSignature:
    latency = _batch_latency(hw, compute_t, memory_t, batch, n_nodes)
    ddr_j = bytes_moved * hw.ddr_pj_per_byte
    energy = hw.power_busy * latency + ddr_j
    return CostSignature(
        backend=backend, batch=batch, hw=hw.name,
        flops=float(graph.n_ops) * batch, bytes_moved=bytes_moved,
        latency_s=latency, energy_j=energy,
        j_per_inference=energy / batch, power_w=hw.power_busy,
        weights_resident=resident, ddr_energy_j=ddr_j)


def cost_signature(graph: Graph, backend: str, batch: int,
                   hw: Optional[HardwareModel] = None,
                   quantized: Optional[Set[str]] = None,
                   node_times: Optional[Dict[str, float]] = None,
                   extra_bytes: float = 0.0,
                   packed_bytes: Optional[Dict[str, int]] = None
                   ) -> CostSignature:
    """The modeled cost of one ``batch``-sized dispatch of ``graph`` on
    ``backend`` (hardware from BACKEND_HW unless overridden), under the
    pre-pass op-by-op bytes model: every activation round-trips DDR.

    ``node_times``/``extra_bytes``/``packed_bytes`` are the autotuner's
    kernel-level refinements (per-node tuned kernel times, weight
    restream traffic, prepacked footprints — DESIGN.md §11); absent, the
    signature is byte-for-byte the pre-autotune model."""
    if hw is None:
        hw = BACKEND_HW[backend]
    compute_t, memory_t, bytes_moved, resident, n_nodes = _graph_cost(
        graph, hw, backend, batch, quantized, node_times, extra_bytes,
        packed_bytes)
    return _make_signature(graph, backend, batch, hw, compute_t, memory_t,
                           bytes_moved, resident, n_nodes)


def plan_cost_signature(graph: Graph, backend: str, batch: int, arena,
                        hw: Optional[HardwareModel] = None,
                        quantized: Optional[Set[str]] = None,
                        node_times: Optional[Dict[str, float]] = None,
                        extra_bytes: float = 0.0,
                        packed_bytes: Optional[Dict[str, int]] = None
                        ) -> CostSignature:
    """The modeled cost of a FUSED plan's dispatch: DDR bytes come from
    the static arena plan (`core/memory.py`) — graph inputs/outputs,
    arena spills, and segment-boundary round-trips only; BRAM-resident
    intermediates are free. Spilled weights still stream per inference.
    Compute time is shared with `_graph_cost` (fusion moves bytes, not
    FLOPs), so the energy delta vs `cost_signature` is the off-chip
    traffic the fusion+arena pipeline keeps on-chip.
    ``node_times``/``extra_bytes``/``packed_bytes`` carry the
    autotuner's kernel-level refinements (see `cost_signature`)."""
    if hw is None:
        hw = BACKEND_HW[backend]
    w_bytes = weight_bytes(graph, backend, quantized, packed_bytes)
    resident = w_bytes <= hw.onchip_bytes
    compute_t, n_nodes = _compute_cost(graph, hw, backend, batch,
                                       node_times)
    bytes_moved = (float(arena.ddr_bytes_per_sample) * batch
                   + float(extra_bytes))
    if not resident:
        bytes_moved += w_bytes * batch
    memory_t = bytes_moved / hw.hbm_bw
    return _make_signature(graph, backend, batch, hw, compute_t, memory_t,
                           bytes_moved, resident, n_nodes)


# ---------------------------------------------------------------------------
# Pipelined stage decomposition + overlap ledger (DESIGN.md §12)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StageCost:
    """One pipeline stage of one dispatched batch: host staging, one plan
    segment's compute, or host readback. ``resource`` names the hardware
    unit the stage occupies — stages of DIFFERENT batches overlap iff
    their resources differ. Staging and readback get SEPARATE host
    resources ('host_in' / 'host_out'): the PS-side AXI DMA channels are
    full-duplex, so batch k+1's input assembly overlaps batch k's output
    drain (the whole point of double buffering)."""
    name: str                       # 'stage_in' | 'seg<i>/<backend>' | 'readback'
    resource: str                   # 'host_in' | 'host_out' | 'accel' | 'flex' | 'cpu'
    seconds: float


def stage_costs(graph: Graph, backend: str, batch: int, segments: Sequence,
                arena=None,
                hw: Optional[HardwareModel] = None,
                quantized: Optional[Set[str]] = None,
                node_times: Optional[Dict[str, float]] = None,
                packed_bytes: Optional[Dict[str, int]] = None
                ) -> Tuple[StageCost, ...]:
    """Decompose one ``batch``-sized dispatch into its pipeline stages:

    * ``stage_in`` on the ``host_in`` resource — the per-dispatch setup
      (``overhead_s``) plus the graph inputs streamed at the PS staging
      bandwidth (``stage_bw``; the paper's Fig 11 load_ip_input phase),
    * one stage per plan *segment* on that segment's backend resource —
      per-node compute time (tuned kernel times when available, else the
      roofline term, exactly `_compute_cost`'s per-node pricing) maxed
      against the segment's share of the plan's DDR traffic,
    * ``readback`` on ``host_out`` — graph outputs back at ``stage_bw``
      (a separate resource from ``host_in``: the DMA path is full-duplex,
      so one batch's drain overlaps the next batch's input assembly).

    This is a REFINEMENT of the serial signature, not a replacement: the
    serial ``latency_s`` (one global roofline max + overhead) is what the
    synchronous runtime and the envelope charge; the stage decomposition
    is what the pipelined runtime overlaps. Both are priced from the same
    node times and the same bytes model (arena when fused, op-by-op
    otherwise), so sum(stages) tracks the serial latency and
    max(stages) is the steady-state pipelined batch interval.
    """
    from repro.core.opgraph import consumers as _consumers

    if hw is None:
        hw = BACKEND_HW[backend]
    q = _quantized_set(graph, backend, quantized)
    w_bytes = weight_bytes(graph, backend, q, packed_bytes)
    resident = w_bytes <= hw.onchip_bytes
    peak = _peak(hw, backend)

    seg_of: Dict[str, int] = {}
    for si, seg in enumerate(segments):
        for n in seg.nodes:
            seg_of[n] = si
    seg_bytes = [0.0] * max(len(segments), 1)
    if arena is not None:
        cons = _consumers(graph)
        for b in arena.buffers.values():
            si = seg_of.get(b.name)
            if b.tier != "ddr" or si is None:
                continue
            # written once; read back only if somebody reads it (the
            # arena's own spill/boundary traffic rule)
            seg_bytes[si] += b.nbytes * (2 if cons.get(b.name) else 1)
    else:
        # op-by-op bytes model: every value round-trips DDR
        for name in graph.order:
            node = graph.nodes[name]
            si = seg_of.get(name)
            if node.op in ("input", "const") or si is None:
                continue
            reads = sum(_act_bytes(graph, i) for i in node.inputs
                        if graph.nodes[i].op != "const")
            seg_bytes[si] += _act_bytes(graph, name) + reads
    if not resident:                    # spilled weights stream per inference
        for name, si in seg_of.items():
            seg_bytes[si] += _node_weight_bytes(graph.nodes[name], q,
                                                packed_bytes)

    in_bytes = sum(_act_bytes(graph, n) for n in graph.graph_inputs) * batch
    out_bytes = sum(_act_bytes(graph, o) for o in set(graph.outputs)) * batch
    stages = [StageCost(
        "stage_in", "host_in",
        hw.overhead_s + (in_bytes / hw.stage_bw if hw.stage_bw else 0.0))]
    for si, seg in enumerate(segments):
        c = 0.0
        for n in seg.nodes:
            node = graph.nodes[n]
            if node_times and n in node_times:
                c += node_times[n]      # tuned time includes util already
            else:
                c += node.ops * batch / peak / hw.util
            c += hw.dispatch_s * batch
        m = seg_bytes[si] * batch / hw.hbm_bw
        stages.append(StageCost(f"seg{si}/{seg.backend}", seg.backend,
                                max(c, m)))
    stages.append(StageCost(
        "readback", "host_out",
        out_bytes / hw.stage_bw if hw.stage_bw else 0.0))
    return tuple(stages)


def steady_state_overlap(stages: Sequence[StageCost]) -> float:
    """Asymptotic throughput gain of pipelining this stage chain over a
    saturated stream: serial per-batch time / longest stage (one batch
    completes per longest stage once the pipeline fills)."""
    total = sum(s.seconds for s in stages)
    longest = max((s.seconds for s in stages), default=0.0)
    return total / longest if longest > 0 else 1.0


@dataclasses.dataclass(frozen=True)
class StageInterval:
    """One placed stage occupancy on the timeline."""
    dispatch: int                   # dispatch ordinal on this timeline
    stage: str
    resource: str
    start: float
    end: float


class PipelineTimeline:
    """Deterministic per-resource occupancy ledger of the pipelined
    runtime — the modeled clock's overlap accounting.

    ``add()`` places one dispatch's stage chain in dispatch order: each
    stage starts at max(its predecessor's finish, its resource's free
    time, the dispatch's ``earliest`` start — the batch's data-arrival
    time). The same chain is also appended to a single virtual *serial*
    resource: the synchronous baseline every overlap speedup is measured
    against. Pure arithmetic over modeled stage seconds and trace
    arrival times — machine-independent under ``clock="modeled"``.
    """

    def __init__(self) -> None:
        self._free: Dict[str, float] = {}       # resource -> busy-until
        self._serial_free: Optional[float] = None
        self.intervals: List[StageInterval] = []
        self.n_dispatches = 0
        self._start: Optional[float] = None
        self._end = 0.0
        self._serial_start: Optional[float] = None
        self._serial_end = 0.0

    def add(self, stages: Sequence[StageCost], earliest: float = 0.0
            ) -> Tuple[float, float]:
        """Place one dispatch; returns its (start, finish) on the
        pipelined timeline."""
        t = float(earliest)
        first: Optional[float] = None
        for st in stages:
            s = max(t, self._free.get(st.resource, t))
            e = s + st.seconds
            self._free[st.resource] = e
            self.intervals.append(StageInterval(
                self.n_dispatches, st.name, st.resource, s, e))
            if first is None:
                first = s
            t = e
        total = sum(st.seconds for st in stages)
        s0 = float(earliest) if self._serial_free is None \
            else max(float(earliest), self._serial_free)
        self._serial_free = s0 + total
        self._serial_start = s0 if self._serial_start is None \
            else min(self._serial_start, s0)
        self._serial_end = max(self._serial_end, self._serial_free)
        if first is not None:
            self._start = first if self._start is None \
                else min(self._start, first)
            self._end = max(self._end, t)
        self.n_dispatches += 1
        return (first if first is not None else float(earliest)), t

    @property
    def span_s(self) -> float:
        """Pipelined makespan (first stage start to last stage end)."""
        return self._end - self._start if self._start is not None else 0.0

    @property
    def serial_span_s(self) -> float:
        """Makespan of the same dispatches chained on one resource."""
        return (self._serial_end - self._serial_start
                if self._serial_start is not None else 0.0)

    @property
    def speedup_x(self) -> float:
        """Effective-throughput gain of overlap: serial / pipelined
        makespan. >= 1 by construction (a stage never starts later on
        the pipelined timeline than on the serial chain); the clamp only
        guards float-summation jitter when nothing ever overlapped."""
        if self.span_s <= 0:
            return 1.0
        return max(1.0, self.serial_span_s / self.span_s)

    def busy_s(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for iv in self.intervals:
            out[iv.resource] = out.get(iv.resource, 0.0) + (iv.end - iv.start)
        return out

    def report(self) -> Dict:
        busy = self.busy_s()
        span = self.span_s
        return {
            "n_dispatches": self.n_dispatches,
            "pipelined_span_s": span,
            "serial_span_s": self.serial_span_s,
            "overlap_speedup_x": self.speedup_x,
            "busy_s": busy,
            "occupancy": {r: (b / span if span > 0 else 0.0)
                          for r, b in busy.items()},
        }


# ---------------------------------------------------------------------------
# Orbital power envelope (DESIGN.md §9)
# ---------------------------------------------------------------------------

_EPS_T = 1e-9
_EPS_J = 1e-9


@dataclasses.dataclass(frozen=True)
class Draw:
    """One recorded power draw: a dispatched batch modeled as ``watts``
    drawn over ``[start, end]`` (plan-time cost signature terms)."""
    start: float
    end: float
    watts: float
    tag: str = ""

    @property
    def energy_j(self) -> float:
        return self.watts * (self.end - self.start)


class PowerEnvelope:
    """Mission power budget the dispatcher schedules against.

    Two constraints, checked at admission time so they hold by
    construction over the whole run:

    * **sustained**: the energy drawn in ANY trailing window of
      ``window_s`` seconds never exceeds the energy the power system
      supplied over that window — the integral of the (possibly stepped)
      ``sustained_w`` budget across it — plus the ``burst_j``
      battery/capacitor margin. Integrating the budget (rather than
      point-sampling it at the window end) makes phase transitions
      physical: a window straddling eclipse entry still credits the
      sunlight seconds it contains. Spreading a draw's energy over the
      window is what duty-cycles a high-power backend (the DPU at 6.75 W
      under a 3 W envelope runs at most ~44% duty).
    * **peak**: total instantaneous power of overlapping draws never
      exceeds ``peak_w(t)`` (None = uncapped). This is what excludes a
      backend outright during eclipse and forces the cpu/flex fallback.

    The budget is a step schedule over time (``set_budget``): orbital
    phases (sunlight / penumbra / eclipse) are known in advance, so
    admission sees future steps too — a draw whose trailing window would
    cross into a tighter phase is refused *before* the phase starts,
    exactly the pre-eclipse power-down a real operations plan requires.

    ``admit`` is check+record; ``next_admit`` answers "when could this
    draw fit" so a virtual-clock scheduler can advance time instead of
    spinning. ``audit`` re-derives the invariant over the recorded ledger
    (the machine-independent CI gate: zero violations, always).
    """

    def __init__(self, sustained_w: float = math.inf,
                 peak_w: Optional[float] = None,
                 burst_j: float = 0.0, window_s: float = 10.0):
        if window_s <= 0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        self.window_s = float(window_s)
        self.burst_j = float(burst_j)
        # budget step schedule: (t, sustained_w, peak_w), t ascending
        self._schedule: List[Tuple[float, float, float]] = [
            (-math.inf, float(sustained_w),
             math.inf if peak_w is None else float(peak_w))]
        self.draws: List[Draw] = []

    # -- budget schedule ----------------------------------------------------

    def set_budget(self, t: float, sustained_w: Optional[float] = None,
                   peak_w: Optional[float] = None) -> None:
        """Step the budget at time ``t`` (>= the last scheduled step).
        Omitted fields carry over. Pre-schedule orbit phases before
        serving; admission accounts for future steps."""
        last_t, last_s, last_p = self._schedule[-1]
        if t < last_t:
            raise ValueError(f"budget step at {t} precedes last step "
                             f"at {last_t}")
        self._schedule.append((
            float(t),
            last_s if sustained_w is None else float(sustained_w),
            last_p if peak_w is None else float(peak_w)))

    def budget_at(self, t: float) -> Tuple[float, float]:
        """(sustained_w, peak_w) in effect at time ``t``."""
        idx = bisect.bisect_right([s[0] for s in self._schedule], t) - 1
        _, sus, peak = self._schedule[max(idx, 0)]
        return sus, peak

    # -- ledger accounting ---------------------------------------------------

    def power_at(self, t: float, extra: Optional[Draw] = None) -> float:
        p = sum(d.watts for d in self.draws if d.start <= t < d.end)
        if extra is not None and extra.start <= t < extra.end:
            p += extra.watts
        return p

    def window_energy(self, tau: float, extra: Optional[Draw] = None
                      ) -> float:
        """Energy drawn in the trailing window ``[tau - window_s, tau]``."""
        lo = tau - self.window_s
        e = 0.0
        for d in self.draws + ([extra] if extra is not None else []):
            ov = min(d.end, tau) - max(d.start, lo)
            if ov > 0:
                e += d.watts * ov
        return e

    def budget_energy(self, lo: float, hi: float) -> float:
        """Energy the power system supplies over ``[lo, hi]`` — the
        sustained-budget step schedule integrated across the interval."""
        e = 0.0
        steps = self._schedule
        for i, (t0, sus, _) in enumerate(steps):
            t1 = steps[i + 1][0] if i + 1 < len(steps) else math.inf
            ov_lo, ov_hi = max(t0, lo), min(t1, hi)
            if ov_hi > ov_lo:
                if math.isinf(sus):
                    return math.inf
                e += sus * (ov_hi - ov_lo)
        return e

    def _window_ok(self, tau: float, extra: Optional[Draw]) -> bool:
        supplied = self.budget_energy(tau - self.window_s, tau)
        return (self.window_energy(tau, extra)
                <= supplied + self.burst_j + _EPS_J)

    def _peak_ok(self, t: float, extra: Optional[Draw]) -> bool:
        _, peak = self.budget_at(t)
        return self.power_at(t, extra) <= peak + _EPS_J

    def _step_times(self, lo: float, hi: float) -> List[float]:
        return [s[0] for s in self._schedule if lo < s[0] <= hi]

    def admissible(self, t: float, watts: float, duration: float) -> bool:
        """Would a draw of ``watts`` over ``[t, t + duration]`` keep both
        constraints? Checked at the finitely many candidate times where a
        violation can first appear: power steps up only at draw starts and
        budget steps; trailing-window energy peaks only where power drops
        (draw ends), where a start slides out of the window (start +
        window), or where the budget steps down."""
        d = Draw(t, t + duration, watts)
        end = d.end
        # instantaneous peak: at t, at later overlapping draw starts, and
        # at budget steps inside the draw
        peaks = [t] + [x.start for x in self.draws if t < x.start < end]
        peaks += self._step_times(t, end - _EPS_T)
        if not all(self._peak_ok(p, d) for p in peaks):
            return False
        # trailing-window energy: candidate maxima while this draw can
        # still be inside a window
        horizon = max([end] + [x.end for x in self.draws]) + self.window_s
        taus = {end, t + self.window_s, end + self.window_s}
        taus.update(x.end for x in self.draws if x.end > t)
        taus.update(x.start + self.window_s for x in self.draws
                    if x.start + self.window_s > t)
        steps = self._step_times(t - self.window_s, horizon)
        taus.update(s for s in steps if s > t)
        taus.update(s + self.window_s for s in steps
                    if s + self.window_s > t)
        return all(self._window_ok(tau, d) for tau in taus if tau <= horizon)

    def admit(self, t: float, watts: float, duration: float,
              tag: str = "") -> Optional[Draw]:
        """Record the draw if admissible; returns it (for rollback via
        :meth:`remove`) or None if refused."""
        if not self.admissible(t, watts, duration):
            return None
        d = Draw(t, t + duration, watts, tag)
        bisect.insort(self.draws, d, key=lambda x: x.start)
        return d

    def remove(self, draw: Draw) -> None:
        """Roll back a recorded draw (dispatch failed; batch re-queued)."""
        self.draws.remove(draw)

    def feasible_ever(self, watts: float, duration: float) -> bool:
        """Could a bare draw (empty window) EVER fit some budget regime?
        The register-time sanity gate: a model none of whose backends
        passes this can never be dispatched under the envelope."""
        for _, sus, peak in self._schedule:
            if (watts <= peak + _EPS_J
                    and watts * min(duration, self.window_s)
                    <= sus * self.window_s + self.burst_j + _EPS_J):
                return True
        return False

    def next_admit(self, t: float, watts: float, duration: float
                   ) -> Optional[float]:
        """Earliest time >= ``t`` at which the draw becomes admissible, or
        None if it never does (even against the final budget with an
        otherwise-empty window). Between envelope events feasibility is
        monotone (old draws only age out, overlaps only end), so a
        coarse event scan + bisection is exact."""
        if self.admissible(t, watts, duration):
            return t
        last_step = max((s[0] for s in self._schedule
                         if s[0] > -math.inf), default=t)
        horizon = (max([t, last_step] + [d.end for d in self.draws])
                   + self.window_s + duration)
        steps = self._step_times(t - self.window_s, horizon)
        events = sorted(
            {e for d in self.draws
             for e in (d.end, d.end + self.window_s,
                       d.start + self.window_s) if e > t}
            | {s for s in steps if s > t}
            | {s + self.window_s for s in steps if s + self.window_s > t}
            | {horizon})
        prev = t
        for c in events:
            if self.admissible(c, watts, duration):
                lo, hi = prev, c
                for _ in range(60):             # bisect the flip point
                    mid = 0.5 * (lo + hi)
                    if self.admissible(mid, watts, duration):
                        hi = mid
                    else:
                        lo = mid
                return max(hi, t + _EPS_T)
            prev = c
        return None

    # -- reporting -----------------------------------------------------------

    @property
    def total_j(self) -> float:
        return sum(d.energy_j for d in self.draws)

    def busy_s(self) -> float:
        """Total time with at least one draw active (interval union)."""
        busy, cur_s, cur_e = 0.0, None, None
        for d in sorted(self.draws, key=lambda x: x.start):
            if cur_e is None or d.start > cur_e:
                if cur_e is not None:
                    busy += cur_e - cur_s
                cur_s, cur_e = d.start, d.end
            else:
                cur_e = max(cur_e, d.end)
        if cur_e is not None:
            busy += cur_e - cur_s
        return busy

    def audit(self) -> Dict:
        """Re-derive both invariants over the whole recorded ledger.
        ``n_violations`` must be 0 on every host: admission enforced the
        same predicate, so this is the machine-independent CI gate."""
        step_ts = [s[0] for s in self._schedule if s[0] > -math.inf]
        taus = sorted(
            {d.end for d in self.draws}
            | {d.start + self.window_s for d in self.draws}
            | set(step_ts) | {s + self.window_s for s in step_ts})
        n_viol = 0
        max_window_w = 0.0
        for tau in taus:
            e = self.window_energy(tau)
            supplied = self.budget_energy(tau - self.window_s, tau)
            max_window_w = max(max_window_w, e / self.window_s)
            if e > supplied + self.burst_j + 1e-6:
                n_viol += 1
        peak_seen = 0.0
        for d in self.draws:
            p = self.power_at(d.start)
            peak_seen = max(peak_seen, p)
            _, peak = self.budget_at(d.start)
            if p > peak + 1e-6:
                n_viol += 1
        span = (max(d.end for d in self.draws)
                - min(d.start for d in self.draws)) if self.draws else 0.0
        return {
            "n_draws": len(self.draws),
            "n_violations": n_viol,
            "total_j": self.total_j,
            "busy_s": self.busy_s(),
            "span_s": span,
            "duty_cycle": self.busy_s() / span if span > 0 else 0.0,
            "max_window_w": max_window_w,
            "peak_w_seen": peak_seen,
            "window_s": self.window_s,
            "burst_j": self.burst_j,
        }


# ---------------------------------------------------------------------------
# Measured-host accounting (relative Table III reproduction)
# ---------------------------------------------------------------------------

HOST_POWER_BUSY = 65.0     # nominal W for this host CPU — only ratios used


def measured_report(name: str, backend: str, latency_s: float,
                    n_ops: int) -> EnergyReport:
    return EnergyReport(
        hw="host", backend=backend,
        latency_s=latency_s,
        energy_j=HOST_POWER_BUSY * latency_s,
        fps=1.0 / latency_s if latency_s > 0 else float("inf"),
        mops=n_ops / latency_s / 1e6 if latency_s > 0 else float("inf"),
        weights_resident=True,
        bound="measured",
    )


def power_trace(graph: Graph, hw: HardwareModel, backend: str,
                n_inferences: int = 1000, dt: float = 1e-3):
    """Modeled power-over-time for the serving phases (paper Figs 9-13):
    idle -> configure (bitstream analog: program load spike) -> staging ->
    inference -> idle. Returns (times, watts)."""
    import numpy as np
    rep = model_graph(graph, hw, backend)
    t_cfg = 0.5                        # program/bitstream load
    t_stage = 0.2
    t_inf = rep.latency_s * n_inferences
    seq = [
        (0.5, hw.power_idle),
        (t_cfg, hw.power_busy * 1.15),          # config spike (paper Fig 13)
        (t_stage, hw.power_idle + 0.3 * (hw.power_busy - hw.power_idle)),
        (t_inf, hw.power_busy),
        (0.5, hw.power_idle),
    ]
    times, watts = [], []
    t = 0.0
    for dur, p in seq:
        n = max(int(dur / dt), 1)
        for i in range(n):
            times.append(t)
            watts.append(p)
            t += dt
    return np.asarray(times), np.asarray(watts)
