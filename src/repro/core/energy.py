"""Energy / power / throughput model — the paper's E = P x t, on TPU terms.

The paper measures the ZCU104's 12 V rail (board) and INT rail (MPSoC) and
reports per-inference energy. This container has no power rails, so we do
both of what's honest:

* **measured-host** numbers: wall-clock latency of the cpu/flex/accel
  backends on THIS host. Speedups and *relative* energy ratios reproduce
  the paper's Table III structure (CPU 1x baseline).
* **modeled-TPU** numbers: an analytic roofline-style model with public
  TPU v5e constants. Per op: t = max(FLOPs/peak, bytes/HBM_bw);
  E = P_busy * t + leakage share. Weight residency mirrors the paper's
  BRAM policy — params that fit the VMEM budget are charged HBM traffic
  once (first load), spilled params are charged per inference
  (the BaselineNet effect in the paper's Table III).

Both are reported side by side in benchmarks/table3_performance.py and are
never conflated.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.opgraph import Graph

# ---------------------------------------------------------------------------
# Hardware models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    name: str
    peak_flops_f32: float
    peak_flops_bf16: float
    peak_ops_int8: float
    hbm_bw: float                  # bytes/s
    onchip_bytes: float            # VMEM budget for weight residency
    power_busy: float              # W during compute
    power_idle: float              # W static
    ici_bw: float = 0.0            # per-link bytes/s
    util: float = 1.0              # achievable fraction of peak compute
    overhead_s: float = 0.0        # fixed per-inference overhead (staging)


# Public TPU v5e figures: 197 TFLOP/s bf16 / 394 TOP/s int8, 819 GB/s HBM,
# ~50 GB/s/link ICI (assignment constants). fp32 on the MXU runs at ~1/4
# bf16 rate. VMEM ~64 MiB; chip power ~170 W busy / ~60 W idle (board-level
# figures from public v5e efficiency reports; used consistently, only
# ratios matter for the Table III reproduction).
TPU_V5E = HardwareModel(
    name="tpu_v5e",
    peak_flops_f32=197e12 / 4,
    peak_flops_bf16=197e12,
    peak_ops_int8=394e12,
    hbm_bw=819e9,
    onchip_bytes=64 * 2**20,
    power_busy=170.0,
    power_idle=60.0,
    ici_bw=50e9,
)

# The paper's ZCU104 (for cross-checking our model against their CPU/DPU
# measurements): A53 CPU ~ 6 GFLOP/s fp32; DPU B4096 @300 MHz = 1.2 TOP/s
# int8; DDR4 ~19.2 GB/s; BRAM+URAM ~ 4.75 MB; PS ~2-2.75 W, DPU adds ~4 W.
ZCU104_CPU = HardwareModel(
    name="zcu104_arm_a53",
    peak_flops_f32=6e9, peak_flops_bf16=6e9, peak_ops_int8=12e9,
    hbm_bw=19.2e9, onchip_bytes=1 * 2**20,
    power_busy=2.75, power_idle=2.0)
ZCU104_DPU = HardwareModel(
    name="zcu104_dpu_b4096",
    peak_flops_f32=0.1e12, peak_flops_bf16=0.1e12, peak_ops_int8=1.2e12,
    hbm_bw=19.2e9, onchip_bytes=4.75 * 2**20,
    power_busy=6.75, power_idle=5.0,
    # Paper Table III implies the DPU sustains 4-13% of its 1.2 TOP/s peak
    # on these small CNNs (50.6 / 150.1 GOP/s measured); 0.125 calibrated
    # to CNetPlusScalar, the DPU-friendliest workload.
    util=0.125, overhead_s=2e-4)

# The paper's *naive* HLS designs (no perf pragmas): each layer maps to a
# sequential 100 MHz dataflow stage; Table III's HLS rows imply ~15-25
# effective MOP/s plus ~27 us of AXI staging per inference. This model
# reproduces all four HLS rows within ~35% (see table3 cross-check).
ZCU104_HLS_NAIVE = HardwareModel(
    name="zcu104_hls_naive",
    peak_flops_f32=20e6, peak_flops_bf16=20e6, peak_ops_int8=20e6,
    hbm_bw=19.2e9, onchip_bytes=4.75 * 2**20,
    power_busy=1.75, power_idle=1.5,
    util=1.0, overhead_s=27e-6)


# ---------------------------------------------------------------------------
# Per-graph energy model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EnergyReport:
    hw: str
    backend: str
    latency_s: float
    energy_j: float
    fps: float
    mops: float                     # throughput in MOP/s (paper's metric)
    weights_resident: bool
    bound: str                      # 'compute' | 'memory'

    def row(self) -> str:
        return (f"{self.hw:14s} {self.backend:6s} "
                f"lat={self.latency_s*1e3:8.3f} ms  fps={self.fps:10.1f}  "
                f"thr={self.mops:12.1f} MOP/s  E={self.energy_j*1e3:9.4f} mJ  "
                f"bound={self.bound}")


def _dtype_bytes(backend: str) -> int:
    return 1 if backend == "accel" else 4


def _peak(hw: HardwareModel, backend: str) -> float:
    if backend == "accel":
        return hw.peak_ops_int8
    return hw.peak_flops_f32


def model_graph(graph: Graph, hw: HardwareModel, backend: str = "flex",
                batch: int = 1) -> EnergyReport:
    """Analytic latency/energy for one inference (batch amortizes weights)."""
    db = _dtype_bytes(backend)
    param_bytes = graph.n_params * db
    resident = param_bytes <= hw.onchip_bytes

    compute_t = 0.0
    memory_t = 0.0
    peak = _peak(hw, backend)
    for node in graph.nodes.values():
        if node.op == "input":
            continue
        compute_t += node.ops * batch / peak
        act_bytes = 1
        if node.out_shape:
            n = 1
            for d in node.out_shape:
                n *= d
            act_bytes = n * 4  # activations stay fp32 on the wire
        w_bytes = 0 if resident else node.param_count * db
        memory_t += (act_bytes * batch + w_bytes * batch) / hw.hbm_bw
    # non-resident weights stream once per inference; resident ones are
    # loaded once and amortized away (steady-state serving)
    compute_t /= hw.util
    latency = max(compute_t, memory_t) + hw.overhead_s * batch
    bound = "compute" if compute_t >= memory_t else "memory"
    energy = hw.power_busy * latency
    return EnergyReport(
        hw=hw.name, backend=backend,
        latency_s=latency / batch,
        energy_j=energy / batch,
        fps=batch / latency,
        mops=graph.n_ops * batch / latency / 1e6,
        weights_resident=resident,
        bound=bound,
    )


# ---------------------------------------------------------------------------
# Measured-host accounting (relative Table III reproduction)
# ---------------------------------------------------------------------------

HOST_POWER_BUSY = 65.0     # nominal W for this host CPU — only ratios used


def measured_report(name: str, backend: str, latency_s: float,
                    n_ops: int) -> EnergyReport:
    return EnergyReport(
        hw="host", backend=backend,
        latency_s=latency_s,
        energy_j=HOST_POWER_BUSY * latency_s,
        fps=1.0 / latency_s if latency_s > 0 else float("inf"),
        mops=n_ops / latency_s / 1e6 if latency_s > 0 else float("inf"),
        weights_resident=True,
        bound="measured",
    )


def power_trace(graph: Graph, hw: HardwareModel, backend: str,
                n_inferences: int = 1000, dt: float = 1e-3):
    """Modeled power-over-time for the serving phases (paper Figs 9-13):
    idle -> configure (bitstream analog: program load spike) -> staging ->
    inference -> idle. Returns (times, watts)."""
    import numpy as np
    rep = model_graph(graph, hw, backend)
    t_cfg = 0.5                        # program/bitstream load
    t_stage = 0.2
    t_inf = rep.latency_s * n_inferences
    seq = [
        (0.5, hw.power_idle),
        (t_cfg, hw.power_busy * 1.15),          # config spike (paper Fig 13)
        (t_stage, hw.power_idle + 0.3 * (hw.power_busy - hw.power_idle)),
        (t_inf, hw.power_busy),
        (0.5, hw.power_idle),
    ]
    times, watts = [], []
    t = 0.0
    for dur, p in seq:
        n = max(int(dur / dt), 1)
        for i in range(n):
            times.append(t)
            watts.append(p)
            t += dt
    return np.asarray(times), np.asarray(watts)
