"""PTQ for the LM serving path — the paper's INT8 lever, beyond-paper.

The paper quantizes CNN weights to INT8 for DPU residency; the LM-decode
analog quantizes (a) the model weights (w8a16: int8 storage, bf16 math —
halves the dominant weight-read traffic of the memory-bound decode step)
and (b) the KV cache (int8 + per-token-head scales — halves the other
half). §Perf iterations B1/B2 measure both on yi-34b decode_32k.

Weights use per-tensor symmetric scales (scalar — serving-grade PTQ;
per-channel is core/quantize.py's job for the space CNNs). The pytree
mirrors the bf16 param tree, so the same logical-axis sharding rules apply
leaf-for-leaf.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

# quantize leaves with at least this many elements (skip norms, biases)
MIN_QUANT_SIZE = 65_536


def _is_leaf_struct(x) -> bool:
    return hasattr(x, "shape") and hasattr(x, "dtype")


def should_quantize(leaf) -> bool:
    return (len(leaf.shape) >= 2 and
            math.prod(leaf.shape) >= MIN_QUANT_SIZE and
            leaf.dtype in (jnp.bfloat16, jnp.float32))


class QTensor(Dict):
    """{'q': int8 array, 's': f32 scalar scale} — a dict so pytree-native."""


def quantize_params(params) -> Any:
    """bf16 param tree -> tree with big leaves replaced by {'q','s'}."""
    def one(leaf):
        if not should_quantize(leaf):
            return leaf
        xf = leaf.astype(jnp.float32)
        s = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(xf / s), -127, 127).astype(jnp.int8)
        return {"q": q, "s": s}
    return jax.tree.map(one, params)


def abstract_quantized(params_abs) -> Any:
    def one(leaf):
        if not should_quantize(leaf):
            return leaf
        return {"q": jax.ShapeDtypeStruct(leaf.shape, jnp.int8),
                "s": jax.ShapeDtypeStruct((), jnp.float32)}
    return jax.tree.map(one, params_abs, is_leaf=_is_leaf_struct)


def quantized_axes(params_abs, p_axes) -> Any:
    """Logical axes for the quantized tree (q inherits, s is replicated)."""
    from repro.parallel.sharding import is_logical_leaf

    def one(axes, leaf):
        if not should_quantize(leaf):
            return axes
        return {"q": axes, "s": ()}
    return jax.tree.map(one, p_axes, params_abs, is_leaf=is_logical_leaf)


def dequantize_params(qparams, dtype=jnp.bfloat16) -> Any:
    """Reconstruct the model-dtype tree (XLA fuses the convert into the
    consuming dot on TPU; HBM reads stay 1 B/element)."""
    def is_qt(x):
        return isinstance(x, dict) and set(x.keys()) == {"q", "s"}

    def one(x):
        if is_qt(x):
            return (x["q"].astype(jnp.float32) * x["s"]).astype(dtype)
        return x
    return jax.tree.map(one, qparams, is_leaf=is_qt)


# ---------------------------------------------------------------------------
# INT8 KV cache (B2): cache int8 codes + per-(batch, pos, head) scales
# ---------------------------------------------------------------------------


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, H, hd] -> (int8 codes, f32 scales [B, S, H]).

    All-zero tiles get scale 1.0, not an epsilon: a tiny epsilon scale
    survives in f32 but underflows to exactly 0.0 when the scale plane is
    stored at reduced precision (the KV arena keeps scales in f16), and a
    zero scale turns every later inverse-scale/requant into inf/NaN. A
    zero tile round-trips exactly under any positive scale, so 1.0 is
    both safe and lossless.
    """
    xf = x.astype(jnp.float32)
    m = jnp.max(jnp.abs(xf), axis=-1)
    s = jnp.where(m > 0, m / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / s[..., None]), -127, 127).astype(jnp.int8)
    return q, s


def dequantize_kv(q: jax.Array, s: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * s[..., None]).astype(dtype)
