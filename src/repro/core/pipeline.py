"""Batched on-board serving pipeline.

The paper's PYNQ flow is load_ip_input() -> start_ip() -> read_ip_output(),
with Fig 11 showing input staging *dominating* inference for small models.
This pipeline reproduces that phase structure and fixes it the way a real
deployment would: a pool of reusable host staging buffers (batch k+1 is
assembled while batch k computes), non-blocking dispatch tickets riding
JAX's async dispatch, and micro-batching, with per-phase timing so the
staging/compute overlap is measurable.

It also implements the use cases' *decision* layer: selective downlink —
requests whose model output crosses the trigger predicate are kept
(e.g. MMS region-of-interest, ESPERTA warnings), everything else is
dropped, and the achieved downlink-reduction ratio is reported (the
paper's motivating metric).

``ServingPipeline`` is the *single-model, single-batch-size core*: one
compiled plan, one padded batch per call. The continuous-batching
scheduler (core/scheduler.py) composes one pipeline per ladder rung and
drives :meth:`execute_batch` (or :meth:`execute_batch_async` in pipelined
mode) per dispatch; :meth:`run` is the standalone fixed-batch streaming
mode over a pre-materialized request list.

Synchronization contract (DESIGN.md §12): no path here ever calls
``jax.block_until_ready``. A dispatch's outputs are forced — one
``np.asarray`` per output, which blocks on exactly that batch — when its
:class:`DispatchTicket` retires: immediately in :meth:`execute_batch`,
lazily (slot-pool exhaustion, stream end, or an explicit :meth:`sync`
telemetry barrier) in the pipelined paths.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import (Callable, Deque, Dict, Iterable, List, Optional,
                    Tuple)

import jax
import numpy as np

from repro.core import memory as memory_mod


@dataclasses.dataclass
class PhaseTimes:
    stage_in: float = 0.0
    compute: float = 0.0
    stage_out: float = 0.0
    overlapped: float = 0.0         # wall time saved by pipelining

    @property
    def serial(self) -> float:
        return self.stage_in + self.compute + self.stage_out

    @property
    def wall(self) -> float:
        return self.serial - self.overlapped


@dataclasses.dataclass
class ServeStats:
    n_requests: int
    n_kept: int
    phases: PhaseTimes
    fps: float

    @property
    def downlink_reduction(self) -> float:
        return 1.0 - self.n_kept / max(self.n_requests, 1)


@dataclasses.dataclass
class BatchResult:
    """One dispatched batch: host outputs sliced back to the real requests,
    the per-request selective-downlink verdicts, and per-phase timings.
    ``compute_time`` spans dispatch to retirement (it includes the async
    wait when the ticket retired late)."""
    outputs: Dict[str, np.ndarray]      # [n_real, ...] — padding sliced off
    keep: List[bool]                    # per real request
    stage_time: float
    compute_time: float
    output_time: float

    @property
    def n_kept(self) -> int:
        return sum(self.keep)


def stage_batch(reqs: List[Dict[str, np.ndarray]], batch_size: int
                ) -> Dict[str, jax.Array]:
    """Stack request dicts into one ``[batch_size, ...]`` device batch,
    padding a ragged tail by repeating the last sample (the padding rows
    are sliced off after compute). The freshly-allocating fallback of the
    arena staging path below — and the reference its bit-exactness is
    tested against.

    Assembly is host-side NumPy on purpose: staging must cost one device
    transfer, never an XLA compile — jnp stacking would recompile for
    every distinct ragged length the scheduler flushes."""
    if not reqs:
        raise ValueError("stage_batch needs at least one request")
    if len(reqs) > batch_size:
        raise ValueError(f"{len(reqs)} requests > batch size {batch_size}")
    batch = {k: np.stack([np.asarray(r[k], np.float32) for r in reqs])
             for k in reqs[0]}
    if len(reqs) < batch_size:             # pad the ragged tail
        pad = batch_size - len(reqs)
        batch = {k: np.concatenate(
            [v, np.repeat(v[-1:], pad, axis=0)]) for k, v in batch.items()}
    return jax.device_put(batch)


class HostStagingArena:
    """The pool of reusable host batch buffers a :class:`StagingPlan`
    sizes: ``slots`` preallocated fp32 ``[B, ...]`` NumPy buffers per
    graph input, filled in place per dispatch instead of re-allocating a
    fresh stack for every ``jax.device_put``.

    Donation invariant (DESIGN.md §12): ``acquire()`` transfers slot
    ownership to the dispatch being staged; the slot returns to the free
    pool only when that dispatch's ticket retires. ``jax.device_put``
    may alias host memory on CPU backends, so an owned slot is NEVER
    rewritten while its batch is in flight. ``stage()`` writes every row
    (real rows then ragged padding), so slot reuse can never leak a
    previous batch's samples."""

    def __init__(self, staging: memory_mod.StagingPlan):
        self.staging = staging
        self._bufs = [
            {k: np.empty(shape, np.float32)
             for k, shape in staging.input_shapes.items()}
            for _ in range(staging.slots)]
        self._free: Deque[int] = deque(range(staging.slots))
        self.n_staged = 0           # dispatches staged through a slot
        self.n_fallback = 0         # pool-exhausted fresh allocations

    @property
    def n_slots(self) -> int:
        return self.staging.slots

    @property
    def n_free(self) -> int:
        return len(self._free)

    def acquire(self) -> Optional[int]:
        """Take a free slot (None when the pool is exhausted — callers
        fall back to a fresh `stage_batch` allocation, never deadlock)."""
        return self._free.popleft() if self._free else None

    def release(self, slot: int) -> None:
        self._free.append(slot)

    def stage(self, slot: int, reqs: List[Dict[str, np.ndarray]]
              ) -> Dict[str, np.ndarray]:
        """Fill ``slot`` in place with ``reqs`` (+ repeat-last padding);
        returns the slot's buffer dict. Bit-identical to `stage_batch`:
        the same fp32 casts, the same padding rule."""
        n = len(reqs)
        bufs = self._bufs[slot]
        for k, buf in bufs.items():
            for i, r in enumerate(reqs):
                buf[i] = np.asarray(r[k], np.float32)
            if n < self.staging.batch_size:
                buf[n:] = buf[n - 1]
        self.n_staged += 1
        return bufs


@dataclasses.dataclass
class DispatchTicket:
    """One in-flight dispatched batch: unforced device outputs plus the
    staging slot the dispatch owns. ``retire()`` forces the outputs to
    host (np.asarray — blocks on exactly this batch), runs the keep
    predicate, releases the slot back to the pool, and returns the
    :class:`BatchResult`. Idempotent: later calls return the cached
    result.

    Failure contract: if forcing the outputs or the keep predicate
    raises, the staging slot is STILL released and the ticket unlinked
    (a pool slot must never leak with its dispatch — the old leak
    silently drained the pool into the ``n_fallback`` path forever); the
    ticket is left poisoned, so a later ``retire()`` raises RuntimeError
    instead of fabricating a result."""
    pipeline: "ServingPipeline"
    outputs: Optional[Dict[str, jax.Array]]
    n_real: int
    slot: Optional[int]
    stage_time: float
    dispatched_at: float                # perf_counter at dispatch
    _result: Optional[BatchResult] = None

    @property
    def retired(self) -> bool:
        return self._result is not None

    def _release(self) -> None:
        if self.slot is not None:
            self.pipeline.arena.release(self.slot)
            self.slot = None
        try:
            self.pipeline._inflight.remove(self)
        except ValueError:
            pass

    def retire(self) -> BatchResult:
        if self._result is not None:
            return self._result
        if self.outputs is None:
            raise RuntimeError(
                "retire() after a failed retirement: this ticket's batch "
                "was already abandoned (its outputs are gone)")
        try:
            host_out = self.pipeline._unstage(self.outputs, self.n_real)
            t1 = time.perf_counter()
            keep = self.pipeline._keep(host_out, self.n_real)
            t2 = time.perf_counter()
        except BaseException:
            self.outputs = None         # poison: no result can ever exist
            self._release()
            raise
        self.outputs = {}               # drop the device references
        self._release()
        self._result = BatchResult(
            host_out, keep, stage_time=self.stage_time,
            compute_time=t1 - self.dispatched_at, output_time=t2 - t1)
        return self._result


class ServingPipeline:
    """Micro-batched, pipelined inference over a request stream.

    Uses the engine's staged plan cache: ONE compiled batched executable
    per (backend, batch_size), built up front — the serving loop never
    re-traces. Ragged final chunks are padded up to the plan's batch size
    (and the padding sliced off), so a request stream of any length hits
    exactly one executable. ``staging_buffers`` sizes the host staging
    arena (2 = classic double buffering).
    """

    def __init__(self, engine, backend: str = "flex",
                 batch_size: int = 16,
                 keep_predicate: Optional[Callable] = None,
                 staging_buffers: int = 2):
        self.engine = engine
        self.backend = backend
        self.batch_size = batch_size
        self.keep_predicate = keep_predicate
        self._plan = engine.compile(backend, batch_size)
        self.staging = memory_mod.plan_staging(
            self._plan.plan.graph, batch_size, staging_buffers)
        self.arena = HostStagingArena(self.staging)
        self._inflight: Deque[DispatchTicket] = deque()

    @property
    def cost(self):
        """The compiled plan's plan-time cost signature (energy/latency/W
        of one full-batch dispatch) — what the scheduler ranks backends by
        and charges the power envelope with."""
        return self._plan.cost

    @property
    def stages(self):
        """The plan's pipeline-stage decomposition (energy.StageCost
        tuple) — what the scheduler's overlap ledger prices dispatches
        with."""
        return self._plan.stages

    def _stage(self, reqs: List[Dict[str, np.ndarray]]
               ) -> Tuple[Dict[str, jax.Array], Optional[int]]:
        """Stage one batch into an arena slot (in-place reuse), falling
        back to a fresh `stage_batch` allocation when the pool is dry.
        Returns (device batch, owned slot or None)."""
        if not reqs:
            raise ValueError("stage_batch needs at least one request")
        if len(reqs) > self.batch_size:
            raise ValueError(
                f"{len(reqs)} requests > batch size {self.batch_size}")
        slot = self.arena.acquire()
        if slot is None:
            self.arena.n_fallback += 1
            return stage_batch(reqs, self.batch_size), None
        host = self.arena.stage(slot, reqs)
        return jax.device_put(host), slot

    def _dispatch(self, staged: Dict[str, jax.Array], rng: jax.Array
                  ) -> Tuple[Dict[str, jax.Array], jax.Array]:
        """One plan call — async dispatch, nothing forced; returns
        (unforced device outputs, carried-over rng)."""
        rngs = jax.random.split(rng, self.batch_size + 1)
        return self._plan(staged, rngs[1:]), rngs[0]

    def _issue(self, staged: Dict[str, jax.Array], slot: Optional[int],
               n_real: int, stage_time: float, rng: jax.Array
               ) -> Tuple[DispatchTicket, jax.Array]:
        try:
            out, carry = self._dispatch(staged, rng)
        except BaseException:
            if slot is not None:        # dispatch failed: slot back to pool
                self.arena.release(slot)
            raise
        ticket = DispatchTicket(self, out, n_real, slot, stage_time,
                                time.perf_counter())
        self._inflight.append(ticket)
        return ticket, carry

    def _unstage(self, out: Dict[str, jax.Array], n_real: int
                 ) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v)[:n_real] for k, v in out.items()}

    def _keep(self, host_out: Dict[str, np.ndarray], n_real: int
              ) -> List[bool]:
        if self.keep_predicate is None:
            return [True] * n_real
        return [bool(self.keep_predicate({k: v[i] for k, v in host_out.items()}))
                for i in range(n_real)]

    # -- the scheduler's dispatch core --------------------------------------

    def execute_batch_async(self, reqs: List[Dict[str, np.ndarray]],
                            rng: Optional[jax.Array] = None
                            ) -> DispatchTicket:
        """Stage + dispatch ONE (possibly ragged) batch WITHOUT forcing
        the result: staging is synchronous host work, the plan call rides
        JAX's async dispatch, and the returned ticket owns the staging
        slot until `retire()`."""
        if rng is None:
            rng = jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        staged, slot = self._stage(reqs)
        t1 = time.perf_counter()
        ticket, _ = self._issue(staged, slot, len(reqs), t1 - t0, rng)
        return ticket

    def execute_batch(self, reqs: List[Dict[str, np.ndarray]],
                      rng: Optional[jax.Array] = None) -> BatchResult:
        """Serve exactly ONE (possibly ragged) batch and return its forced
        result: stage + pad -> compiled plan -> slice padding -> keep
        predicate. Synchronous from the caller's view, but with NO
        `jax.block_until_ready` barrier: retiring the ticket forces only
        this batch's outputs (np.asarray), never the whole device queue."""
        return self.execute_batch_async(reqs, rng=rng).retire()

    def sync(self) -> None:
        """Retire every in-flight ticket — the telemetry-flush barrier of
        the pipelined paths."""
        while self._inflight:
            self._inflight[0].retire()

    # -- standalone fixed-batch streaming mode ------------------------------

    def run(self, requests: Iterable[Dict[str, np.ndarray]],
            pipeline: bool = True) -> ServeStats:
        """Stream ``requests`` through fixed-size batches.

        ``pipeline=True`` (default): batch k+1 is staged into a free
        arena slot and dispatched while batch k's async dispatch is still
        computing; tickets retire lazily when the slot pool runs dry and
        once at stream end (the telemetry flush). ``overlapped`` is the
        MEASURED saving: serial phase sum minus end-to-end wall time.

        ``pipeline=False``: strictly serial stage -> compute -> readback
        per batch (each ticket retires before the next dispatch)."""
        reqs = list(requests)
        phases = PhaseTimes()
        if not reqs:                        # empty stream: zero-request stats
            return ServeStats(n_requests=0, n_kept=0, phases=phases, fps=0.0)
        kept = 0
        rng = jax.random.PRNGKey(0)
        batches = [reqs[i:i + self.batch_size]
                   for i in range(0, len(reqs), self.batch_size)]

        tickets: Deque[DispatchTicket] = deque()

        def _retire_next() -> None:
            nonlocal kept
            res = tickets.popleft().retire()
            kept += sum(res.keep)
            phases.stage_in += res.stage_time
            phases.compute += res.compute_time
            phases.stage_out += res.output_time

        wall0 = time.perf_counter()
        for chunk in batches:
            if pipeline:
                # lazy retirement: only when the pool would starve
                while tickets and self.arena.n_free == 0:
                    _retire_next()
            t0 = time.perf_counter()
            staged, slot = self._stage(chunk)
            stage_t = time.perf_counter() - t0
            ticket, rng = self._issue(staged, slot, len(chunk), stage_t, rng)
            tickets.append(ticket)
            if not pipeline:
                _retire_next()
        while tickets:                      # stream-end flush
            _retire_next()
        wall = time.perf_counter() - wall0

        phases.overlapped = max(phases.serial - wall, 0.0)
        fps = len(reqs) / max(wall, 1e-12)
        return ServeStats(n_requests=len(reqs), n_kept=kept, phases=phases,
                          fps=fps)
