"""Batched on-board serving pipeline.

The paper's PYNQ flow is load_ip_input() -> start_ip() -> read_ip_output(),
with Fig 11 showing input staging *dominating* inference for small models.
This pipeline reproduces that phase structure and fixes it the way a real
deployment would: double-buffered staging (stage batch k+1 while batch k
computes) and micro-batching, with per-phase timing so the staging/compute
overlap is measurable.

It also implements the use cases' *decision* layer: selective downlink —
requests whose model output crosses the trigger predicate are kept
(e.g. MMS region-of-interest, ESPERTA warnings), everything else is
dropped, and the achieved downlink-reduction ratio is reported (the
paper's motivating metric).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class PhaseTimes:
    stage_in: float = 0.0
    compute: float = 0.0
    stage_out: float = 0.0
    overlapped: float = 0.0         # wall time saved by double buffering

    @property
    def serial(self) -> float:
        return self.stage_in + self.compute + self.stage_out

    @property
    def wall(self) -> float:
        return self.serial - self.overlapped


@dataclasses.dataclass
class ServeStats:
    n_requests: int
    n_kept: int
    phases: PhaseTimes
    fps: float

    @property
    def downlink_reduction(self) -> float:
        return 1.0 - self.n_kept / max(self.n_requests, 1)


class ServingPipeline:
    """Micro-batched, double-buffered inference over a request stream.

    Uses the engine's staged plan cache: ONE compiled batched executable
    per (backend, batch_size), built up front — the serving loop never
    re-traces. Ragged final chunks are padded up to the plan's batch size
    (and the padding sliced off), so a request stream of any length hits
    exactly one executable.
    """

    def __init__(self, engine, backend: str = "flex",
                 batch_size: int = 16,
                 keep_predicate: Optional[Callable] = None):
        self.engine = engine
        self.backend = backend
        self.batch_size = batch_size
        self.keep_predicate = keep_predicate
        self._plan = engine.compile(backend, batch_size)

    def _stage(self, reqs: List[Dict[str, np.ndarray]]) -> Dict[str, jax.Array]:
        batch = {k: jnp.stack([jnp.asarray(r[k], jnp.float32) for r in reqs])
                 for k in reqs[0]}
        if len(reqs) < self.batch_size:        # pad the ragged tail
            pad = self.batch_size - len(reqs)
            batch = {k: jnp.concatenate(
                [v, jnp.repeat(v[-1:], pad, axis=0)]) for k, v in batch.items()}
        return jax.device_put(batch)

    def run(self, requests: Iterable[Dict[str, np.ndarray]]) -> ServeStats:
        reqs = list(requests)
        phases = PhaseTimes()
        kept = 0
        rng = jax.random.PRNGKey(0)
        batches = [reqs[i:i + self.batch_size]
                   for i in range(0, len(reqs), self.batch_size)]

        staged = None
        stage_times: List[float] = []
        for bi, chunk in enumerate(batches):
            if staged is None:                       # first batch: no overlap
                t0 = time.perf_counter()
                staged = self._stage(chunk)
                stage_times.append(time.perf_counter() - t0)
            current = staged

            t0 = time.perf_counter()
            rngs = jax.random.split(rng, self.batch_size + 1)
            rng, sub = rngs[0], rngs[1:]
            out = self._plan(current, sub)
            jax.block_until_ready(out)
            compute_t = time.perf_counter() - t0

            # double buffering: stage the NEXT batch while this one computes
            # (sequenced here; on hardware the DMA engine runs concurrently —
            # we credit min(stage, compute) as overlapped)
            staged = None
            stage_t = 0.0
            if bi + 1 < len(batches):
                t0 = time.perf_counter()
                staged = self._stage(batches[bi + 1])
                stage_t = time.perf_counter() - t0
                stage_times.append(stage_t)
            phases.compute += compute_t
            phases.overlapped += min(stage_t, compute_t)

            t0 = time.perf_counter()
            host_out = {k: np.asarray(v)[:len(chunk)] for k, v in out.items()}
            phases.stage_out += time.perf_counter() - t0

            if self.keep_predicate is not None:
                for i in range(len(chunk)):
                    if self.keep_predicate(
                            {k: v[i] for k, v in host_out.items()}):
                        kept += 1
            else:
                kept += len(chunk)

        phases.stage_in = sum(stage_times)
        fps = len(reqs) / max(phases.wall, 1e-12)
        return ServeStats(n_requests=len(reqs), n_kept=kept, phases=phases,
                          fps=fps)
