"""Batched on-board serving pipeline.

The paper's PYNQ flow is load_ip_input() -> start_ip() -> read_ip_output(),
with Fig 11 showing input staging *dominating* inference for small models.
This pipeline reproduces that phase structure and fixes it the way a real
deployment would: double-buffered staging (stage batch k+1 while batch k
computes) and micro-batching, with per-phase timing so the staging/compute
overlap is measurable.

It also implements the use cases' *decision* layer: selective downlink —
requests whose model output crosses the trigger predicate are kept
(e.g. MMS region-of-interest, ESPERTA warnings), everything else is
dropped, and the achieved downlink-reduction ratio is reported (the
paper's motivating metric).

``ServingPipeline`` is the *single-model, single-batch-size synchronous
core*: one compiled plan, one padded batch per call. The continuous-
batching scheduler (core/scheduler.py) composes one pipeline per ladder
rung and drives :meth:`execute_batch` per dispatch; :meth:`run` is the
standalone fixed-batch streaming mode over a pre-materialized request
list.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterable, List, Optional

import jax
import numpy as np


@dataclasses.dataclass
class PhaseTimes:
    stage_in: float = 0.0
    compute: float = 0.0
    stage_out: float = 0.0
    overlapped: float = 0.0         # wall time saved by double buffering

    @property
    def serial(self) -> float:
        return self.stage_in + self.compute + self.stage_out

    @property
    def wall(self) -> float:
        return self.serial - self.overlapped


@dataclasses.dataclass
class ServeStats:
    n_requests: int
    n_kept: int
    phases: PhaseTimes
    fps: float

    @property
    def downlink_reduction(self) -> float:
        return 1.0 - self.n_kept / max(self.n_requests, 1)


@dataclasses.dataclass
class BatchResult:
    """One dispatched batch: host outputs sliced back to the real requests,
    the per-request selective-downlink verdicts, and per-phase timings."""
    outputs: Dict[str, np.ndarray]      # [n_real, ...] — padding sliced off
    keep: List[bool]                    # per real request
    stage_time: float
    compute_time: float
    output_time: float

    @property
    def n_kept(self) -> int:
        return sum(self.keep)


def stage_batch(reqs: List[Dict[str, np.ndarray]], batch_size: int
                ) -> Dict[str, jax.Array]:
    """Stack request dicts into one ``[batch_size, ...]`` device batch,
    padding a ragged tail by repeating the last sample (the padding rows
    are sliced off after compute). The single staging/padding path shared
    by the fixed-batch pipeline and the scheduler's ladder dispatches.

    Assembly is host-side NumPy on purpose: staging must cost one device
    transfer, never an XLA compile — jnp stacking would recompile for
    every distinct ragged length the scheduler flushes."""
    if not reqs:
        raise ValueError("stage_batch needs at least one request")
    if len(reqs) > batch_size:
        raise ValueError(f"{len(reqs)} requests > batch size {batch_size}")
    batch = {k: np.stack([np.asarray(r[k], np.float32) for r in reqs])
             for k in reqs[0]}
    if len(reqs) < batch_size:             # pad the ragged tail
        pad = batch_size - len(reqs)
        batch = {k: np.concatenate(
            [v, np.repeat(v[-1:], pad, axis=0)]) for k, v in batch.items()}
    return jax.device_put(batch)


class ServingPipeline:
    """Micro-batched, double-buffered inference over a request stream.

    Uses the engine's staged plan cache: ONE compiled batched executable
    per (backend, batch_size), built up front — the serving loop never
    re-traces. Ragged final chunks are padded up to the plan's batch size
    (and the padding sliced off), so a request stream of any length hits
    exactly one executable.
    """

    def __init__(self, engine, backend: str = "flex",
                 batch_size: int = 16,
                 keep_predicate: Optional[Callable] = None):
        self.engine = engine
        self.backend = backend
        self.batch_size = batch_size
        self.keep_predicate = keep_predicate
        self._plan = engine.compile(backend, batch_size)

    @property
    def cost(self):
        """The compiled plan's plan-time cost signature (energy/latency/W
        of one full-batch dispatch) — what the scheduler ranks backends by
        and charges the power envelope with."""
        return self._plan.cost

    def _stage(self, reqs: List[Dict[str, np.ndarray]]) -> Dict[str, jax.Array]:
        return stage_batch(reqs, self.batch_size)

    def _compute(self, staged: Dict[str, jax.Array], rng: jax.Array):
        """One plan call; returns (device outputs, carried-over rng)."""
        rngs = jax.random.split(rng, self.batch_size + 1)
        out = self._plan(staged, rngs[1:])
        jax.block_until_ready(out)
        return out, rngs[0]

    def _unstage(self, out: Dict[str, jax.Array], n_real: int
                 ) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v)[:n_real] for k, v in out.items()}

    def _keep(self, host_out: Dict[str, np.ndarray], n_real: int
              ) -> List[bool]:
        if self.keep_predicate is None:
            return [True] * n_real
        return [bool(self.keep_predicate({k: v[i] for k, v in host_out.items()}))
                for i in range(n_real)]

    # -- the scheduler's dispatch core --------------------------------------

    def execute_batch(self, reqs: List[Dict[str, np.ndarray]],
                      rng: Optional[jax.Array] = None) -> BatchResult:
        """Serve exactly ONE (possibly ragged) batch synchronously:
        stage + pad -> compiled plan -> slice padding -> keep predicate."""
        if rng is None:
            rng = jax.random.PRNGKey(0)
        t0 = time.perf_counter()
        staged = self._stage(reqs)
        t1 = time.perf_counter()
        out, _ = self._compute(staged, rng)
        t2 = time.perf_counter()
        host_out = self._unstage(out, len(reqs))
        keep = self._keep(host_out, len(reqs))
        t3 = time.perf_counter()
        return BatchResult(host_out, keep, stage_time=t1 - t0,
                           compute_time=t2 - t1, output_time=t3 - t2)

    # -- standalone fixed-batch streaming mode ------------------------------

    def run(self, requests: Iterable[Dict[str, np.ndarray]]) -> ServeStats:
        reqs = list(requests)
        phases = PhaseTimes()
        if not reqs:                        # empty stream: zero-request stats
            return ServeStats(n_requests=0, n_kept=0, phases=phases, fps=0.0)
        kept = 0
        rng = jax.random.PRNGKey(0)
        batches = [reqs[i:i + self.batch_size]
                   for i in range(0, len(reqs), self.batch_size)]

        staged = None
        stage_times: List[float] = []
        for bi, chunk in enumerate(batches):
            if staged is None:                       # first batch: no overlap
                t0 = time.perf_counter()
                staged = self._stage(chunk)
                stage_times.append(time.perf_counter() - t0)
            current = staged

            t0 = time.perf_counter()
            out, rng = self._compute(current, rng)
            compute_t = time.perf_counter() - t0

            # double buffering: stage the NEXT batch while this one computes
            # (sequenced here; on hardware the DMA engine runs concurrently —
            # we credit min(stage, compute) as overlapped)
            staged = None
            stage_t = 0.0
            if bi + 1 < len(batches):
                t0 = time.perf_counter()
                staged = self._stage(batches[bi + 1])
                stage_t = time.perf_counter() - t0
                stage_times.append(stage_t)
            phases.compute += compute_t
            phases.overlapped += min(stage_t, compute_t)

            t0 = time.perf_counter()
            host_out = self._unstage(out, len(chunk))
            kept += sum(self._keep(host_out, len(chunk)))
            phases.stage_out += time.perf_counter() - t0

        phases.stage_in = sum(stage_times)
        fps = len(reqs) / max(phases.wall, 1e-12)
        return ServeStats(n_requests=len(reqs), n_kept=kept, phases=phases,
                          fps=fps)
