"""Orbit-aware radiation environment (DESIGN.md §16).

The degraded-mode suite (§13) injects single-bit weight-arena upsets at
a *constant* Poisson rate. Real LEO missions see nothing of the sort:
the galactic-cosmic-ray (GCR) background is modulated by eclipse phase
(the ZCU104 analog runs hotter and lower-margin in sunlight, colder in
eclipse) and punctuated by South Atlantic Anomaly (SAA) passes where the
trapped-proton flux multiplies the upset rate by one to two orders of
magnitude for a few minutes per orbit. Upsets are also not all
single-bit: adjacent multi-bit bursts (MBUs) from a single heavy-ion
track and control-path upsets (scheduler ladder/queue state, staging
slots, the persisted TuningCache) need their own detection and recovery
story.

This module is the *environment* half of that story — pure numpy, no
jax, importable by both the fault controller and the examples:

- ``ORBIT_PHASES`` — the canonical eclipse phase schedule. This is the
  single source of truth that ``examples/eclipse_orbit.py`` zips with
  its power envelopes, so the radiation model and the power model stay
  synced by construction. Durations are *virtual* seconds at the same
  ~1000x time compression the examples use.
- ``RadiationEnvironment`` — a seedable, deterministic time-varying
  upset-rate model: base GCR rate x eclipse-phase factor x SAA-window
  multiplier, periodic in the orbit. Sampled into concrete schedules
  with non-homogeneous Poisson (NHPP) thinning: draw candidates from a
  homogeneous process at the rate *bound*, accept each with probability
  rate(t)/bound. Every accepted event draws an upset class from the
  configured mixture — 'single' (one flipped bit), 'mbu' (one flipped
  bit in each of ``span`` adjacent bytes), 'control' (a scheduler /
  staging / tuning-cache corruption).
- ``optimize_cadence`` — expected replay-loss + checkpoint-overhead
  cadence optimization against the environment's rate trace, validated
  by the radiation benchmark's modeled-clock watchdog-reboot replays.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "ORBIT_PHASES", "DEFAULT_PHASE_FACTORS", "DEFAULT_MIX",
    "CONTROL_TARGETS", "UpsetEvent", "RadiationEnvironment",
    "CadencePlan", "expected_replay_cost", "optimize_cadence",
]


# ---------------------------------------------------------------------------
# Canonical orbit phase schedule (shared with examples/eclipse_orbit.py)
# ---------------------------------------------------------------------------

# (phase name, duration in virtual seconds). One orbit = 0.50 virtual s
# at the examples' time compression (a real ~95 min LEO orbit).
ORBIT_PHASES: Tuple[Tuple[str, float], ...] = (
    ("sunlight", 0.15),
    ("penumbra", 0.05),
    ("eclipse", 0.15),
    ("penumbra", 0.05),
    ("sunlight", 0.10),
)

# GCR-background multipliers per eclipse phase. Eclipse-side passes run
# through the nightside horns of the outer belt, so the background
# creeps up; the effect is small next to an SAA pass.
DEFAULT_PHASE_FACTORS: Tuple[Tuple[str, float], ...] = (
    ("sunlight", 1.0),
    ("penumbra", 1.15),
    ("eclipse", 1.3),
)

# Upset-class mixture: P(single), P(mbu), P(control). Roughly the split
# reported for SRAM-based FPGAs — most upsets single-bit, a quarter
# adjacent multi-bit, a thin tail hitting configuration/control state.
DEFAULT_MIX: Tuple[Tuple[str, float], ...] = (
    ("single", 0.60),
    ("mbu", 0.25),
    ("control", 0.15),
)

# Control-path subsystems the injector knows how to corrupt.
CONTROL_TARGETS: Tuple[str, ...] = ("ladder", "queue", "staging", "tuning")

UPSET_KINDS: Tuple[str, ...] = ("single", "mbu", "control")


@dataclasses.dataclass(frozen=True)
class UpsetEvent:
    """One scheduled upset: when, what class, and how wide.

    ``span`` is the MBU burst width in adjacent bytes (1 for 'single').
    ``target`` names the control subsystem for 'control' events; empty
    means the injector picks one.
    """
    t: float
    kind: str = "single"
    span: int = 1
    target: str = ""

    def __post_init__(self):
        if self.kind not in UPSET_KINDS:
            raise ValueError(f"unknown upset kind {self.kind!r}; "
                             f"expected one of {UPSET_KINDS}")
        if self.span < 1:
            raise ValueError(f"upset span must be >= 1, got {self.span}")
        if self.target and self.target not in CONTROL_TARGETS:
            raise ValueError(f"unknown control target {self.target!r}; "
                             f"expected one of {CONTROL_TARGETS}")


# ---------------------------------------------------------------------------
# The environment
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RadiationEnvironment:
    """Deterministic time-varying upset-rate model, periodic in the orbit.

    rate(t) = base_rate * phase_factor(phase_of(t)) * saa_factor if t is
    inside the SAA window (orbit-relative) else 1. Rates are upsets per
    *virtual* second — at the examples' ~1000x compression, 2.0/s here
    is a realistic few-per-hour on orbit.
    """
    base_rate: float = 2.0
    phases: Tuple[Tuple[str, float], ...] = ORBIT_PHASES
    phase_factors: Tuple[Tuple[str, float], ...] = DEFAULT_PHASE_FACTORS
    # SAA pass as an orbit-relative [start, end) window, or None.
    saa_window: Optional[Tuple[float, float]] = (0.20, 0.32)
    saa_factor: float = 40.0
    mix: Tuple[Tuple[str, float], ...] = DEFAULT_MIX
    mbu_span: Tuple[int, int] = (2, 8)      # inclusive adjacent-byte range
    control_targets: Tuple[str, ...] = CONTROL_TARGETS

    def __post_init__(self):
        if self.base_rate < 0.0:
            raise ValueError("base_rate must be >= 0")
        if not self.phases:
            raise ValueError("need at least one orbit phase")
        if any(d <= 0.0 for _, d in self.phases):
            raise ValueError("phase durations must be positive")
        factors = dict(self.phase_factors)
        for name, _ in self.phases:
            if name not in factors:
                raise ValueError(f"no phase factor for phase {name!r}")
        if any(f < 0.0 for f in factors.values()):
            raise ValueError("phase factors must be >= 0")
        if self.saa_window is not None:
            s, e = self.saa_window
            if not (0.0 <= s < e <= self.orbit_s + 1e-12):
                raise ValueError(
                    f"saa_window {self.saa_window} must satisfy "
                    f"0 <= start < end <= orbit_s ({self.orbit_s:g})")
        if self.saa_factor < 1.0:
            raise ValueError("saa_factor must be >= 1")
        if abs(sum(w for _, w in self.mix) - 1.0) > 1e-9:
            raise ValueError("upset-class mix weights must sum to 1")
        if any(k not in UPSET_KINDS for k, _ in self.mix):
            raise ValueError(f"mix kinds must be among {UPSET_KINDS}")
        if not (1 <= self.mbu_span[0] <= self.mbu_span[1]):
            raise ValueError(f"bad mbu_span {self.mbu_span}")

    # -- geometry ----------------------------------------------------------

    @property
    def orbit_s(self) -> float:
        return sum(d for _, d in self.phases)

    def phase_of(self, t: float) -> str:
        u = math.fmod(t, self.orbit_s)
        if u < 0.0:
            u += self.orbit_s
        acc = 0.0
        for name, dur in self.phases:
            acc += dur
            if u < acc:
                return name
        return self.phases[-1][0]

    def in_saa(self, t: float) -> bool:
        if self.saa_window is None:
            return False
        u = math.fmod(t, self.orbit_s)
        if u < 0.0:
            u += self.orbit_s
        s, e = self.saa_window
        return s <= u < e

    # -- rates -------------------------------------------------------------

    def rate(self, t: float) -> float:
        """Instantaneous upset rate (events / virtual s) at time t."""
        r = self.base_rate * dict(self.phase_factors)[self.phase_of(t)]
        if self.in_saa(t):
            r *= self.saa_factor
        return r

    def rate_bound(self) -> float:
        """A tight upper bound on rate(t) — the NHPP thinning envelope."""
        fmax = max(dict(self.phase_factors)[name] for name, _ in self.phases)
        bound = self.base_rate * fmax
        if self.saa_window is not None:
            bound *= self.saa_factor
        return bound

    def expected_upsets(self, t0: float, t1: float, dt: float = 1e-3) -> float:
        """Numerical integral of rate(t) over [t0, t1] (midpoint rule)."""
        if t1 <= t0:
            return 0.0
        n = max(1, int(math.ceil((t1 - t0) / dt)))
        step = (t1 - t0) / n
        return sum(self.rate(t0 + (i + 0.5) * step) for i in range(n)) * step

    def uncorrectable_fraction(self, n_domains: int) -> float:
        """Fraction of *arena* upsets SEC-per-domain ECC cannot correct.

        With byte-interleaved protection domains, a burst of span <=
        n_domains lands at most one byte per domain, so singles and
        short MBUs correct; only spans > n_domains are detect-only.
        Control-path upsets never touch the arena and are excluded.
        """
        mix = dict(self.mix)
        arena_w = mix.get("single", 0.0) + mix.get("mbu", 0.0)
        if arena_w <= 0.0:
            return 0.0
        lo, hi = self.mbu_span
        spans = hi - lo + 1
        n_bad = sum(1 for s in range(lo, hi + 1) if s > n_domains)
        return (mix.get("mbu", 0.0) * n_bad / spans) / arena_w

    # -- sampling ----------------------------------------------------------

    def sample_upsets(self, seed: int, horizon_s: float,
                      start: float = 0.0) -> Tuple[UpsetEvent, ...]:
        """Draw a concrete upset schedule over [start, start+horizon_s).

        NHPP thinning: homogeneous candidates at ``rate_bound()``, each
        accepted with probability rate(t)/bound. Class / span / target
        draws happen only for accepted candidates, so two environments
        that agree on rate() and mix produce the same schedule from the
        same seed. Deterministic per (seed, horizon, start).
        """
        bound = self.rate_bound()
        if bound <= 0.0 or horizon_s <= 0.0:
            return ()
        rng = np.random.default_rng(int(seed) + 17)
        mix_kinds = [k for k, _ in self.mix]
        mix_cdf = np.cumsum([w for _, w in self.mix])
        lo, hi = self.mbu_span
        out: List[UpsetEvent] = []
        t = start
        while True:
            t += rng.exponential(1.0 / bound)
            if t >= start + horizon_s:
                break
            if rng.uniform() * bound > self.rate(t):
                continue                     # thinned away
            kind = mix_kinds[int(np.searchsorted(mix_cdf, rng.uniform(),
                                                 side="right"))]
            span, target = 1, ""
            if kind == "mbu":
                span = int(rng.integers(lo, hi + 1))
            elif kind == "control":
                target = self.control_targets[
                    int(rng.integers(len(self.control_targets)))]
            out.append(UpsetEvent(float(t), kind, span, target))
        return tuple(out)


# ---------------------------------------------------------------------------
# Checkpoint-cadence optimization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CadencePlan:
    """The optimizer's pick plus the full cost curve it argmin'd over."""
    cadence_s: float
    expected_cost_s: float
    checkpoint_cost_s: float
    horizon_s: float
    n_checkpoints: int
    curve: Tuple[Tuple[float, float], ...]   # (cadence, expected cost)


def expected_replay_cost(env: RadiationEnvironment, horizon_s: float,
                         cadence_s: float, checkpoint_cost_s: float,
                         replay_factor: float = 1.0, start: float = 0.0,
                         dt: Optional[float] = None) -> float:
    """Expected virtual seconds lost to checkpointing + watchdog replay.

        cost(T) = ceil(H/T) * c_ckpt
                + replay_factor * integral rate(t) * ((t-start) mod T) dt

    The integrand is the expected rollback distance if a reboot-class
    upset lands at t: everything since the last checkpoint replays.
    ``replay_factor`` scales replay seconds into cost (1.0 = replayed
    work costs what it cost the first time).
    """
    if cadence_s <= 0.0 or horizon_s <= 0.0:
        raise ValueError("cadence_s and horizon_s must be positive")
    if checkpoint_cost_s < 0.0:
        raise ValueError("checkpoint_cost_s must be >= 0")
    n_ckpt = int(math.ceil(horizon_s / cadence_s))
    if dt is None:
        dt = min(horizon_s / 512.0, cadence_s / 8.0)
    n = max(1, int(math.ceil(horizon_s / dt)))
    step = horizon_s / n
    replay = 0.0
    for i in range(n):
        t = start + (i + 0.5) * step
        replay += env.rate(t) * math.fmod(t - start, cadence_s) * step
    return n_ckpt * checkpoint_cost_s + replay_factor * replay


def optimize_cadence(env: RadiationEnvironment, horizon_s: float,
                     checkpoint_cost_s: float, replay_factor: float = 1.0,
                     start: float = 0.0,
                     candidates: Optional[Sequence[float]] = None,
                     ) -> CadencePlan:
    """Pick the checkpoint cadence minimizing ``expected_replay_cost``.

    The curve is convex-ish in log T (overhead ~ 1/T, replay ~ T), so a
    geometric candidate grid brackets the minimum; the default grid
    spans from "checkpointing is half the budget" down to "one
    checkpoint for the whole horizon". Deterministic — no sampling.
    """
    if candidates is None:
        lo = max(2.0 * checkpoint_cost_s, horizon_s / 512.0)
        lo = min(lo, horizon_s)
        candidates = np.geomspace(lo, horizon_s, 41)
    curve = [(float(T), expected_replay_cost(env, horizon_s, float(T),
                                             checkpoint_cost_s,
                                             replay_factor, start))
             for T in candidates]
    best_T, best_cost = min(curve, key=lambda p: (p[1], p[0]))
    return CadencePlan(
        cadence_s=best_T, expected_cost_s=best_cost,
        checkpoint_cost_s=checkpoint_cost_s, horizon_s=horizon_s,
        n_checkpoints=int(math.ceil(horizon_s / best_T)),
        curve=tuple(curve))
