"""Static activation-buffer planner — the BRAM/DDR two-tier arena
(DESIGN.md §10).

The paper's HLS designs owe their energy win to *buffer planning*: each
layer's output streams into an on-chip buffer sized at synthesis time,
and DDR is touched only at the design's boundary. This module does the
same planning for an execution plan, at plan time:

* **liveness** — every non-input node's value is live from its
  definition to its last use (graph outputs stay live to the end: they
  are the downlink payload).
* **arena assignment** — buffers are packed into a single BRAM arena
  (first-fit over live intervals, the classic static allocator) whose
  budget is the backend's on-chip memory minus resident weights. What
  does not fit *spills* to DDR.
* **tier rules** — a value consumed outside its producing segment
  crosses a backend boundary and must round-trip DDR regardless of
  size; graph inputs arrive from DDR; graph outputs leave to DDR.

The resulting :class:`ArenaPlan` is what `energy.plan_cost_signature`
charges: DDR bytes for spills and boundaries only — on-chip traffic is
free, which is precisely why operator fusion (fewer, narrower
intermediates: int8 instead of fp32) measurably lowers the modeled
J/inference.

Buffers are sized per *sample*: the accelerator streams one sample's
intermediates at a time (batch amortizes staging, not buffer size).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.opgraph import Graph


@dataclasses.dataclass(frozen=True)
class BufferAssignment:
    name: str                       # producing node
    nbytes: int                     # per-sample bytes
    tier: str                       # 'bram' | 'ddr'
    offset: int                     # arena offset (bram) or -1 (ddr)
    first: int                      # def position in topo order
    last: int                       # last-use position
    reason: str = ""                # 'spill' | 'boundary' | '' (bram)


@dataclasses.dataclass
class ArenaPlan:
    """The static buffer plan for one execution plan (one backend)."""
    graph_name: str
    backend: str
    bram_budget: int                # bytes available to activations
    buffers: Dict[str, BufferAssignment]
    bram_peak: int                  # high-water mark of the arena
    input_bytes: int                # graph inputs read from DDR, /sample
    output_bytes: int               # graph outputs written to DDR, /sample
    spill_bytes: int                # DDR round-trip traffic from spills
    boundary_bytes: int             # DDR round-trips at segment crossings
    weight_bytes: int = 0           # resident weight footprint the budget
                                    # was derived from — the PACKED
                                    # (tile-padded) bytes when a prepacked
                                    # weight arena exists (DESIGN.md §11)

    @property
    def n_spilled(self) -> int:
        return sum(1 for b in self.buffers.values()
                   if b.tier == "ddr" and b.reason == "spill")

    @property
    def ddr_bytes_per_sample(self) -> int:
        """Modeled DDR traffic one sample causes through activations."""
        return (self.input_bytes + self.output_bytes
                + self.spill_bytes + self.boundary_bytes)

    def summary(self) -> str:
        lines = [f"arena[{self.graph_name}/{self.backend}]: "
                 f"peak {self.bram_peak:,} / {self.bram_budget:,} B BRAM, "
                 f"{self.n_spilled} spill(s), "
                 f"{self.ddr_bytes_per_sample:,} DDR B/sample"
                 + (f", {self.weight_bytes:,} B resident weights"
                    if self.weight_bytes else "")]
        for b in self.buffers.values():
            where = (f"bram@{b.offset}" if b.tier == "bram"
                     else f"ddr({b.reason})")
            lines.append(f"    {b.name:24s} {b.nbytes:10,d} B  "
                         f"[{b.first:3d},{b.last:3d}]  {where}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class StagingPlan:
    """The HOST-side staging arena for one (plan, batch rung): the fixed
    fp32 batch-buffer shape of every graph input and the slot count the
    double-buffered pipeline preallocates (DESIGN.md §12).

    Planned statically, like the device arena above: the serving loop
    reuses these buffers for every dispatch (batch k+1 is assembled in a
    free slot while batch k computes) instead of allocating a fresh host
    stack per `jax.device_put`. A slot is owned by its in-flight dispatch
    until the dispatch's ticket retires — `jax.device_put` may alias host
    memory, so an owned slot is never rewritten."""
    graph_name: str
    batch_size: int
    slots: int
    input_shapes: Dict[str, Tuple[int, ...]]    # name -> [B, ...] shape

    @property
    def input_bytes(self) -> Dict[str, int]:
        """fp32 bytes of each input buffer, per slot."""
        return {k: int(np.prod(s, dtype=np.int64)) * 4
                for k, s in self.input_shapes.items()}

    @property
    def slot_bytes(self) -> int:
        return sum(self.input_bytes.values())

    @property
    def total_bytes(self) -> int:
        return self.slot_bytes * self.slots

    def summary(self) -> str:
        return (f"staging[{self.graph_name}/b{self.batch_size}]: "
                f"{self.slots} slot(s) x {self.slot_bytes:,} B "
                f"({self.total_bytes:,} B host arena)")


def plan_staging(graph: Graph, batch_size: int, slots: int = 2
                 ) -> StagingPlan:
    """Size the host staging arena for ``batch_size`` dispatches of
    ``graph``: one fp32 ``[batch_size, ...]`` buffer per graph input per
    slot. ``slots=2`` is classic double buffering; more slots deepen the
    in-flight window the async scheduler may keep open."""
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    if slots < 1:
        raise ValueError(f"staging needs >= 1 slot, got {slots}")
    shapes = {name: (batch_size,) + tuple(shape)
              for name, shape in graph.graph_inputs.items()}
    return StagingPlan(graph_name=graph.name, batch_size=batch_size,
                       slots=slots, input_shapes=shapes)


def _nbytes(graph: Graph, name: str,
            act_dtype_bytes: Dict[str, int]) -> int:
    shape = graph.nodes[name].out_shape or ()
    return int(np.prod(shape, dtype=np.int64)) * act_dtype_bytes.get(name, 4)


def plan_arena(graph: Graph,
               segments: Sequence,          # plan.Segment sequence
               bram_budget: int,
               act_dtype_bytes: Optional[Dict[str, int]] = None,
               backend: str = "flex",
               weight_bytes: int = 0) -> ArenaPlan:
    """Assign every activation a tier (+ BRAM offset) via liveness-aware
    first-fit. ``act_dtype_bytes`` maps node name -> bytes/element (1 for
    int8-domain values, default 4); ``bram_budget`` is the on-chip bytes
    left after resident weights — ``weight_bytes`` records the footprint
    that budget was derived from (the packed/padded bytes when a
    prepacked weight arena exists), for reporting."""
    from repro.core.opgraph import consumers as _consumers

    act_dtype_bytes = act_dtype_bytes or {}
    cons = _consumers(graph)
    seg_of: Dict[str, int] = {}
    for si, seg in enumerate(segments):
        for n in seg.nodes:
            seg_of[n] = si

    pos = {name: i for i, name in enumerate(graph.order)}
    end = len(graph.order)
    last_use: Dict[str, int] = {
        name: max([pos[c] for c in cs] or [pos[name]])
        for name, cs in cons.items() if name in pos}
    for o in graph.outputs:
        last_use[o] = end                       # downlink payload

    buffers: Dict[str, BufferAssignment] = {}
    live: List[Tuple[int, int, int]] = []       # (offset, nbytes, last)
    bram_peak = 0
    spill_bytes = boundary_bytes = 0

    def _first_fit(nbytes: int) -> Optional[int]:
        taken = sorted((o, o + s) for o, s, _ in live)
        cursor = 0
        for lo, hi in taken:
            if lo - cursor >= nbytes:
                break
            cursor = max(cursor, hi)
        if cursor + nbytes > bram_budget:
            return None
        return cursor

    for name in graph.order:
        node = graph.nodes[name]
        if node.op in ("input", "const"):
            continue
        t = pos[name]
        # expire buffers whose last use is strictly past (a node may not
        # overwrite a value still being read at t)
        live[:] = [e for e in live if e[2] >= t]
        nbytes = _nbytes(graph, name, act_dtype_bytes)
        last = last_use.get(name, t)
        # write always; read back only if somebody actually reads it (a
        # consumer-less output is written once for downlink, never read)
        traffic = nbytes * (2 if cons.get(name) else 1)
        crosses = any(seg_of.get(c) != seg_of.get(name)
                      for c in cons.get(name, ()))
        if crosses:
            # a backend boundary forces a DDR round-trip regardless of size
            buffers[name] = BufferAssignment(name, nbytes, "ddr", -1, t,
                                             last, "boundary")
            boundary_bytes += traffic
            continue
        off = _first_fit(nbytes)
        if off is None:
            buffers[name] = BufferAssignment(name, nbytes, "ddr", -1, t,
                                             last, "spill")
            spill_bytes += traffic
            continue
        live.append((off, nbytes, last))
        bram_peak = max(bram_peak, off + nbytes)
        buffers[name] = BufferAssignment(name, nbytes, "bram", off, t, last)

    input_bytes = sum(_nbytes(graph, n, act_dtype_bytes)
                      for n in graph.graph_inputs)
    # DDR-tier outputs already paid their write in spill/boundary traffic
    output_bytes = sum(
        _nbytes(graph, o, act_dtype_bytes) for o in set(graph.outputs)
        if o in buffers and buffers[o].tier == "bram")
    return ArenaPlan(
        graph_name=graph.name,
        backend=backend,
        bram_budget=bram_budget,
        buffers=buffers,
        bram_peak=bram_peak,
        input_bytes=input_bytes,
        output_bytes=output_bytes,
        spill_bytes=spill_bytes,
        boundary_bytes=boundary_bytes,
        weight_bytes=weight_bytes,
    )


# ---------------------------------------------------------------------------
# Per-request KV-cache slots (LM autoregressive decode — DESIGN.md §15)
# ---------------------------------------------------------------------------

# slot capacities are padded to a whole number of 128-position tiles: the
# int8 K/V planes then tile cleanly on the MXU lane dim, and every slot
# in the arena shares one static shape (no per-request re-trace)
KV_TILE = 128


@dataclasses.dataclass(frozen=True)
class KVSpec:
    """Per-slot cache geometry for ONE stateful LM node."""
    node: str                       # graph node the cache backs
    kind: str                       # 'attention' | 'ssd'
    shape: Tuple[int, ...]          # attention: [capacity, Hkv, hd]
                                    # ssd:       [H, P, N]
    slot_bytes: int                 # one request's bytes for this node

    def describe(self) -> str:
        return (f"{self.node}[{self.kind}] {self.shape} "
                f"{self.slot_bytes:,} B/slot")


@dataclasses.dataclass
class KVCachePlan:
    """The static KV-cache arena: ``n_slots`` fixed-capacity per-request
    slots, sized at plan time and charged to the memory budget like
    prepacked weights. Attention nodes store int8 K/V codes plus f16
    per-(position, head) scale planes; SSD nodes store their fp32
    recurrent state. Steady-state decode reuses these buffers in place —
    zero allocations, zero re-traces."""
    graph_name: str
    n_slots: int
    capacity: int                   # tile-aligned max sequence length
    specs: Dict[str, KVSpec]
    tier: str                       # 'bram' | 'ddr'

    @property
    def slot_bytes(self) -> int:
        return sum(s.slot_bytes for s in self.specs.values())

    @property
    def total_bytes(self) -> int:
        return self.slot_bytes * self.n_slots

    @property
    def bram_bytes(self) -> int:
        return self.total_bytes if self.tier == "bram" else 0

    @property
    def ddr_bytes(self) -> int:
        return self.total_bytes if self.tier == "ddr" else 0

    def summary(self) -> str:
        return (f"kv[{self.graph_name}]: {self.n_slots} slot(s) x "
                f"{self.slot_bytes:,} B (cap {self.capacity}) = "
                f"{self.total_bytes:,} B {self.tier}")


def plan_kv_cache(graph: Graph, n_slots: int, max_seq: int,
                  bram_available: int = 0) -> KVCachePlan:
    """Size the per-request KV-cache slots for every stateful node of an
    LM graph. ``max_seq`` (prompt + generated tokens) is padded up to a
    whole number of :data:`KV_TILE` positions; the arena lands in BRAM
    when all slots fit in ``bram_available`` (on-chip bytes left after
    resident weights), otherwise DDR — mirroring the weight-residency
    policy."""
    from repro.core.opgraph import base_op as _base_op

    if n_slots < 1:
        raise ValueError(f"KV cache needs >= 1 slot, got {n_slots}")
    if max_seq < 1:
        raise ValueError(f"max_seq must be >= 1, got {max_seq}")
    capacity = -(-max_seq // KV_TILE) * KV_TILE
    specs: Dict[str, KVSpec] = {}
    for name in graph.order:
        node = graph.nodes[name]
        bop = _base_op(node)
        if bop == "attention":
            _, hkv, hd = graph.nodes[node.inputs[1]].out_shape
            # int8 K + V codes, f16 K + V scale planes
            nbytes = 2 * capacity * hkv * hd + 2 * capacity * hkv * 2
            specs[name] = KVSpec(name, "attention",
                                 (capacity, hkv, hd), nbytes)
        elif bop == "ssd":
            _, h, p = graph.nodes[node.inputs[0]].out_shape
            n = graph.nodes[node.inputs[1]].out_shape[-1]
            specs[name] = KVSpec(name, "ssd", (h, p, n), h * p * n * 4)
    total = sum(s.slot_bytes for s in specs.values()) * n_slots
    tier = "bram" if total and total <= bram_available else "ddr"
    return KVCachePlan(graph_name=graph.name, n_slots=n_slots,
                       capacity=capacity, specs=specs, tier=tier)


class KVSlotAllocator:
    """Free-list allocator over the KV arena's request slots, driven by
    the scheduler at request admission/retirement. Counts every assign —
    the steady-state-decode gate asserts the count does NOT move while
    tokens stream (all allocation happened at admission)."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots))
        self._owner: Dict[object, int] = {}
        self.n_assigns = 0
        self.high_water = 0

    @property
    def in_use(self) -> int:
        return self.n_slots - len(self._free)

    def assign(self, request_id) -> Optional[int]:
        """Claim a slot for ``request_id``; None when the arena is full
        (the scheduler keeps the request queued)."""
        if request_id in self._owner:
            raise ValueError(f"request {request_id!r} already holds "
                             f"slot {self._owner[request_id]}")
        if not self._free:
            return None
        slot = self._free.pop(0)
        self._owner[request_id] = slot
        self.n_assigns += 1
        self.high_water = max(self.high_water, self.in_use)
        return slot

    def release(self, request_id) -> int:
        slot = self._owner.pop(request_id)
        self._free.append(slot)
        return slot

    def slot_of(self, request_id) -> int:
        return self._owner[request_id]


# ---------------------------------------------------------------------------
# Protection domains: ECC/TMR footprint + MBU interleaving (DESIGN.md §16)
# ---------------------------------------------------------------------------


def protected_weight_bytes(packed_bytes: int, mode: str) -> int:
    """Packed-weight arena footprint under a protection mode: SEC-DED
    ECC adds 8 check bits per 64 data bits (+12.5%); spatial TMR keeps
    three live copies (x3). This is the footprint the protected cost
    signature charges against the BRAM budget."""
    if packed_bytes < 0:
        raise ValueError(f"packed_bytes must be >= 0, got {packed_bytes}")
    if mode == "none":
        return packed_bytes
    if mode == "ecc":
        return (packed_bytes * 9 + 7) // 8      # ceil(x * 9/8)
    if mode == "tmr":
        return packed_bytes * 3
    raise ValueError(f"unknown protection mode {mode!r}; expected "
                     f"'none' | 'ecc' | 'tmr'")


@dataclasses.dataclass(frozen=True)
class ProtectionDomainPlan:
    """How the arena's bytes map onto independent ECC domains.

    An adjacent multi-bit burst (MBU) flips one bit in each of ``span``
    consecutive bytes. SEC-per-domain ECC corrects at most ONE corrupted
    byte per domain word, so the layout decides correctability:

    * **interleaved** (the planner's choice): byte i belongs to domain
      i mod n_domains, so a burst of span <= n_domains lands at most one
      byte in any domain — correctable by construction.
    * **contiguous** (the naive layout): domains are consecutive
      stripes; a burst lands entirely inside one stripe and puts all
      ``span`` bytes into one domain word — detect-only for span > 1.
    """
    total_bytes: int
    n_domains: int
    interleaved: bool = True

    def __post_init__(self):
        if self.total_bytes < 0:
            raise ValueError("total_bytes must be >= 0")
        if self.n_domains < 1:
            raise ValueError("n_domains must be >= 1")

    def domain_of(self, byte: int) -> int:
        if not (0 <= byte < max(self.total_bytes, 1)):
            raise ValueError(f"byte {byte} outside arena "
                             f"[0, {self.total_bytes})")
        if self.interleaved:
            return byte % self.n_domains
        stripe = max(1, -(-self.total_bytes // self.n_domains))
        return min(byte // stripe, self.n_domains - 1)

    def domains_hit(self, offset: int, span: int) -> Dict[int, int]:
        """domain -> corrupted-byte count for a burst at ``offset``."""
        hits: Dict[int, int] = {}
        for b in range(offset, min(offset + span, self.total_bytes)):
            d = self.domain_of(b)
            hits[d] = hits.get(d, 0) + 1
        return hits

    def worst_hit(self, span: int) -> int:
        """Max bytes any single domain absorbs from ANY span-byte burst."""
        span = max(0, min(span, self.total_bytes))
        if span == 0:
            return 0
        if self.interleaved:
            return -(-span // self.n_domains)        # ceil
        stripe = max(1, -(-self.total_bytes // self.n_domains))
        return min(span, stripe)

    def correctable(self, span: int) -> bool:
        """Can SEC-per-domain ECC correct EVERY possible placement of a
        span-byte adjacent burst? (<= 1 corrupted byte per domain.)"""
        return 0 < span and self.worst_hit(span) <= 1


def plan_protection_domains(total_bytes: int, n_domains: int = 4,
                            interleaved: bool = True) -> ProtectionDomainPlan:
    """Plan the arena's ECC-domain layout. The default is interleaved —
    the whole point of the layout pass: one MBU burst of span up to
    ``n_domains`` can only put a single byte in any one domain, keeping
    it SEC-correctable where the contiguous layout would only detect."""
    return ProtectionDomainPlan(total_bytes=total_bytes,
                                n_domains=max(1, n_domains),
                                interleaved=interleaved)
