"""Layer-graph IR for the space use-case networks.

The paper's workflow is graph-centric: Netron to visualize, the Vitis AI
*inspector* to check operator support, ONNX2C to translate for HLS. This
module is the equivalent substrate: a small typed op graph with shape
inference and MAC/parameter accounting (Table I), which the inspector
partitions and the engine executes on either backend.

Ops cover everything the four use cases need: 2-D and 3-D conv/pool,
dense, activations (relu / leaky_relu / sigmoid / softplus / tanh),
flatten / concat / add / mul / exp, comparator (`greater`) and gaussian
sampling — the last two being exactly the ops the paper calls out as
DPU-unsupported.

Two structural kinds support the pass pipeline (core/passes.py,
DESIGN.md §10):

* ``const`` — a compile-time value (``attrs["value"]``), produced by
  constant folding; carries no runtime cost.
* ``fused`` — a compute node (``attrs["base_op"]`` in conv2d/dense) with
  an element-wise epilogue (``attrs["epilogue"]`` in relu/sigmoid) and an
  optional int8 requantize step folded in. Parameters live under the
  original producer's name (``attrs["param_of"]``); shape inference
  delegates to the base op (epilogues are shape-preserving).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

Shape = Tuple[int, ...]

# fused-node epilogue ops must be shape-preserving element-wise ops
FUSABLE_EPILOGUES = ("relu", "sigmoid")

# ops that consume the per-sample RNG stream: their EXECUTION ORDER is
# part of the numerics contract (each one splits the key chain), so no
# pass may add, remove, or reorder them
RANDOM_OPS = frozenset({"sample_normal"})


@dataclasses.dataclass
class Node:
    name: str
    op: str
    inputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # filled by the graph builder
    out_shape: Optional[Shape] = None
    param_count: int = 0
    bias_params: int = 0             # the fp32-resident share of param_count
    macs: int = 0                    # multiply-accumulates
    ops: int = 0                     # total arithmetic ops (paper's metric)


def base_op(node: Node) -> str:
    """The compute op of a node — the wrapped op for ``fused`` nodes."""
    return node.attrs["base_op"] if node.op == "fused" else node.op


def param_node(node: Node) -> str:
    """The name parameters are keyed under (the original producer for a
    fused node, the node itself otherwise)."""
    return node.attrs.get("param_of", node.name)


def node_param_bytes(node: Node, weight_dtype_bytes: int = 4) -> int:
    """One node's parameter footprint with weights at
    ``weight_dtype_bytes`` and biases at fp32 (the Vitis-AI int8 layout
    keeps biases fp32) — the single definition `Graph.param_bytes` and
    the energy model's weight accounting share."""
    return ((node.param_count - node.bias_params) * weight_dtype_bytes
            + node.bias_params * 4)


class Graph:
    """A feed-forward op graph (SSA; multiple inputs, multiple outputs)."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.order: List[str] = []
        self.graph_inputs: Dict[str, Shape] = {}
        self.outputs: List[str] = []

    # -- construction -------------------------------------------------------

    def input(self, name: str, shape: Shape) -> str:
        self.graph_inputs[name] = tuple(shape)
        node = Node(name, "input", [], out_shape=tuple(shape))
        self.nodes[name] = node
        self.order.append(name)
        return name

    def add(self, op: str, inputs: Sequence[str], name: Optional[str] = None,
            **attrs) -> str:
        if name is None:
            # collision-proof auto-naming: the obvious f"{op}_{len(order)}"
            # collides with explicitly-named nodes (a tracer emitting
            # hundreds of auto-named nodes next to user-named outputs hits
            # this immediately) — bump the counter until the name is free
            i = len(self.order)
            name = f"{op}_{i}"
            while name in self.nodes:
                i += 1
                name = f"{op}_{i}"
        if name in self.nodes:
            raise ValueError(f"duplicate node {name}")
        node = Node(name, op, list(inputs), attrs)
        _infer(node, [self.nodes[i] for i in inputs])
        self.nodes[name] = node
        self.order.append(name)
        return name

    def mark_output(self, *names: str) -> None:
        self.outputs.extend(names)

    # -- accounting (Table I) -----------------------------------------------

    @property
    def n_params(self) -> int:
        return sum(n.param_count for n in self.nodes.values())

    @property
    def n_ops(self) -> int:
        return sum(n.ops for n in self.nodes.values())

    @property
    def n_macs(self) -> int:
        return sum(n.macs for n in self.nodes.values())

    def param_bytes(self, dtype_bytes: int = 4,
                    node_dtype_bytes: Optional[Dict[str, int]] = None) -> int:
        """Total parameter footprint. ``node_dtype_bytes`` maps a node
        name to its *weight* width in bytes (e.g. 1 for a PTQ int8 node);
        biases stay fp32 (4 B) — the Vitis-AI layout. Nodes absent from
        the map are charged at ``dtype_bytes``. This is what BRAM
        residency and the `CostSignature` weight-bytes use, so quantized
        models are no longer over-counted at 4 B/param."""
        if not node_dtype_bytes:
            return self.n_params * dtype_bytes
        total = 0
        for n in self.nodes.values():
            wb = node_dtype_bytes.get(n.name)
            if wb is None:
                total += n.param_count * dtype_bytes
            else:
                total += node_param_bytes(n, wb)
        return total

    def clone(self) -> "Graph":
        """Deep-enough copy for pass rewriting: nodes and ordering are
        fresh objects; attrs dicts are copied one level deep."""
        g = Graph(self.name)
        g.graph_inputs = dict(self.graph_inputs)
        g.outputs = list(self.outputs)
        g.order = list(self.order)
        for name, n in self.nodes.items():
            g.nodes[name] = dataclasses.replace(
                n, inputs=list(n.inputs), attrs=dict(n.attrs))
        return g

    def summary(self) -> str:
        lines = [f"Graph {self.name}: {self.n_params:,} params, "
                 f"{self.n_ops:,} ops"]
        for name in self.order:
            n = self.nodes[name]
            label = n.op
            if n.op == "fused":
                label = "+".join([n.attrs["base_op"]]
                                 + list(n.attrs.get("epilogue", ())))
                if n.attrs.get("requant_scale") is not None:
                    label += "+requant"
            lines.append(f"  {name:24s} {label:20s} -> {n.out_shape} "
                         f"params={n.param_count:,} ops={n.ops:,}")
        return "\n".join(lines)


def consumers(graph: Graph) -> Dict[str, List[str]]:
    """node name -> names of the nodes that read it, in graph order."""
    out: Dict[str, List[str]] = {n: [] for n in graph.nodes}
    for name in graph.order:
        for i in graph.nodes[name].inputs:
            out[i].append(name)
    return out


# ---------------------------------------------------------------------------
# Shape inference + op/param accounting
# ---------------------------------------------------------------------------


def _conv_out(size: int, k: int, stride: int, pad: str) -> int:
    if pad == "SAME":
        return -(-size // stride)
    return (size - k) // stride + 1


def _pool_out(size: int, k: int, stride: int) -> int:
    """VALID-window pooling output size — matches `lax.reduce_window`
    execution exactly (including odd spatial dims and stride != kernel;
    the old ``size // stride`` formula diverged whenever k != stride)."""
    if size < k:
        raise ValueError(f"pool kernel {k} exceeds input dim {size}")
    return (size - k) // stride + 1


def _infer(node: Node, ins: List[Node]) -> None:
    """Shape-inference entry point. Every failure names the node and its
    input shapes — a trace of a 200-eqn jaxpr dies with a message that
    points at the offending node, not just the op kind."""
    try:
        _infer_impl(node, ins)
    except ValueError as e:
        shapes = [i.out_shape for i in ins]
        if node.name in str(e):         # already carries full context
            raise
        raise ValueError(
            f"{node.op} node {node.name!r} (input shapes {shapes}): {e}"
        ) from e
    except (KeyError, TypeError, IndexError) as e:
        shapes = [i.out_shape for i in ins]
        raise ValueError(
            f"{node.op} node {node.name!r} (input shapes {shapes}): "
            f"{type(e).__name__}: {e}") from e


def _infer_impl(node: Node, ins: List[Node]) -> None:
    op, a = node.op, node.attrs
    shapes = [i.out_shape for i in ins]

    if op == "conv2d":
        if len(shapes[0]) != 3:
            raise ValueError(
                f"conv2d {node.name!r} needs a rank-3 HWC input, got "
                f"{shapes[0]}")
        (h, w, cin) = shapes[0]
        kh, kw = a["kernel"]
        cout, stride, pad = a["features"], a.get("stride", 1), a.get("padding", "SAME")
        groups = a.get("groups", 1)
        if cin % groups or cout % groups:
            raise ValueError(
                f"conv2d {node.name!r}: groups={groups} must divide both "
                f"cin={cin} and features={cout}")
        ho, wo = _conv_out(h, kh, stride, pad), _conv_out(w, kw, stride, pad)
        if ho <= 0 or wo <= 0:
            raise ValueError(f"conv2d {node.name!r}: kernel ({kh},{kw}) "
                             f"with padding {pad} over {shapes[0]} leaves "
                             "no output")
        node.out_shape = (ho, wo, cout)
        node.param_count = kh * kw * (cin // groups) * cout + cout
        node.bias_params = cout
        node.macs = ho * wo * cout * kh * kw * (cin // groups)
        node.ops = 2 * node.macs + ho * wo * cout
    elif op == "conv3d":
        (d, h, w, cin) = shapes[0]
        kd, kh, kw = a["kernel"]
        cout, stride, pad = a["features"], a.get("stride", 1), a.get("padding", "SAME")
        do, ho, wo = (_conv_out(d, kd, stride, pad), _conv_out(h, kh, stride, pad),
                      _conv_out(w, kw, stride, pad))
        node.out_shape = (do, ho, wo, cout)
        node.param_count = kd * kh * kw * cin * cout + cout
        node.bias_params = cout
        node.macs = do * ho * wo * cout * kd * kh * kw * cin
        node.ops = 2 * node.macs + do * ho * wo * cout
    elif op in ("maxpool2d", "avgpool2d"):
        (h, w, c) = shapes[0]
        k, stride = a["kernel"], a.get("stride", a["kernel"])
        node.out_shape = (_pool_out(h, k, stride), _pool_out(w, k, stride), c)
        node.ops = int(np.prod(node.out_shape)) * k * k
    elif op in ("maxpool3d", "avgpool3d"):
        (d, h, w, c) = shapes[0]
        k, stride = a["kernel"], a.get("stride", a["kernel"])
        node.out_shape = (_pool_out(d, k, stride), _pool_out(h, k, stride),
                          _pool_out(w, k, stride), c)
        node.ops = int(np.prod(node.out_shape)) * k ** 3
    elif op == "dense":
        fout = a["features"]
        if a.get("per_position", False):
            # token-wise projection: matmul over the LAST axis only, all
            # leading (position) axes preserved — the LM QKV/MLP shape
            if len(shapes[0]) < 1:
                raise ValueError(
                    f"dense {node.name!r}: per_position needs a rank>=1 "
                    f"input, got {shapes[0]}")
            fin = int(shapes[0][-1])
            n_pos = int(np.prod(shapes[0][:-1])) if len(shapes[0]) > 1 else 1
            node.out_shape = tuple(shapes[0][:-1]) + (fout,)
            node.macs = n_pos * fin * fout
        else:
            fin = int(np.prod(shapes[0]))
            node.out_shape = (fout,)
            node.macs = fin * fout
        node.param_count = fin * fout + (fout if a.get("bias", True) else 0)
        node.bias_params = fout if a.get("bias", True) else 0
        node.ops = 2 * node.macs + int(np.prod(node.out_shape))
    elif op == "attention":
        # scaled-dot-product attention over per-sample [S, H, hd] tensors:
        # inputs (q, k, v); GQA when Hq is a multiple of Hkv. Output has
        # the query's shape. MACs: QK^T + PV, each Sq*Sk*Hq*hd.
        if len(shapes) != 3:
            raise ValueError(
                f"attention {node.name!r} needs (q, k, v) inputs, got "
                f"{len(shapes)}")
        if any(len(s) != 3 for s in shapes):
            raise ValueError(
                f"attention {node.name!r} needs rank-3 [S,H,hd] inputs, "
                f"got {shapes}")
        (sq, hq, hd), (sk, hkv, hdk) = shapes[0], shapes[1]
        if shapes[2] != shapes[1]:
            raise ValueError(
                f"attention {node.name!r}: k {shapes[1]} and v {shapes[2]} "
                "shapes must match")
        if hdk != hd:
            raise ValueError(
                f"attention {node.name!r}: head dim mismatch q={hd} k={hdk}")
        if hq % hkv:
            raise ValueError(
                f"attention {node.name!r}: query heads {hq} must be a "
                f"multiple of KV heads {hkv}")
        node.out_shape = (sq, hq, hd)
        node.macs = 2 * sq * sk * hq * hd
        # softmax: max/sub/exp/sum/div ≈ 5 ops per score entry
        node.ops = 2 * node.macs + 5 * sq * sk * hq
    elif op == "ssd":
        # chunked state-space (Mamba-2 SSD) scan over per-sample inputs
        # x [S,H,P], B [S,N], C [S,N], dt [S,H]; per-head decay A is the
        # node's parameter vector [H]. Output matches x.
        if len(shapes) != 4:
            raise ValueError(
                f"ssd {node.name!r} needs (x, B, C, dt) inputs, got "
                f"{len(shapes)}")
        (s, h, p) = shapes[0]
        (sb, n) = shapes[1]
        if shapes[2] != shapes[1] or sb != s or shapes[3] != (s, h):
            raise ValueError(
                f"ssd {node.name!r}: inconsistent input shapes {shapes}")
        node.out_shape = (s, h, p)
        node.param_count = h               # A (fp32-resident, like biases)
        node.bias_params = h
        # state update (H*P*N) + output contraction (H*P*N) per step
        node.macs = 2 * s * h * p * n
        # + decay/exp and state blend element-wise work
        node.ops = 2 * node.macs + 3 * s * h * p * n
    elif op == "reshape":
        # static per-sample reshape (attrs["shape"], one -1 allowed) —
        # structural glue between token-major [S,D] and head-major
        # [S,H,hd] layouts; carries no arithmetic cost
        tgt = list(a["shape"])
        n_in = int(np.prod(shapes[0]))
        if tgt.count(-1) > 1:
            raise ValueError(
                f"reshape {node.name!r}: at most one -1 in {tgt}")
        if -1 in tgt:
            rest = int(np.prod([d for d in tgt if d != -1]))
            if rest == 0 or n_in % rest:
                raise ValueError(
                    f"reshape {node.name!r}: cannot infer -1 in {tgt} "
                    f"from {shapes[0]}")
            tgt[tgt.index(-1)] = n_in // rest
        if int(np.prod(tgt)) != n_in:
            raise ValueError(
                f"reshape {node.name!r}: {shapes[0]} has {n_in} elements, "
                f"target {tgt} has {int(np.prod(tgt))}")
        node.out_shape = tuple(int(d) for d in tgt)
    elif op == "flatten":
        node.out_shape = (int(np.prod(shapes[0])),)
    elif op in ("relu", "leaky_relu", "sigmoid", "tanh", "softplus", "exp"):
        node.out_shape = shapes[0]
        node.ops = int(np.prod(shapes[0])) * (4 if op in ("sigmoid", "tanh",
                                                          "softplus") else 1)
    elif op == "concat":
        ax = a.get("axis", -1)
        rank = len(shapes[0])
        if any(len(s) != rank for s in shapes):
            raise ValueError(
                f"concat {node.name!r}: input ranks differ "
                f"({[len(s) for s in shapes]})")
        if not -rank <= ax < rank:
            raise ValueError(f"concat {node.name!r}: axis {ax} out of "
                             f"range for rank-{rank} inputs")
        pos = ax + rank if ax < 0 else ax
        for s in shapes[1:]:
            mismatched = [d for d in range(rank)
                          if d != pos and s[d] != shapes[0][d]]
            if mismatched:
                raise ValueError(
                    f"concat {node.name!r}: non-axis dims differ between "
                    f"{shapes[0]} and {s} (axis={ax})")
        base = list(shapes[0])
        base[pos] = sum(s[pos] for s in shapes)
        node.out_shape = tuple(base)
    elif op in ("add", "mul", "sub"):
        node.out_shape = shapes[0]
        node.ops = int(np.prod(shapes[0]))
    elif op == "greater":
        node.out_shape = shapes[0]
        node.ops = int(np.prod(shapes[0]))
        # threshold constant counts as a parameter (ESPERTA decision level)
        node.param_count = 0
    elif op == "scale_shift":
        # y = x * w + b with per-element params (ESPERTA's tiny regressors)
        node.out_shape = shapes[0]
        n = int(np.prod(shapes[0]))
        node.param_count = 0
        node.ops = 2 * n
    elif op == "sample_normal":
        # z = mu + exp(0.5*logvar) * eps — the VAE tail the paper runs on CPU
        node.out_shape = shapes[0]
        node.ops = 3 * int(np.prod(shapes[0]))
    elif op == "argmax":
        node.out_shape = ()
        node.ops = int(np.prod(shapes[0]))
    elif op == "const":
        node.out_shape = tuple(np.shape(a["value"]))
        node.ops = 0
    elif op == "fused":
        # delegate to the base compute op, then account the epilogue as
        # element-wise ops on the output (requantize is one more op/elt)
        proxy = Node(node.name, a["base_op"], list(node.inputs),
                     {k: v for k, v in a.items()
                      if k not in ("base_op", "epilogue", "param_of",
                                   "requant_scale", "int8_input")})
        _infer(proxy, ins)
        node.out_shape = proxy.out_shape
        node.param_count = proxy.param_count
        node.bias_params = proxy.bias_params
        node.macs = proxy.macs
        n_out = int(np.prod(node.out_shape)) if node.out_shape else 1
        epi_ops = sum(4 if e in ("sigmoid", "tanh", "softplus") else 1
                      for e in a.get("epilogue", ()))
        node.ops = proxy.ops + n_out * epi_ops
        if a.get("requant_scale") is not None:
            node.ops += n_out
    else:
        raise ValueError(f"unknown op {op!r}")
