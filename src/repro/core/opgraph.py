"""Layer-graph IR for the space use-case networks.

The paper's workflow is graph-centric: Netron to visualize, the Vitis AI
*inspector* to check operator support, ONNX2C to translate for HLS. This
module is the equivalent substrate: a small typed op graph with shape
inference and MAC/parameter accounting (Table I), which the inspector
partitions and the engine executes on either backend.

Ops cover everything the four use cases need: 2-D and 3-D conv/pool,
dense, activations (relu / leaky_relu / sigmoid / softplus / tanh),
flatten / concat / add / mul / exp, comparator (`greater`) and gaussian
sampling — the last two being exactly the ops the paper calls out as
DPU-unsupported.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

Shape = Tuple[int, ...]


@dataclasses.dataclass
class Node:
    name: str
    op: str
    inputs: List[str]
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # filled by the graph builder
    out_shape: Optional[Shape] = None
    param_count: int = 0
    macs: int = 0                    # multiply-accumulates
    ops: int = 0                     # total arithmetic ops (paper's metric)


class Graph:
    """A feed-forward op graph (SSA; multiple inputs, multiple outputs)."""

    def __init__(self, name: str):
        self.name = name
        self.nodes: Dict[str, Node] = {}
        self.order: List[str] = []
        self.graph_inputs: Dict[str, Shape] = {}
        self.outputs: List[str] = []

    # -- construction -------------------------------------------------------

    def input(self, name: str, shape: Shape) -> str:
        self.graph_inputs[name] = tuple(shape)
        node = Node(name, "input", [], out_shape=tuple(shape))
        self.nodes[name] = node
        self.order.append(name)
        return name

    def add(self, op: str, inputs: Sequence[str], name: Optional[str] = None,
            **attrs) -> str:
        name = name or f"{op}_{len(self.order)}"
        if name in self.nodes:
            raise ValueError(f"duplicate node {name}")
        node = Node(name, op, list(inputs), attrs)
        _infer(node, [self.nodes[i] for i in inputs])
        self.nodes[name] = node
        self.order.append(name)
        return name

    def mark_output(self, *names: str) -> None:
        self.outputs.extend(names)

    # -- accounting (Table I) -----------------------------------------------

    @property
    def n_params(self) -> int:
        return sum(n.param_count for n in self.nodes.values())

    @property
    def n_ops(self) -> int:
        return sum(n.ops for n in self.nodes.values())

    @property
    def n_macs(self) -> int:
        return sum(n.macs for n in self.nodes.values())

    def param_bytes(self, dtype_bytes: int = 4) -> int:
        return self.n_params * dtype_bytes

    def summary(self) -> str:
        lines = [f"Graph {self.name}: {self.n_params:,} params, "
                 f"{self.n_ops:,} ops"]
        for name in self.order:
            n = self.nodes[name]
            lines.append(f"  {name:24s} {n.op:12s} -> {n.out_shape} "
                         f"params={n.param_count:,} ops={n.ops:,}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Shape inference + op/param accounting
# ---------------------------------------------------------------------------


def _conv_out(size: int, k: int, stride: int, pad: str) -> int:
    if pad == "SAME":
        return -(-size // stride)
    return (size - k) // stride + 1


def _infer(node: Node, ins: List[Node]) -> None:
    op, a = node.op, node.attrs
    shapes = [i.out_shape for i in ins]

    if op == "conv2d":
        (h, w, cin) = shapes[0]
        kh, kw = a["kernel"]
        cout, stride, pad = a["features"], a.get("stride", 1), a.get("padding", "SAME")
        ho, wo = _conv_out(h, kh, stride, pad), _conv_out(w, kw, stride, pad)
        node.out_shape = (ho, wo, cout)
        node.param_count = kh * kw * cin * cout + cout
        node.macs = ho * wo * cout * kh * kw * cin
        node.ops = 2 * node.macs + ho * wo * cout
    elif op == "conv3d":
        (d, h, w, cin) = shapes[0]
        kd, kh, kw = a["kernel"]
        cout, stride, pad = a["features"], a.get("stride", 1), a.get("padding", "SAME")
        do, ho, wo = (_conv_out(d, kd, stride, pad), _conv_out(h, kh, stride, pad),
                      _conv_out(w, kw, stride, pad))
        node.out_shape = (do, ho, wo, cout)
        node.param_count = kd * kh * kw * cin * cout + cout
        node.macs = do * ho * wo * cout * kd * kh * kw * cin
        node.ops = 2 * node.macs + do * ho * wo * cout
    elif op in ("maxpool2d", "avgpool2d"):
        (h, w, c) = shapes[0]
        k, stride = a["kernel"], a.get("stride", a["kernel"])
        node.out_shape = (h // stride, w // stride, c)
        node.ops = int(np.prod(node.out_shape)) * k * k
    elif op in ("maxpool3d", "avgpool3d"):
        (d, h, w, c) = shapes[0]
        k, stride = a["kernel"], a.get("stride", a["kernel"])
        node.out_shape = (d // stride, h // stride, w // stride, c)
        node.ops = int(np.prod(node.out_shape)) * k ** 3
    elif op == "dense":
        fin = int(np.prod(shapes[0]))
        fout = a["features"]
        node.out_shape = (fout,)
        node.param_count = fin * fout + (fout if a.get("bias", True) else 0)
        node.macs = fin * fout
        node.ops = 2 * node.macs + fout
    elif op == "flatten":
        node.out_shape = (int(np.prod(shapes[0])),)
    elif op in ("relu", "leaky_relu", "sigmoid", "tanh", "softplus", "exp"):
        node.out_shape = shapes[0]
        node.ops = int(np.prod(shapes[0])) * (4 if op in ("sigmoid", "tanh",
                                                          "softplus") else 1)
    elif op == "concat":
        ax = a.get("axis", -1)
        base = list(shapes[0])
        base[ax] = sum(s[ax] for s in shapes)
        node.out_shape = tuple(base)
    elif op in ("add", "mul", "sub"):
        node.out_shape = shapes[0]
        node.ops = int(np.prod(shapes[0]))
    elif op == "greater":
        node.out_shape = shapes[0]
        node.ops = int(np.prod(shapes[0]))
        # threshold constant counts as a parameter (ESPERTA decision level)
        node.param_count = 0
    elif op == "scale_shift":
        # y = x * w + b with per-element params (ESPERTA's tiny regressors)
        node.out_shape = shapes[0]
        n = int(np.prod(shapes[0]))
        node.param_count = 0
        node.ops = 2 * n
    elif op == "sample_normal":
        # z = mu + exp(0.5*logvar) * eps — the VAE tail the paper runs on CPU
        node.out_shape = shapes[0]
        node.ops = 3 * int(np.prod(shapes[0]))
    elif op == "argmax":
        node.out_shape = ()
        node.ops = int(np.prod(shapes[0]))
    else:
        raise ValueError(f"unknown op {op!r}")
