"""Degraded-mode fault modeling: SEU injection, in-band self-test
detection, and the recovery ladder (DESIGN.md §13).

Radiation-induced single-event upsets (SEUs) are the dominant on-orbit
failure mode the deployment literature centers on (PAPERS.md: the FPGA
space-accelerator survey and the CubeSat cloud-detection design). The
repo already holds every mechanism a detect -> recover -> resume story
needs — prepacked int8 weight arenas (live, argument-fed buffers on the
compiled plans), golden output digests, modeled cost signatures, and
multi-backend registration — and this module connects them:

* :class:`SEUInjector` — deterministic, seedable bit flips in a plan's
  live :attr:`~repro.core.plan.ExecutionPlan.weight_arena` (the modeled
  DPU weight memory). Because compiled plans consume the arena as a
  RUNTIME argument, a flip corrupts every subsequent dispatch on that
  backend without any re-trace — exactly the silent-corruption regime an
  SEU creates. Flips into host *staging* buffers are also supported;
  they are transient by construction (``stage()`` rewrites every row).
* :class:`GoldenCanary` — one fixed canary batch per armed model, run
  once at arm time against pristine weights to pin a sha256 output
  digest (the serve-time analog of ``tests/golden/``). A self-test
  re-runs the canary and compares digests — bit-exact or corrupt, no
  tolerance band, because the int8 plans are deterministic.
* :class:`FaultController` — the watchdog: injects scheduled faults,
  runs periodic self-tests as LOW-PRIORITY scheduler work (deferred
  while the model's queue is busy, aged in after half a period so
  detection latency stays bounded), prices every test and recovery on
  the virtual clock and the energy ledger, and drives the recovery
  ladder — ``repack`` (restore the arena from pristine host copies,
  re-verify) or ``demote`` (quarantine the primary backend so dispatch
  falls back through the existing multi-backend registration, repair and
  un-quarantine after a watchdog delay). A cost-signature drift report
  (EWMA service estimates vs plan-time modeled latencies) provides the
  complementary always-on detection signal.
* :func:`save_checkpoint` / :func:`load_checkpoint` — the scheduler
  ledger (``state_dict()``) as a single ``.npz``: JSON metadata with
  every ndarray lifted into named entries (``allow_pickle=False`` on
  both sides), so a simulated watchdog reboot restores the accepted
  queues, EWMA state, RNG, and telemetry records and loses zero
  accepted requests.

An unarmed / inert controller (no faults, no self-test period) leaves
``serve_trace`` dispatch-for-dispatch identical to running without one —
``benchmarks/faults.py`` pins that.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import energy as energy_mod

_CANARY_KEY = 20260801          # fixed canary rng: digests must be stable
_ARRAY_TAG = "__array__:"


# ---------------------------------------------------------------------------
# Digests
# ---------------------------------------------------------------------------


def output_digest(outputs: Dict[str, np.ndarray]) -> str:
    """sha256 over (key, shape, dtype, bytes) of every output, sorted by
    key — the bit-exact fingerprint self-tests compare."""
    h = hashlib.sha256()
    for k in sorted(outputs):
        a = np.ascontiguousarray(np.asarray(outputs[k]))
        h.update(k.encode())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# SEU injection
# ---------------------------------------------------------------------------


class SEUInjector:
    """Deterministic seeded single-bit flips in live weight arenas.

    Target selection is weighted by buffer size (a physical SEU is
    equally likely per bit of exposed memory); explicit ``node`` /
    ``byte`` / ``bit`` pin the flip for regression tests."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self.n_flips = 0

    def flip(self, plan, node: Optional[str] = None,
             byte: Optional[int] = None, bit: Optional[int] = None
             ) -> Tuple[str, int, int]:
        """Flip one bit of one weight-arena entry of ``plan`` (in place:
        the entry is replaced by a host round-tripped copy with the bit
        XORed). Returns (node, byte offset, bit index)."""
        arena = plan.weight_arena
        if not arena:
            raise ValueError(
                f"plan {plan.graph.name}/{plan.backend} has no quantized "
                f"weight arena to inject into")
        if node is None:
            names = sorted(arena)
            sizes = np.array([int(np.asarray(arena[n]).nbytes)
                              for n in names], dtype=np.float64)
            node = names[int(self._rng.choice(len(names),
                                              p=sizes / sizes.sum()))]
        arr = np.array(arena[node])            # host copy, contiguous
        flat = arr.view(np.uint8).reshape(-1)
        if byte is None:
            byte = int(self._rng.integers(flat.size))
        if bit is None:
            bit = int(self._rng.integers(8))
        flat[byte] ^= np.uint8(1 << bit)
        import jax.numpy as jnp
        arena[node] = jnp.asarray(arr)
        self.n_flips += 1
        return node, byte, bit

    def flip_staging(self, arena, slot: int = 0) -> Tuple[str, int, int]:
        """Flip one bit in a host staging buffer (transient corruption:
        ``stage()`` rewrites every row of every buffer, so the flip only
        matters if it lands between staging and dispatch)."""
        bufs = arena._bufs[slot]
        name = sorted(bufs)[int(self._rng.integers(len(bufs)))]
        flat = bufs[name].view(np.uint8).reshape(-1)
        byte = int(self._rng.integers(flat.size))
        bit = int(self._rng.integers(8))
        flat[byte] ^= np.uint8(1 << bit)
        self.n_flips += 1
        return name, byte, bit


# ---------------------------------------------------------------------------
# Canaries
# ---------------------------------------------------------------------------


class GoldenCanary:
    """One in-band self-test unit: a fixed canary batch through one
    (model, backend, bottom-rung) pipeline, digest pinned at arm time."""

    def __init__(self, name: str, pipeline,
                 reqs: Sequence[Dict[str, np.ndarray]]):
        self.name = name
        self.pipeline = pipeline
        self.reqs = list(reqs)
        self.cost = pipeline.cost           # modeled canary dispatch cost
        self.digest, self.reference = self._snapshot()

    def _snapshot(self) -> Tuple[str, Dict[str, np.ndarray]]:
        out = self.run()
        return output_digest(out), out

    def run(self) -> Dict[str, np.ndarray]:
        res = self.pipeline.execute_batch(
            self.reqs, rng=jax.random.PRNGKey(_CANARY_KEY))
        return res.outputs

    def check(self) -> Tuple[bool, str]:
        """(passed, observed digest). Bit-exact comparison — any mismatch
        is corruption, by the int8 plans' determinism contract."""
        got = output_digest(self.run())
        return got == self.digest, got


# ---------------------------------------------------------------------------
# The controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault-storm shape. ``fault_times`` pins injections explicitly
    (deterministic storms, the benchmark gates); otherwise a Poisson
    schedule at ``fault_rate`` over ``horizon_s`` is derived from
    ``seed``. ``self_test_period=None`` disables periodic canaries (the
    inert controller the identity gate pins)."""
    seed: int = 0
    fault_times: Tuple[float, ...] = ()
    fault_rate: float = 0.0             # faults / virtual second
    horizon_s: float = 0.0
    self_test_period: Optional[float] = None
    recovery: str = "repack"            # 'repack' | 'demote'
    repair_delay_s: float = 0.05        # demote: watchdog repair latency
    aging_fraction: float = 0.5         # run a busy-deferred test once
                                        # overdue by this fraction of the
                                        # period (bounds detection lag)

    def __post_init__(self):
        if self.recovery not in ("repack", "demote"):
            raise ValueError(
                f"recovery must be repack|demote, got {self.recovery!r}")

    def schedule(self) -> List[float]:
        if self.fault_times:
            return sorted(float(t) for t in self.fault_times)
        if self.fault_rate <= 0.0 or self.horizon_s <= 0.0:
            return []
        rng = np.random.default_rng(self.seed + 1)
        times, t = [], 0.0
        while True:
            t += float(rng.exponential(1.0 / self.fault_rate))
            if t >= self.horizon_s:
                return times
            times.append(t)


@dataclasses.dataclass
class FaultEvent:
    """One injected SEU's lifecycle in the controller's ledger."""
    t_injected: float
    model: str
    node: str
    byte: int
    bit: int
    detected_at: Optional[float] = None
    recovered_at: Optional[float] = None
    action: str = ""                    # 'repack' | 'demote+repack'

    @property
    def detection_latency_s(self) -> Optional[float]:
        return (None if self.detected_at is None
                else self.detected_at - self.t_injected)


@dataclasses.dataclass
class _ArmedModel:
    name: str
    backend: str                        # primary (faultable) backend
    canary: GoldenCanary
    plan: Any                           # the primary backend ExecutionPlan
    next_test: Optional[float]
    repair_at: Optional[float] = None   # pending demote repair


class FaultController:
    """The degraded-mode watchdog ``serve_trace`` ticks every scheduling
    round (see module docstring for the full protocol)."""

    def __init__(self, config: FaultConfig):
        self.config = config
        self.injector = SEUInjector(config.seed)
        self._models: Dict[str, _ArmedModel] = {}
        self._pending: List[float] = config.schedule()
        self.events: List[FaultEvent] = []
        self.energy_j = 0.0                 # self-tests + recoveries
        self.n_self_tests = 0
        self.n_recoveries = 0

    # -- arming --------------------------------------------------------------

    def arm(self, sched, name: str,
            canary_reqs: Sequence[Dict[str, np.ndarray]]) -> None:
        """Arm one registered model: pin its pristine canary digest on
        the primary backend's bottom rung. Must run BEFORE any fault can
        fire (the digest is the recovery reference)."""
        svc = sched._svcs[name]
        backend = svc.backends[0]
        rung = svc.ladder[0]
        pipe = svc.pipelines[backend][rung]
        reqs = (list(canary_reqs) * rung)[:rung]
        canary = GoldenCanary(name, pipe, reqs)
        period = self.config.self_test_period
        self._models[name] = _ArmedModel(
            name=name, backend=backend, canary=canary,
            plan=pipe._plan.plan,
            next_test=None if period is None else period)

    # -- the serve_trace hooks ----------------------------------------------

    def tick(self, sched, now: float) -> float:
        """One watchdog round at virtual time ``now``: inject due
        faults (instantaneous), run due repairs, then run due self-tests
        — each test/recovery advances and returns the clock."""
        while self._pending and self._pending[0] <= now + 1e-12:
            self._inject(self._pending.pop(0))
        for am in self._models.values():
            if am.repair_at is not None and am.repair_at <= now + 1e-12:
                now = self._repair(sched, am, now)
        period = self.config.self_test_period
        if period is None:
            return now
        for am in self._models.values():
            if am.next_test is None or am.repair_at is not None:
                continue                # known-bad: the repair timer owns it
            if am.next_test > now + 1e-12:
                continue
            overdue = now - am.next_test
            busy = sched._svcs[am.name].pick(now) is not None
            if busy and overdue < self.config.aging_fraction * period:
                continue                # low priority: real work first
            now = self._self_test(sched, am, now)
            am.next_test = now + period
        return now

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest pending watchdog instant — what an idle virtual
        clock jumps to (so self-tests run on schedule between bursts)."""
        times = list(self._pending)
        for am in self._models.values():
            if am.repair_at is not None:
                times.append(am.repair_at)
            elif am.next_test is not None:
                times.append(am.next_test)
        future = [t for t in times if t > now + 1e-12]
        return min(future) if future else None

    def finalize(self, sched, now: float) -> float:
        """End-of-stream closing sweep: one self-test per armed model,
        so nothing injected during the final period escapes the ledger.
        A fully inert controller (no faults, no period) does nothing."""
        if not self.events and self.config.self_test_period is None:
            return now
        for am in self._models.values():
            if am.repair_at is not None:
                now = self._repair(sched, am, max(now, am.repair_at))
            now = self._self_test(sched, am, now)
            if am.next_test is not None:
                am.next_test = now + self.config.self_test_period
        return now

    # -- fault lifecycle -----------------------------------------------------

    def _inject(self, t: float) -> None:
        targets = [am for am in self._models.values()
                   if am.plan.weight_arena]
        if not targets:
            raise RuntimeError(
                f"fault due at t={t:.4f}s but no armed model has a "
                f"weight arena; arm() accel models before serving")
        sizes = np.array([sum(int(np.asarray(a).nbytes)
                              for a in am.plan.weight_arena.values())
                          for am in targets], dtype=np.float64)
        am = targets[int(self.injector._rng.choice(
            len(targets), p=sizes / sizes.sum()))]
        node, byte, bit = self.injector.flip(am.plan)
        self.events.append(FaultEvent(t, am.name, node, byte, bit))

    def _run_priced_canary(self, am: _ArmedModel, now: float
                           ) -> Tuple[bool, float]:
        """Run one canary, advancing the clock by its modeled service
        and charging its modeled energy. Returns (passed, new now)."""
        passed, _ = am.canary.check()
        self.n_self_tests += 1
        self.energy_j += am.canary.cost.energy_j
        return passed, now + am.canary.cost.latency_s

    def _self_test(self, sched, am: _ArmedModel, now: float) -> float:
        passed, now = self._run_priced_canary(am, now)
        if passed:
            return now
        for ev in self.events:
            if ev.model == am.name and ev.detected_at is None:
                ev.detected_at = now
        if self.config.recovery == "demote":
            svc = sched._svcs[am.name]
            if len(svc.backends) < 2:
                raise RuntimeError(
                    f"recovery='demote' needs a fallback backend for "
                    f"{am.name!r}; it registered only {svc.backends}")
            svc.quarantined.add(am.backend)
            am.repair_at = now + self.config.repair_delay_s
            return now
        return self._repack(am, now, action="repack")

    def _repack(self, am: _ArmedModel, now: float, action: str) -> float:
        """Restore the whole arena from pristine host copies (scrubbing
        cannot localize the flip), price it, and re-verify bit-exact."""
        nbytes = am.plan.repack_weights()
        hw = energy_mod.BACKEND_HW[am.plan.backend]
        cost = energy_mod.repack_cost(hw, nbytes)
        now += cost.seconds
        self.energy_j += cost.energy_j
        self.n_recoveries += 1
        passed, now = self._run_priced_canary(am, now)
        if not passed:
            raise RuntimeError(
                f"arena re-pack for {am.name!r} did not restore the "
                f"pristine canary digest — host weight copies corrupt?")
        for ev in self.events:
            if ev.model == am.name and ev.recovered_at is None:
                if ev.detected_at is None:
                    # injected between detection and this repack (e.g.
                    # during a demote quarantine): the full-arena scrub
                    # restores it collaterally, and the verification
                    # canary that just passed is its detection record
                    ev.detected_at = now
                ev.recovered_at = now
                ev.action = action
        return now

    def _repair(self, sched, am: _ArmedModel, now: float) -> float:
        now = self._repack(am, now, action="demote+repack")
        sched._svcs[am.name].quarantined.discard(am.backend)
        am.repair_at = None
        return now

    # -- reporting -----------------------------------------------------------

    def drift_report(self, sched) -> Dict[str, Dict[str, float]]:
        """EWMA service estimate vs plan-time modeled latency per armed
        (backend, rung) — the always-on complementary detection signal:
        a hard fault that slows a backend (retries, bus errors) shows up
        as ratio drift even between self-tests. Under ``clock="modeled"``
        every ratio is exactly 1.0 (estimates ARE the signatures)."""
        out: Dict[str, Dict[str, float]] = {}
        for name in self._models:
            svc = sched._svcs[name]
            ratios = {
                f"{b}/b{r}": est / svc.costs[(b, r)].latency_s
                for (b, r), est in svc.est_service.items()
                if svc.costs[(b, r)].latency_s > 0}
            out[name] = ratios
        return out

    def report(self) -> Dict[str, Any]:
        detected = [e for e in self.events if e.detected_at is not None]
        recovered = [e for e in self.events if e.recovered_at is not None]
        return {
            "n_injected": len(self.events),
            "n_detected": len(detected),
            "n_recovered": len(recovered),
            "n_self_tests": self.n_self_tests,
            "n_recoveries": self.n_recoveries,
            "overhead_energy_j": self.energy_j,
            "max_detection_latency_s": max(
                (e.detection_latency_s for e in detected), default=0.0),
            "events": [dataclasses.asdict(e) for e in self.events],
        }


# ---------------------------------------------------------------------------
# Checkpoint files (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _lift_arrays(obj: Any, arrays: Dict[str, np.ndarray]) -> Any:
    """Replace every ndarray in a state tree with an ``__array__:aN``
    placeholder, collecting the arrays — what makes the metadata pure
    JSON and the file loadable with ``allow_pickle=False``."""
    if isinstance(obj, np.ndarray):
        key = f"a{len(arrays)}"
        arrays[key] = obj
        return _ARRAY_TAG + key
    if isinstance(obj, dict):
        return {str(k): _lift_arrays(v, arrays) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_lift_arrays(v, arrays) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def _sink_arrays(obj: Any, data) -> Any:
    if isinstance(obj, str) and obj.startswith(_ARRAY_TAG):
        return data[obj[len(_ARRAY_TAG):]]
    if isinstance(obj, dict):
        return {k: _sink_arrays(v, data) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_sink_arrays(v, data) for v in obj]
    return obj


def save_checkpoint(path: str, state: Dict[str, Any]) -> None:
    """Write a scheduler ``state_dict()`` (or any JSON+ndarray tree) to
    one ``.npz``: ``__meta__`` holds the JSON skeleton, ``aN`` entries
    hold the lifted arrays. No pickling on either side."""
    arrays: Dict[str, np.ndarray] = {}
    meta = _lift_arrays(state, arrays)
    with open(path, "wb") as f:
        np.savez(f, __meta__=np.asarray(json.dumps(meta)), **arrays)


def load_checkpoint(path: str) -> Dict[str, Any]:
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        return _sink_arrays(meta, data)
