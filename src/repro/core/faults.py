"""Degraded-mode fault modeling: SEU injection, in-band self-test
detection, and the recovery ladder (DESIGN.md §13).

Radiation-induced single-event upsets (SEUs) are the dominant on-orbit
failure mode the deployment literature centers on (PAPERS.md: the FPGA
space-accelerator survey and the CubeSat cloud-detection design). The
repo already holds every mechanism a detect -> recover -> resume story
needs — prepacked int8 weight arenas (live, argument-fed buffers on the
compiled plans), golden output digests, modeled cost signatures, and
multi-backend registration — and this module connects them:

* :class:`SEUInjector` — deterministic, seedable bit flips in a plan's
  live :attr:`~repro.core.plan.ExecutionPlan.weight_arena` (the modeled
  DPU weight memory). Because compiled plans consume the arena as a
  RUNTIME argument, a flip corrupts every subsequent dispatch on that
  backend without any re-trace — exactly the silent-corruption regime an
  SEU creates. Flips into host *staging* buffers are also supported;
  they are transient by construction (``stage()`` rewrites every row).
* :class:`GoldenCanary` — one fixed canary batch per armed model, run
  once at arm time against pristine weights to pin a sha256 output
  digest (the serve-time analog of ``tests/golden/``). A self-test
  re-runs the canary and compares digests — bit-exact or corrupt, no
  tolerance band, because the int8 plans are deterministic.
* :class:`FaultController` — the watchdog: injects scheduled faults,
  runs periodic self-tests as LOW-PRIORITY scheduler work (deferred
  while the model's queue is busy, aged in after half a period so
  detection latency stays bounded), prices every test and recovery on
  the virtual clock and the energy ledger, and drives the recovery
  ladder — ``repack`` (restore the arena from pristine host copies,
  re-verify) or ``demote`` (quarantine the primary backend so dispatch
  falls back through the existing multi-backend registration, repair and
  un-quarantine after a watchdog delay). A cost-signature drift report
  (EWMA service estimates vs plan-time modeled latencies) provides the
  complementary always-on detection signal.
* :func:`save_checkpoint` / :func:`load_checkpoint` — the scheduler
  ledger (``state_dict()``) as a single ``.npz``: JSON metadata with
  every ndarray lifted into named entries (``allow_pickle=False`` on
  both sides), so a simulated watchdog reboot restores the accepted
  queues, EWMA state, RNG, and telemetry records and loses zero
  accepted requests.

An unarmed / inert controller (no faults, no self-test period) leaves
``serve_trace`` dispatch-for-dispatch identical to running without one —
``benchmarks/faults.py`` pins that.

The radiation layer (DESIGN.md §16) widens all of this beyond constant-
rate single-bit flips. ``core/radiation.py`` supplies orbit-correlated
:class:`~repro.core.radiation.UpsetEvent` schedules with an upset-class
mixture, and this module handles each class end to end:

* **'single'** — the §13 path: one flipped bit, canary detection,
  repack/demote recovery.
* **'mbu'** — adjacent multi-bit bursts (:meth:`SEUInjector.flip_mbu`):
  one flipped bit in each of ``span`` consecutive bytes. Same canary
  detection; under ECC the burst is correctable iff the interleaved
  protection-domain plan keeps it to one byte per domain.
* **'control'** — upsets OUTSIDE the weight arena: the scheduler's EWMA
  service ladder, a queued request's deadline, a host staging slot, or
  the persisted ``TuningCache`` file. Canaries cannot see these, so the
  controller runs periodic structural self-checks (invariant sweeps) on
  the self-test cadence and restores corrupt control state from an
  internally held ``state_dict()``-style shadow snapshot.

Always-on arena protection is priced, not assumed: ``FaultConfig(
protection='ecc'|'tmr')`` re-prices the armed model's cost signatures
through `energy.protected_signature` (ECC decode drag + scrub power;
TMR footprint/power tripling + vote latency) and schedules periodic
scrub passes; :func:`choose_protection` is the dispatcher-side J/inf
table that trades canary self-test budget against that standing cost as
the orbit's upset rate swings (quiet GCR background vs an SAA pass).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import energy as energy_mod
from repro.core import memory as memory_mod
from repro.core.radiation import CONTROL_TARGETS, UpsetEvent

_CANARY_KEY = 20260801          # fixed canary rng: digests must be stable
_ARRAY_TAG = "__array__:"


# ---------------------------------------------------------------------------
# Digests
# ---------------------------------------------------------------------------


def output_digest(outputs: Dict[str, np.ndarray]) -> str:
    """sha256 over (key, shape, dtype, bytes) of every output, sorted by
    key — the bit-exact fingerprint self-tests compare."""
    h = hashlib.sha256()
    for k in sorted(outputs):
        a = np.ascontiguousarray(np.asarray(outputs[k]))
        h.update(k.encode())
        h.update(str(a.shape).encode())
        h.update(str(a.dtype).encode())
        h.update(a.tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------------
# SEU injection
# ---------------------------------------------------------------------------


class SEUInjector:
    """Deterministic seeded single-bit flips in live weight arenas.

    Target selection is weighted by buffer size (a physical SEU is
    equally likely per bit of exposed memory); explicit ``node`` /
    ``byte`` / ``bit`` pin the flip for regression tests."""

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self._rng = np.random.default_rng(self.seed)
        self.n_flips = 0

    def flip(self, plan, node: Optional[str] = None,
             byte: Optional[int] = None, bit: Optional[int] = None
             ) -> Tuple[str, int, int]:
        """Flip one bit of one weight-arena entry of ``plan`` (in place:
        the entry is replaced by a host round-tripped copy with the bit
        XORed). Returns (node, byte offset, bit index)."""
        arena = plan.weight_arena
        if not arena:
            raise ValueError(
                f"plan {plan.graph.name}/{plan.backend} has no quantized "
                f"weight arena to inject into")
        if node is None:
            names = sorted(arena)
            sizes = np.array([int(np.asarray(arena[n]).nbytes)
                              for n in names], dtype=np.float64)
            node = names[int(self._rng.choice(len(names),
                                              p=sizes / sizes.sum()))]
        arr = np.array(arena[node])            # host copy, contiguous
        flat = arr.view(np.uint8).reshape(-1)
        if byte is None:
            byte = int(self._rng.integers(flat.size))
        if bit is None:
            bit = int(self._rng.integers(8))
        flat[byte] ^= np.uint8(1 << bit)
        import jax.numpy as jnp
        arena[node] = jnp.asarray(arr)
        self.n_flips += 1
        return node, byte, bit

    def flip_mbu(self, plan, span: int, node: Optional[str] = None,
                 byte: Optional[int] = None) -> Tuple[str, int, int]:
        """Adjacent multi-bit burst: flip one bit in each of ``span``
        CONSECUTIVE bytes of one weight-arena entry (a single heavy-ion
        track clipping a row of cells). The burst is clamped to the
        entry, so it never wraps across arena entries — which is what
        makes byte-interleaved ECC domains effective against it.
        Returns (node, first byte offset, span actually flipped)."""
        if span < 1:
            raise ValueError(f"MBU span must be >= 1, got {span}")
        arena = plan.weight_arena
        if not arena:
            raise ValueError(
                f"plan {plan.graph.name}/{plan.backend} has no quantized "
                f"weight arena to inject into")
        if node is None:
            names = sorted(arena)
            sizes = np.array([int(np.asarray(arena[n]).nbytes)
                              for n in names], dtype=np.float64)
            node = names[int(self._rng.choice(len(names),
                                              p=sizes / sizes.sum()))]
        arr = np.array(arena[node])
        flat = arr.view(np.uint8).reshape(-1)
        span = min(int(span), flat.size)
        if byte is None:
            byte = int(self._rng.integers(flat.size - span + 1))
        byte = min(int(byte), flat.size - span)
        for i in range(span):
            flat[byte + i] ^= np.uint8(1 << int(self._rng.integers(8)))
        import jax.numpy as jnp
        arena[node] = jnp.asarray(arr)
        self.n_flips += span
        return node, byte, span

    def flip_staging(self, arena, slot: int = 0) -> Tuple[str, int, int]:
        """Flip one bit in a host staging buffer (transient corruption:
        ``stage()`` rewrites every row of every buffer, so the flip only
        matters if it lands between staging and dispatch)."""
        bufs = arena._bufs[slot]
        name = sorted(bufs)[int(self._rng.integers(len(bufs)))]
        flat = bufs[name].view(np.uint8).reshape(-1)
        byte = int(self._rng.integers(flat.size))
        bit = int(self._rng.integers(8))
        flat[byte] ^= np.uint8(1 << bit)
        self.n_flips += 1
        return name, byte, bit


# ---------------------------------------------------------------------------
# Canaries
# ---------------------------------------------------------------------------


class GoldenCanary:
    """One in-band self-test unit: a fixed canary batch through one
    (model, backend, bottom-rung) pipeline, digest pinned at arm time."""

    def __init__(self, name: str, pipeline,
                 reqs: Sequence[Dict[str, np.ndarray]]):
        self.name = name
        self.pipeline = pipeline
        self.reqs = list(reqs)
        self.cost = pipeline.cost           # modeled canary dispatch cost
        self.digest, self.reference = self._snapshot()

    def _snapshot(self) -> Tuple[str, Dict[str, np.ndarray]]:
        out = self.run()
        return output_digest(out), out

    def run(self) -> Dict[str, np.ndarray]:
        res = self.pipeline.execute_batch(
            self.reqs, rng=jax.random.PRNGKey(_CANARY_KEY))
        return res.outputs

    def check(self) -> Tuple[bool, str]:
        """(passed, observed digest). Bit-exact comparison — any mismatch
        is corruption, by the int8 plans' determinism contract."""
        got = output_digest(self.run())
        return got == self.digest, got


# ---------------------------------------------------------------------------
# The controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault-storm shape. In precedence order: ``upsets`` pins a typed
    orbit-aware schedule (what ``RadiationEnvironment.sample_upsets``
    produces — mixed single/MBU/control classes); ``fault_times`` pins
    plain single-bit injections (deterministic storms, the §13 benchmark
    gates); otherwise a Poisson schedule at ``fault_rate`` over
    ``horizon_s`` is derived from ``seed``. ``self_test_period=None``
    disables periodic canaries AND the control-path structural checks
    that ride the same cadence (the inert controller the identity gate
    pins). ``protection`` prices always-on arena hardening (DESIGN.md
    §16) into the armed models' cost signatures and schedules scrubs."""
    seed: int = 0
    fault_times: Tuple[float, ...] = ()
    fault_rate: float = 0.0             # faults / virtual second
    horizon_s: float = 0.0
    self_test_period: Optional[float] = None
    recovery: str = "repack"            # 'repack' | 'demote'
    repair_delay_s: float = 0.05        # demote: watchdog repair latency
    aging_fraction: float = 0.5         # run a busy-deferred test once
                                        # overdue by this fraction of the
                                        # period (bounds detection lag)
    upsets: Tuple[UpsetEvent, ...] = ()  # typed orbit-aware schedule
    protection: str = "none"            # 'none' (canary-only) | 'ecc' | 'tmr'
    scrub_period_s: float = 0.05        # ECC/TMR background scrub cadence
    interleave_domains: int = 4         # ECC domains, byte-interleaved:
                                        # an MBU of span <= this corrects

    def __post_init__(self):
        if self.recovery not in ("repack", "demote"):
            raise ValueError(
                f"recovery must be repack|demote, got {self.recovery!r}")
        if self.protection not in energy_mod.PROTECTION_MODES:
            raise ValueError(
                f"protection must be one of "
                f"{energy_mod.PROTECTION_MODES}, got {self.protection!r}")
        if self.fault_rate < 0.0:
            raise ValueError(f"fault_rate must be >= 0, "
                             f"got {self.fault_rate}")
        if self.horizon_s < 0.0:
            raise ValueError(f"horizon_s must be >= 0, "
                             f"got {self.horizon_s}")
        if self.scrub_period_s <= 0.0:
            raise ValueError(f"scrub_period_s must be > 0, "
                             f"got {self.scrub_period_s}")
        if self.interleave_domains < 1:
            raise ValueError(f"interleave_domains must be >= 1, "
                             f"got {self.interleave_domains}")
        object.__setattr__(self, "fault_times", tuple(self.fault_times))
        object.__setattr__(self, "upsets", tuple(self.upsets))
        # a half-specified Poisson storm used to yield a silently empty
        # schedule; name the missing field instead
        if not self.fault_times and not self.upsets:
            if self.fault_rate > 0.0 and self.horizon_s <= 0.0:
                raise ValueError(
                    f"FaultConfig: fault_rate={self.fault_rate:g} > 0 "
                    f"but horizon_s == 0, so the Poisson schedule would "
                    f"be silently empty — set the missing field "
                    f"'horizon_s' to the virtual-time span the storm "
                    f"should cover")
            if self.horizon_s > 0.0 and self.fault_rate <= 0.0:
                raise ValueError(
                    f"FaultConfig: horizon_s={self.horizon_s:g} > 0 but "
                    f"fault_rate == 0, so the Poisson schedule would be "
                    f"silently empty — set the missing field "
                    f"'fault_rate' (faults / virtual second), or drop "
                    f"horizon_s")

    def schedule(self) -> List[float]:
        if self.fault_times:
            return sorted(float(t) for t in self.fault_times)
        if self.fault_rate <= 0.0 or self.horizon_s <= 0.0:
            return []
        rng = np.random.default_rng(self.seed + 1)
        times, t = [], 0.0
        while True:
            t += float(rng.exponential(1.0 / self.fault_rate))
            if t >= self.horizon_s:
                return times
            times.append(t)

    def upset_schedule(self) -> List[UpsetEvent]:
        """The typed schedule the controller consumes: explicit
        ``upsets`` when given, else every ``schedule()`` time as a
        single-bit upset (the §13 behavior, unchanged)."""
        if self.upsets:
            return sorted(self.upsets, key=lambda ev: ev.t)
        return [UpsetEvent(t) for t in self.schedule()]


@dataclasses.dataclass
class FaultEvent:
    """One injected upset's lifecycle in the controller's ledger.

    ``kind`` / ``span`` / ``target`` carry the radiation layer's upset
    class (DESIGN.md §16); the §13 single-bit defaults keep old ledgers
    readable. ``action`` records how it closed: 'repack' /
    'demote+repack' (canary-detected arena faults), 'ecc-correct' /
    'tmr-mask' (protection absorbed it at injection), 'scrub+repack'
    (ECC-uncorrectable burst caught by the background scrub),
    'control-restore' / 'control-rebuild' / 'control-rewrite' /
    'control-selfheal' (structural check repaired — or verified already
    overwritten — scheduler/tuning state), 'transient' (staging flip,
    overwritten by the next stage())."""
    t_injected: float
    model: str
    node: str
    byte: int
    bit: int
    detected_at: Optional[float] = None
    recovered_at: Optional[float] = None
    action: str = ""
    kind: str = "single"                # 'single' | 'mbu' | 'control'
    span: int = 1                       # MBU adjacent-byte burst width
    target: str = ""                    # control subsystem hit

    @property
    def detection_latency_s(self) -> Optional[float]:
        return (None if self.detected_at is None
                else self.detected_at - self.t_injected)


@dataclasses.dataclass
class _ArmedModel:
    name: str
    backend: str                        # primary (faultable) backend
    canary: GoldenCanary
    plan: Any                           # the primary backend ExecutionPlan
    next_test: Optional[float]
    repair_at: Optional[float] = None   # pending demote repair
    next_scrub: Optional[float] = None  # ECC/TMR background scrub timer
    protection_cost: Any = None         # energy.ProtectionCost when armed
                                        # under protection != 'none'
    domains: Any = None                 # memory.ProtectionDomainPlan (ECC
                                        # MBU correctability)


class FaultController:
    """The degraded-mode watchdog ``serve_trace`` ticks every scheduling
    round (see module docstring for the full protocol)."""

    # modeled cost of one structural control-state sweep (per armed
    # model): a CPU-side invariant walk over the ladder, the queues, and
    # the tuning cache — small next to a canary dispatch
    CONTROL_CHECK_S = 2e-5

    def __init__(self, config: FaultConfig):
        self.config = config
        self.injector = SEUInjector(config.seed)
        self._models: Dict[str, _ArmedModel] = {}
        self._pending: List[UpsetEvent] = config.upset_schedule()
        self.events: List[FaultEvent] = []
        self.energy_j = 0.0                 # self-tests + recoveries
        self.n_self_tests = 0
        self.n_recoveries = 0
        # radiation-layer telemetry (DESIGN.md §16)
        self.n_control_checks = 0
        self.n_scrubs = 0
        self.n_corrected = 0                # ECC-corrected + TMR-masked
        self._next_control_check: Optional[float] = None
        self._shadow: Dict[str, Dict[str, Any]] = {}   # control snapshots
        self._tuning_cache = None

    # -- arming --------------------------------------------------------------

    def arm(self, sched, name: str,
            canary_reqs: Sequence[Dict[str, np.ndarray]]) -> None:
        """Arm one registered model: pin its pristine canary digest on
        the primary backend's bottom rung. Must run BEFORE any fault can
        fire (the digest is the recovery reference).

        Under ``protection != 'none'`` this also applies the protected
        cost signatures to the model's primary backend (through
        ``sched.apply_protection``), plans the arena's byte-interleaved
        ECC domains, and starts the background scrub timer; and it
        snapshots the model's control state as the structural checks'
        restore point."""
        svc = sched._svcs[name]
        backend = svc.backends[0]
        rung = svc.ladder[0]
        pipe = svc.pipelines[backend][rung]
        reqs = (list(canary_reqs) * rung)[:rung]
        canary = GoldenCanary(name, pipe, reqs)
        period = self.config.self_test_period
        am = _ArmedModel(
            name=name, backend=backend, canary=canary,
            plan=pipe._plan.plan,
            next_test=None if period is None else period)
        prot = self.config.protection
        arena_bytes = sum(int(np.asarray(a).nbytes)
                          for a in am.plan.weight_arena.values())
        if prot != "none" and arena_bytes > 0:
            am.domains = memory_mod.plan_protection_domains(
                arena_bytes, self.config.interleave_domains)
            hw = energy_mod.BACKEND_HW[backend]
            am.protection_cost = energy_mod.protection_cost(
                hw, arena_bytes, prot, self.config.scrub_period_s)
            sched.apply_protection(name, prot, {
                (backend, r): energy_mod.protected_signature(
                    svc.costs[(backend, r)], hw, am.protection_cost)
                for r in svc.ladder})
            am.next_scrub = self.config.scrub_period_s
        self._models[name] = am
        self._shadow[name] = self._control_snapshot(svc)
        if period is not None and self._next_control_check is None:
            self._next_control_check = period

    def attach_tuning_cache(self, cache) -> None:
        """Register a persisted :class:`~repro.core.autotune.TuningCache`
        as a control-path fault target: 'tuning' upsets corrupt its file
        on disk, and the structural check validates/rewrites it."""
        self._tuning_cache = cache

    # -- the serve_trace hooks ----------------------------------------------

    def tick(self, sched, now: float) -> float:
        """One watchdog round at virtual time ``now``: inject due
        upsets (instantaneous), run due repairs, due background scrubs,
        due self-tests, and the due control-state structural check —
        each test/scrub/recovery advances and returns the clock."""
        while self._pending and self._pending[0].t <= now + 1e-12:
            self._inject(sched, self._pending.pop(0))
        for am in self._models.values():
            if am.repair_at is not None and am.repair_at <= now + 1e-12:
                now = self._repair(sched, am, now)
        for am in self._models.values():
            if am.next_scrub is not None and am.next_scrub <= now + 1e-12:
                now = self._scrub(am, now)
                am.next_scrub = now + self.config.scrub_period_s
        period = self.config.self_test_period
        if period is None:
            return now
        for am in self._models.values():
            if am.next_test is None or am.repair_at is not None:
                continue                # known-bad: the repair timer owns it
            if am.next_test > now + 1e-12:
                continue
            overdue = now - am.next_test
            busy = sched._svcs[am.name].pick(now) is not None
            if busy and overdue < self.config.aging_fraction * period:
                continue                # low priority: real work first
            now = self._self_test(sched, am, now)
            am.next_test = now + period
        if (self._next_control_check is not None
                and self._next_control_check <= now + 1e-12):
            now = self._control_check(sched, now)
            self._next_control_check = now + period
        return now

    def next_event_time(self, now: float) -> Optional[float]:
        """Earliest pending watchdog instant — what an idle virtual
        clock jumps to (so self-tests run on schedule between bursts)."""
        times = [ev.t for ev in self._pending]
        if self._next_control_check is not None:
            times.append(self._next_control_check)
        for am in self._models.values():
            if am.next_scrub is not None:
                times.append(am.next_scrub)
            if am.repair_at is not None:
                times.append(am.repair_at)
            elif am.next_test is not None:
                times.append(am.next_test)
        future = [t for t in times if t > now + 1e-12]
        return min(future) if future else None

    def finalize(self, sched, now: float) -> float:
        """End-of-stream closing sweep: one scrub (where protected) and
        one self-test per armed model, plus one structural control
        check, so nothing injected during the final period escapes the
        ledger. A fully inert controller (no faults, no period, no
        protection) does nothing."""
        if (not self.events and self.config.self_test_period is None
                and self._next_control_check is None
                and all(am.next_scrub is None
                        for am in self._models.values())):
            return now
        for am in self._models.values():
            if am.repair_at is not None:
                now = self._repair(sched, am, max(now, am.repair_at))
            if am.next_scrub is not None:
                now = self._scrub(am, now)
                am.next_scrub = now + self.config.scrub_period_s
            now = self._self_test(sched, am, now)
            if am.next_test is not None:
                am.next_test = now + self.config.self_test_period
        open_control = any(e.kind == "control" and e.recovered_at is None
                           for e in self.events)
        if self._next_control_check is not None or open_control:
            now = self._control_check(sched, now)
            if self._next_control_check is not None:
                self._next_control_check = (
                    now + self.config.self_test_period)
        return now

    # -- fault lifecycle -----------------------------------------------------

    def _inject(self, sched, ev: UpsetEvent) -> None:
        """Land one due upset. Arena classes ('single'/'mbu') go through
        the protection stack: TMR masks everything (majority vote),
        interleaved-domain ECC corrects on access anything that puts at
        most one byte per domain, and what remains corrupts the live
        arena for the canary (or, under ECC, the scrub) to catch.
        'control' upsets corrupt scheduler / staging / tuning state."""
        if ev.kind == "control":
            self._inject_control(sched, ev)
            return
        targets = [am for am in self._models.values()
                   if am.plan.weight_arena]
        if not targets:
            raise RuntimeError(
                f"fault due at t={ev.t:.4f}s but no armed model has a "
                f"weight arena; arm() accel models before serving")
        sizes = np.array([sum(int(np.asarray(a).nbytes)
                              for a in am.plan.weight_arena.values())
                          for am in targets], dtype=np.float64)
        am = targets[int(self.injector._rng.choice(
            len(targets), p=sizes / sizes.sum()))]
        prot = self.config.protection
        if prot == "tmr" and am.protection_cost is not None:
            # two pristine copies outvote the hit copy on every access;
            # the periodic scrub resyncs the diverged copy in background
            self.events.append(FaultEvent(
                ev.t, am.name, node="(tmr-masked)", byte=-1, bit=-1,
                detected_at=ev.t, recovered_at=ev.t, action="tmr-mask",
                kind=ev.kind, span=ev.span))
            self.n_corrected += 1
            return
        if (prot == "ecc" and am.domains is not None
                and am.domains.correctable(ev.span)):
            # <= 1 corrupted byte per interleaved domain: SEC corrects
            # on the next access; the ledger stamps it at injection
            self.events.append(FaultEvent(
                ev.t, am.name, node="(ecc-corrected)", byte=-1, bit=-1,
                detected_at=ev.t, recovered_at=ev.t, action="ecc-correct",
                kind=ev.kind, span=ev.span))
            self.n_corrected += 1
            return
        # raw corruption: unprotected, or an ECC-uncorrectable burst
        # (span wider than the domain interleave — detect-only)
        if ev.kind == "mbu":
            node, byte, span = self.injector.flip_mbu(am.plan, ev.span)
            self.events.append(FaultEvent(
                ev.t, am.name, node, byte, bit=-1, kind="mbu", span=span))
        else:
            node, byte, bit = self.injector.flip(am.plan)
            self.events.append(FaultEvent(ev.t, am.name, node, byte, bit))

    def _inject_control(self, sched, ev: UpsetEvent) -> None:
        """Corrupt control-path state: the EWMA service ladder, a queued
        request's deadline, a host staging slot, or the persisted tuning
        cache. Targets that do not exist right now (empty queue, no
        staged buffers, no cache file) fall back to 'ladder' so the
        scheduled upset always lands somewhere real."""
        rng = self.injector._rng
        target = ev.target or CONTROL_TARGETS[
            int(rng.integers(len(CONTROL_TARGETS)))]
        names = sorted(self._models)
        if not names:
            raise RuntimeError(
                f"control fault due at t={ev.t:.4f}s but no model is "
                f"armed; arm() models before serving")
        name = names[int(rng.integers(len(names)))]
        am = self._models[name]
        svc = sched._svcs[name]
        if target == "queue" and not svc.queue:
            target = "ladder"
        if target == "staging":
            pipe = svc.pipelines[am.backend][svc.ladder[0]]
            if not pipe.arena._bufs[0]:
                target = "ladder"
        if target == "tuning":
            cache = self._tuning_cache
            if (cache is None or not getattr(cache, "path", None)
                    or not os.path.exists(cache.path)):
                target = "ladder"

        if target == "ladder":
            keys = sorted(svc.est_service)
            b, r = keys[int(rng.integers(len(keys)))]
            # a high-exponent-bit flip: the estimate explodes, the flush
            # margin with it — batching degrades until the check restores
            svc.est_service[(b, r)] = (
                svc.est_service[(b, r)] * float(2 ** 40))
            self.events.append(FaultEvent(
                ev.t, name, node=f"est_service[{b}/b{r}]", byte=-1,
                bit=-1, kind="control", target="ladder"))
        elif target == "queue":
            idx = int(rng.integers(len(svc.queue)))
            req = svc.queue[idx]
            svc.queue[idx] = dataclasses.replace(
                req, deadline=req.deadline * float(2 ** 40))
            self.events.append(FaultEvent(
                ev.t, name, node=f"queue[rid={req.rid}].deadline",
                byte=-1, bit=-1, kind="control", target="queue"))
        elif target == "staging":
            pipe = svc.pipelines[am.backend][svc.ladder[0]]
            buf, byte, bit = self.injector.flip_staging(pipe.arena)
            # transient by construction: stage() rewrites every row
            # before the next dispatch reads the slot
            self.events.append(FaultEvent(
                ev.t, name, node=f"staging[{buf}]", byte=byte, bit=bit,
                detected_at=ev.t, recovered_at=ev.t, action="transient",
                kind="control", target="staging"))
        else:                                   # tuning
            cache = self._tuning_cache
            with open(cache.path, "rb") as f:
                raw = bytearray(f.read())
            byte = int(rng.integers(len(raw)))
            raw[byte] ^= 1 << int(rng.integers(8))
            with open(cache.path, "wb") as f:
                f.write(bytes(raw))
            self.events.append(FaultEvent(
                ev.t, name, node=f"tuning_cache[{cache.path}]",
                byte=byte, bit=-1, kind="control", target="tuning"))

    @staticmethod
    def _control_snapshot(svc) -> Dict[str, Any]:
        """The structural checks' restore point for one model: the EWMA
        ladder state (what a control upset can silently corrupt and a
        queue rebuild can't re-derive). Refreshed after every passing
        check so measured-clock estimates stay current."""
        return {"est_service": dict(svc.est_service),
                "seeded": set(svc._seeded)}

    def _close_control_events(self, model: Optional[str], target: str,
                              now: float, action: str) -> None:
        for ev in self.events:
            if (ev.kind == "control" and ev.target == target
                    and (model is None or ev.model == model)
                    and ev.recovered_at is None):
                if ev.detected_at is None:
                    ev.detected_at = now
                ev.recovered_at = now
                ev.action = action

    # estimates this far off the modeled signature are structural
    # corruption, not drift: the injected exponent flip is ~2^40, the
    # widest honest measured-vs-modeled scale gap is orders below this
    _EST_BAND = 1e6

    def _control_check(self, sched, now: float) -> float:
        """One structural sweep over every armed model's control state:
        ladder estimates finite/positive/within the plausibility band
        (else restored from the shadow snapshot), queue deadlines
        reconstructible as arrival + deadline_s (else rebuilt), and the
        persisted tuning cache valid JSON of the current schema (else
        rewritten from the in-memory entries). Prices one CPU sweep on
        the clock and the energy ledger; refreshes the shadow from the
        now-verified state."""
        self.n_control_checks += 1
        hw = energy_mod.BACKEND_HW["cpu"]
        dt = self.CONTROL_CHECK_S * max(1, len(self._models))
        self.energy_j += hw.power_busy * dt
        now += dt
        for name, am in self._models.items():
            svc = sched._svcs[name]
            shadow = self._shadow.get(name)
            bad = [k for k, est in svc.est_service.items()
                   if not np.isfinite(est) or est <= 0.0
                   or (svc.costs[k].latency_s > 0.0
                       and not (svc.costs[k].latency_s / self._EST_BAND
                                <= est
                                <= svc.costs[k].latency_s * self._EST_BAND))]
            if bad and shadow is not None:
                svc.est_service = dict(shadow["est_service"])
                svc._seeded = set(shadow["seeded"])
            # open ladder events close either way: restored from the
            # shadow, or verified already overwritten by later EWMA
            # observations (the corrupt value retired out of the system)
            self._close_control_events(
                name, "ladder", now,
                "control-restore" if bad else "control-selfheal")
            rebuilt = False
            for idx, req in enumerate(svc.queue):
                want = req.arrival + svc.deadline_s
                if (not np.isfinite(req.deadline)
                        or abs(req.deadline - want) > 1e-9):
                    svc.queue[idx] = dataclasses.replace(
                        req, deadline=want)
                    rebuilt = True
            self._close_control_events(
                name, "queue", now,
                "control-rebuild" if rebuilt else "control-selfheal")
            self._shadow[name] = self._control_snapshot(svc)
        cache = self._tuning_cache
        if (cache is not None and getattr(cache, "path", None)
                and os.path.exists(cache.path)):
            ok = True
            try:
                with open(cache.path, "r", encoding="utf-8") as f:
                    payload = json.load(f)
                ok = (isinstance(payload, dict)
                      and isinstance(payload.get("entries"), dict))
            except (OSError, ValueError):
                ok = False
            if not ok:
                # the in-memory entries are authoritative: rewrite the
                # file through the cache's own atomic save path
                cache._dirty = True
                cache.save()
            self._close_control_events(
                None, "tuning", now,
                "control-rewrite" if not ok else "control-selfheal")
        return now

    def _scrub(self, am: _ArmedModel, now: float) -> float:
        """One background scrub pass over the protected arena: price the
        sweep, then repair what it found — under ECC an uncorrectable
        burst (span wider than the domain interleave) is detect-only, so
        detection happens HERE and recovery is a full repack; under TMR
        the pass resyncs the diverged copy (events already closed at
        injection by the majority vote)."""
        pcost = am.protection_cost
        self.n_scrubs += 1
        self.energy_j += pcost.scrub_energy_j
        now += pcost.scrub_s
        dirty = [e for e in self.events
                 if e.model == am.name and e.kind in ("single", "mbu")
                 and e.detected_at is None]
        if dirty:
            for e in dirty:
                e.detected_at = now
            now = self._repack(am, now, action="scrub+repack")
        return now

    def _run_priced_canary(self, am: _ArmedModel, now: float
                           ) -> Tuple[bool, float]:
        """Run one canary, advancing the clock by its modeled service
        and charging its modeled energy. Returns (passed, new now)."""
        passed, _ = am.canary.check()
        self.n_self_tests += 1
        self.energy_j += am.canary.cost.energy_j
        return passed, now + am.canary.cost.latency_s

    def _self_test(self, sched, am: _ArmedModel, now: float) -> float:
        passed, now = self._run_priced_canary(am, now)
        if passed:
            return now
        for ev in self.events:
            if ev.model == am.name and ev.detected_at is None:
                ev.detected_at = now
        if self.config.recovery == "demote":
            svc = sched._svcs[am.name]
            if len(svc.backends) < 2:
                raise RuntimeError(
                    f"recovery='demote' needs a fallback backend for "
                    f"{am.name!r}; it registered only {svc.backends}")
            svc.quarantined.add(am.backend)
            am.repair_at = now + self.config.repair_delay_s
            return now
        return self._repack(am, now, action="repack")

    def _repack(self, am: _ArmedModel, now: float, action: str) -> float:
        """Restore the whole arena from pristine host copies (scrubbing
        cannot localize the flip), price it, and re-verify bit-exact."""
        nbytes = am.plan.repack_weights()
        hw = energy_mod.BACKEND_HW[am.plan.backend]
        cost = energy_mod.repack_cost(hw, nbytes)
        now += cost.seconds
        self.energy_j += cost.energy_j
        self.n_recoveries += 1
        passed, now = self._run_priced_canary(am, now)
        if not passed:
            raise RuntimeError(
                f"arena re-pack for {am.name!r} did not restore the "
                f"pristine canary digest — host weight copies corrupt?")
        for ev in self.events:
            if ev.model == am.name and ev.recovered_at is None:
                if ev.detected_at is None:
                    # injected between detection and this repack (e.g.
                    # during a demote quarantine): the full-arena scrub
                    # restores it collaterally, and the verification
                    # canary that just passed is its detection record
                    ev.detected_at = now
                ev.recovered_at = now
                ev.action = action
        return now

    def _repair(self, sched, am: _ArmedModel, now: float) -> float:
        now = self._repack(am, now, action="demote+repack")
        sched._svcs[am.name].quarantined.discard(am.backend)
        am.repair_at = None
        return now

    # -- reporting -----------------------------------------------------------

    def drift_report(self, sched, window_s: Optional[float] = None,
                     now: Optional[float] = None
                     ) -> Dict[str, Dict[str, Optional[float]]]:
        """Observed-vs-modeled service-time ratio per armed (backend,
        rung) — the always-on complementary detection signal: a hard
        fault that slows a backend (retries, bus errors) shows up as
        ratio drift even between self-tests.

        Without a window: EWMA estimate / plan-time modeled latency
        (under ``clock="modeled"`` every ratio is exactly 1.0 —
        estimates ARE the signatures). With ``window_s``: the mean
        service time of dispatches RETIRED inside ``[now - window_s,
        now]`` over the modeled latency, per cell.

        A cell is ``None`` — never nan/inf — when it has no meaningful
        ratio: zero retired dispatches in the window (the 0/0 that used
        to leak out as nan), or a zero modeled latency."""
        out: Dict[str, Dict[str, Optional[float]]] = {}
        for name in self._models:
            svc = sched._svcs[name]
            ratios: Dict[str, Optional[float]] = {}
            if window_s is None:
                for (b, r), est in sorted(svc.est_service.items()):
                    lat = svc.costs[(b, r)].latency_s
                    ratios[f"{b}/b{r}"] = est / lat if lat > 0.0 else None
            else:
                if now is None:
                    done = [d.started + d.service_time
                            for d in sched.dispatches]
                    now = max(done, default=0.0)
                lo = now - window_s
                obs: Dict[Tuple[str, int], List[float]] = {}
                for d in sched.dispatches:
                    retired = d.started + d.service_time
                    if (d.model == name and not d.failed
                            and lo <= retired <= now):
                        obs.setdefault((d.backend, d.rung),
                                       []).append(d.service_time)
                for (b, r) in sorted(svc.costs):
                    lat = svc.costs[(b, r)].latency_s
                    cell = obs.get((b, r))
                    ratios[f"{b}/b{r}"] = (
                        None if not cell or lat <= 0.0
                        else (sum(cell) / len(cell)) / lat)
            out[name] = ratios
        return out

    def report(self) -> Dict[str, Any]:
        detected = [e for e in self.events if e.detected_at is not None]
        recovered = [e for e in self.events if e.recovered_at is not None]
        per_class: Dict[str, Dict[str, Any]] = {}
        for kind in ("single", "mbu", "control"):
            evs = [e for e in self.events if e.kind == kind]
            lats = [e.detection_latency_s for e in evs
                    if e.detected_at is not None]
            per_class[kind] = {
                "n_injected": len(evs),
                "n_detected": sum(1 for e in evs
                                  if e.detected_at is not None),
                "n_recovered": sum(1 for e in evs
                                   if e.recovered_at is not None),
                "max_detection_latency_s": max(lats, default=0.0),
            }
        return {
            "n_injected": len(self.events),
            "n_detected": len(detected),
            "n_recovered": len(recovered),
            "n_self_tests": self.n_self_tests,
            "n_recoveries": self.n_recoveries,
            "n_control_checks": self.n_control_checks,
            "n_scrubs": self.n_scrubs,
            "n_corrected": self.n_corrected,
            "overhead_energy_j": self.energy_j,
            "max_detection_latency_s": max(
                (e.detection_latency_s for e in detected), default=0.0),
            "per_class": per_class,
            "events": [dataclasses.asdict(e) for e in self.events],
        }

    # -- checkpointing -------------------------------------------------------

    def state_dict(self) -> Dict[str, Any]:
        """The controller's restorable state as a JSON-serializable
        tree (save alongside the scheduler's ``state_dict()`` through
        :func:`save_checkpoint`): the pending upset schedule, the event
        ledger, all counters, the injector RNG state, the per-model
        timers, and the control-state shadows. Restoring into a freshly
        armed controller resumes a mid-storm timeline dispatch-for-
        dispatch identically (the §16 watchdog-reboot contract)."""
        return {
            "version": 1,
            "pending": [dataclasses.asdict(ev) for ev in self._pending],
            "events": [dataclasses.asdict(e) for e in self.events],
            "energy_j": float(self.energy_j),
            "n_self_tests": int(self.n_self_tests),
            "n_recoveries": int(self.n_recoveries),
            "n_control_checks": int(self.n_control_checks),
            "n_scrubs": int(self.n_scrubs),
            "n_corrected": int(self.n_corrected),
            "n_flips": int(self.injector.n_flips),
            "rng_state": self.injector._rng.bit_generator.state,
            "next_control_check": self._next_control_check,
            "models": {name: {"next_test": am.next_test,
                              "repair_at": am.repair_at,
                              "next_scrub": am.next_scrub}
                       for name, am in self._models.items()},
            "shadow": {name: {
                "est_service": [[b, r, t] for (b, r), t
                                in sorted(sh["est_service"].items())],
                "seeded": [[b, r] for (b, r) in sorted(sh["seeded"])]}
                for name, sh in self._shadow.items()},
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore :meth:`state_dict` into THIS controller. Requires the
        same models already armed (a reboot re-arms against pristine
        weights first — re-packing the arena and re-pinning the canary —
        then the ledger restore resumes the storm timeline)."""
        if state.get("version") != 1:
            raise ValueError(f"unsupported controller checkpoint version "
                             f"{state.get('version')!r}")
        if set(state["models"]) != set(self._models):
            raise ValueError(
                f"checkpoint arms {sorted(state['models'])} but this "
                f"controller arms {sorted(self._models)}")
        self._pending = [UpsetEvent(t=float(ev["t"]), kind=str(ev["kind"]),
                                    span=int(ev["span"]),
                                    target=str(ev["target"]))
                         for ev in state["pending"]]
        self.events = [FaultEvent(**e) for e in state["events"]]
        self.energy_j = float(state["energy_j"])
        self.n_self_tests = int(state["n_self_tests"])
        self.n_recoveries = int(state["n_recoveries"])
        self.n_control_checks = int(state["n_control_checks"])
        self.n_scrubs = int(state["n_scrubs"])
        self.n_corrected = int(state["n_corrected"])
        self.injector.n_flips = int(state["n_flips"])
        self.injector._rng.bit_generator.state = state["rng_state"]
        self._next_control_check = state["next_control_check"]
        for name, ms in state["models"].items():
            am = self._models[name]
            am.next_test = ms["next_test"]
            am.repair_at = ms["repair_at"]
            am.next_scrub = ms["next_scrub"]
        self._shadow = {name: {
            "est_service": {(str(b), int(r)): float(t)
                            for b, r, t in sh["est_service"]},
            "seeded": {(str(b), int(r)) for b, r in sh["seeded"]}}
            for name, sh in state["shadow"].items()}


# ---------------------------------------------------------------------------
# Protection-mode selection (DESIGN.md §16)
# ---------------------------------------------------------------------------


def choose_protection(backend: str, sig, packed_bytes: int, canary_cost,
                      upset_rate: float, p_uncorrectable: float = 0.0,
                      self_test_period: float = 0.05,
                      scrub_period_s: float = 0.05,
                      throughput_inf_s: Optional[float] = None
                      ) -> Tuple[str, Dict[str, float]]:
    """The dispatcher's protection trade at a given arena upset rate:
    effective modeled J/inference of each mode, standing costs folded
    in. Returns ``(argmin mode, {mode: effective J/inf})``.

    * **'none'** (canary-only): the unprotected dispatch energy, plus a
      standing canary budget (one canary dispatch per self-test period),
      plus per-upset damage — a full arena repack AND the inferences
      served corrupt until detection (half a period's worth, wasted).
    * **'ecc'**: the decode-drag-priced dispatch energy plus standing
      scrub power; only the ``p_uncorrectable`` burst fraction still
      costs a repack (detected within a scrub period).
    * **'tmr'**: the vote-priced, power-tripled dispatch energy plus
      scrub power; every arena upset is masked — no exposure at all.

    In a quiet orbit the canary budget undercuts any always-on
    protection; inside an SAA pass the per-upset damage term swamps it
    and the ordering flips — the regime switch `benchmarks/radiation.py`
    gates on. ``upset_rate`` is the ARENA upset rate (upsets/virtual s;
    control-path upsets cost the same in every mode and cancel).
    ``throughput_inf_s`` defaults to the signature's saturated rate."""
    if self_test_period <= 0.0:
        raise ValueError("self_test_period must be > 0")
    if upset_rate < 0.0 or not 0.0 <= p_uncorrectable <= 1.0:
        raise ValueError("need upset_rate >= 0 and p_uncorrectable in "
                         "[0, 1]")
    hw = energy_mod.BACKEND_HW[backend]
    if throughput_inf_s is None:
        throughput_inf_s = sig.batch / sig.latency_s
    repack = energy_mod.repack_cost(hw, packed_bytes)
    table: Dict[str, float] = {}
    for mode in energy_mod.PROTECTION_MODES:
        pcost = energy_mod.protection_cost(hw, packed_bytes, mode,
                                           scrub_period_s)
        psig = energy_mod.protected_signature(sig, hw, pcost)
        standing_w = pcost.scrub_power_w
        if mode == "none":
            standing_w += canary_cost.energy_j / self_test_period
            exposure_j = (0.5 * self_test_period * throughput_inf_s
                          * sig.j_per_inference)
            standing_w += upset_rate * (repack.energy_j + exposure_j)
        elif mode == "ecc":
            exposure_j = (0.5 * scrub_period_s * throughput_inf_s
                          * sig.j_per_inference)
            standing_w += (upset_rate * p_uncorrectable
                           * (repack.energy_j + exposure_j))
        table[mode] = (psig.j_per_inference
                       + standing_w / throughput_inf_s)
    best = min(energy_mod.PROTECTION_MODES, key=lambda m: table[m])
    return best, table


# ---------------------------------------------------------------------------
# Checkpoint files (DESIGN.md §13)
# ---------------------------------------------------------------------------


def _lift_arrays(obj: Any, arrays: Dict[str, np.ndarray]) -> Any:
    """Replace every ndarray in a state tree with an ``__array__:aN``
    placeholder, collecting the arrays — what makes the metadata pure
    JSON and the file loadable with ``allow_pickle=False``."""
    if isinstance(obj, np.ndarray):
        key = f"a{len(arrays)}"
        arrays[key] = obj
        return _ARRAY_TAG + key
    if isinstance(obj, dict):
        return {str(k): _lift_arrays(v, arrays) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_lift_arrays(v, arrays) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    return obj


def _sink_arrays(obj: Any, data) -> Any:
    if isinstance(obj, str) and obj.startswith(_ARRAY_TAG):
        return data[obj[len(_ARRAY_TAG):]]
    if isinstance(obj, dict):
        return {k: _sink_arrays(v, data) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_sink_arrays(v, data) for v in obj]
    return obj


def save_checkpoint(path: str, state: Dict[str, Any]) -> None:
    """Write a scheduler ``state_dict()`` (or any JSON+ndarray tree) to
    one ``.npz``: ``__meta__`` holds the JSON skeleton, ``aN`` entries
    hold the lifted arrays. No pickling on either side."""
    arrays: Dict[str, np.ndarray] = {}
    meta = _lift_arrays(state, arrays)
    with open(path, "wb") as f:
        np.savez(f, __meta__=np.asarray(json.dumps(meta)), **arrays)


def load_checkpoint(path: str) -> Dict[str, Any]:
    with np.load(path, allow_pickle=False) as data:
        meta = json.loads(str(data["__meta__"]))
        return _sink_arrays(meta, data)
