"""Plan-time kernel autotuner + prepacked weight arenas (DESIGN.md §11).

The paper's DPU/HLS gap (34.16x vs 5.4x over the ARM baseline) is a
*schedule* gap: the DPU compiler picks tile shapes per layer and keeps
weights resident in a packed on-chip layout, while the naive HLS designs
fix one unsearched schedule per network. Our kernels had the same
blind spot — `kernels/int8_matmul.py` hard-coded heuristic blocks
(`heuristic_blocks`) and every call re-padded weight tiles. This module
moves both decisions to plan time:

* **Autotuner** — at ``ExecutionPlan.lower()`` time, enumerate candidate
  tile configs per (op, shape, dtype, backend, batch-rung), price each
  with a kernel-level refinement of the `core/energy.py` roofline
  (padded-tile MACs at the backend's sustained rate, a per-grid-step
  sequencer overhead ``HardwareModel.grid_step_s``, and weight restream
  traffic when the packed weights don't fit on-chip), optionally refine
  the top-K by wall-clock measurement, and persist winners to a JSON
  tuning cache keyed by a stable config hash — repeat lowerings (and CI)
  never re-search. The heuristic default is always candidate #0, so a
  tuned pick is *never worse than the default under the same pricer* by
  construction.

* **Prepacked weight arenas** — quantization, tile-alignment padding and
  neutral scale/bias extension move out of the per-call kernel bodies
  into one plan-time prepack producing device-resident, tile-aligned
  buffers (`PackedDense`/`PackedConv`) that the fused kernels consume
  directly (``prepacked=True`` paths). `core/memory.py` residency and
  `energy.weight_bytes` charge the packed (padded) footprint.

Search spaces per kernel kind:

* ``int8_dense`` (accel) — (bm, bn, bk) MXU tile blocks; candidates are
  8-sublane-aligned clamps of {8..1024} per dim, VMEM-feasible only.
* ``int8_conv`` (accel) — rows-per-block (output-row tiling) and
  cout-per-block (output-channel tiling; smaller VMEM weight slice, more
  grid steps).
* ``hls`` (flex) — the dataflow unroll factor the paper's *naive* HLS
  designs never searched: ``u`` parallel MACs/cycle, capped by the
  layer's reduction depth and a 64-lane DSP budget. Execution on this
  substrate is unchanged (XLA already emits its own schedule) — the
  config prices the synthesis-time schedule the flex analog would run,
  which is exactly what the energy-aware dispatcher ranks plans by.

Bit-exactness: integer accumulation is associative and padding lanes are
exact zeros (neutral 1.0 scales / 0.0 biases), so EVERY candidate config
— and the prepacked path — produces bit-identical int8/fp32 outputs to
the heuristic default; the flex configs don't touch execution at all.
`tests/test_autotune.py` pins both.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_mod
from repro.core.opgraph import base_op
from repro.kernels.conv2d import conv_geometry
from repro.kernels.epilogue import pad_channel_params
from repro.kernels.int8_matmul import heuristic_blocks

SCHEMA_VERSION = 1

# candidate pools (clamped/filtered per shape; deterministic order)
DENSE_TILES = (8, 16, 32, 64, 128, 256, 512, 1024)
CONV_ROWS = (1, 2, 4, 8, 16, 32, 64, 128)
CONV_COUT_BLOCKS = (8, 16, 32, 64)
HLS_UNROLLS = (1, 2, 4, 8, 16, 32, 64)
HLS_MAX_UNROLL = 64           # DSP-lane budget of the flex dataflow analog
DEFAULT_CONV_ROWS = 8         # the pre-autotune kernel default
INT8_KINDS = ("int8_dense", "int8_conv")
# LM kernel pools: flash-attention q/k block shapes and the SSD scan's
# chunk length (DESIGN.md §15). 256 is the shipped kernel default.
ATTN_BLOCKS = (64, 128, 256, 512)
DEFAULT_ATTN_BLOCK = 256
SSD_CHUNKS = (32, 64, 128, 256, 512)
DEFAULT_SSD_CHUNK = 256


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Configs and decisions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point in a kernel's schedule space. Unused fields stay at
    their zero/identity defaults (a dense config has no rows_per_block;
    an hls config only has unroll)."""
    bm: int = 0
    bn: int = 0
    bk: int = 0                   # dense reduction block / attention K block
    rows_per_block: int = 0
    cout_per_block: int = 0       # 0 = whole Cout per grid step
    unroll: int = 1
    bq: int = 0                   # attention query block
    chunk: int = 0                # SSD scan chunk length

    def to_dict(self) -> Dict[str, int]:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v not in (0, None)} or {"unroll": 1}

    @classmethod
    def from_dict(cls, d: Dict[str, int]) -> "KernelConfig":
        return cls(**{k: int(v) for k, v in d.items()})


@dataclasses.dataclass(frozen=True)
class TuningDecision:
    """The autotuner's verdict for one node at one batch rung."""
    kind: str                     # 'int8_dense' | 'int8_conv' | 'hls'
    config: KernelConfig
    modeled_s: float              # whole-batch kernel time, chosen config
    default_s: float              # same pricer, heuristic default config
    extra_bytes: float = 0.0      # weight restream DDR traffic (non-resident)
    source: str = "model"         # 'model' | 'measured' | 'cache'

    @property
    def speedup(self) -> float:
        return self.default_s / max(self.modeled_s, 1e-30)


# ---------------------------------------------------------------------------
# Tuning cache (JSON, keyed by a stable config hash)
# ---------------------------------------------------------------------------


def cache_key(kind: str, sig: Tuple, backend: str, hw,
              fixed: Optional[KernelConfig] = None,
              resident: bool = True, measured: bool = False) -> str:
    """Stable key for one (op, shape, dtype, backend, batch-rung) search:
    shape signature + backend hardware constants the pricer reads +
    search-space schema version + any fixed-layout constraint + the
    plan's weight-residency flag (an input to the restream pricing) +
    whether the measured refinement ran (wall-clock winners may differ
    from model winners and must never be served into model-only runs).
    Anything that could change the winner — or the stored prices —
    changes the key, so a stale cache can never serve a pick the current
    pricer wouldn't make."""
    payload = {
        "v": SCHEMA_VERSION,
        "kind": kind,
        "sig": list(sig),
        "backend": backend,
        "hw": [hw.name, hw.peak_ops_int8, hw.peak_flops_f32, hw.util,
               hw.grid_step_s, hw.onchip_bytes, hw.hbm_bw],
        "fixed": sorted(fixed.to_dict().items()) if fixed else None,
        "resident": bool(resident),
        "measured": bool(measured),
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:20]


class TuningCache:
    """Persistent winner store: key -> {config, modeled_s, default_s,
    extra_bytes, source}. ``path=None`` keeps it in-memory (one engine's
    repeat lowerings still skip re-search); with a path, winners survive
    processes — the CI/serve warm-start contract."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, Dict[str, Any]] = {}
        self._dirty = False
        if path is not None and os.path.exists(path):
            self.load()

    def load(self) -> None:
        """Load winners from ``path``. A cache file is an OPTIMIZATION,
        never a correctness input: unreadable, truncated, or
        stale-schema files degrade to a cold cache with a one-line
        warning — a corrupt cache must not crash the serve entrypoint
        (it re-searches and rewrites the file on save)."""
        self.entries = {}
        try:
            with open(self.path) as f:
                payload = json.load(f)
        except (OSError, ValueError) as ex:
            print(f"[autotune] ignoring unreadable tuning cache "
                  f"{self.path}: {ex} (cold cache)")
            return
        if (not isinstance(payload, dict)
                or payload.get("version") != SCHEMA_VERSION
                or not isinstance(payload.get("entries", {}), dict)):
            # schema moved on: discard rather than mis-serve old picks
            print(f"[autotune] ignoring stale/foreign tuning cache "
                  f"{self.path} (cold cache)")
            return
        self.entries = payload.get("entries", {})

    def save(self) -> None:
        if self.path is None or not self._dirty:
            return
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump({"version": SCHEMA_VERSION, "entries": self.entries},
                      f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)
        self._dirty = False

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        return self.entries.get(key)

    def put(self, key: str, entry: Dict[str, Any]) -> None:
        self.entries[key] = entry
        self._dirty = True

    def __len__(self) -> int:
        return len(self.entries)


# ---------------------------------------------------------------------------
# Kernel-level pricers (the cost-model refinement of core/energy.py)
# ---------------------------------------------------------------------------


def price_int8_dense(hw, m: int, k: int, n: int, bm: int, bn: int, bk: int,
                     resident: bool) -> Tuple[float, float, bool]:
    """(seconds, restream_bytes, feasible) for one whole-batch [m,k]x[k,n]
    int8 matmul under blocks (bm, bn, bk). The MXU computes PADDED tiles
    (zero lanes occupy the array like real ones — the alignment waste the
    heuristic can't see), each grid step costs one sequencer dispatch,
    and non-resident weights restream once per M-block beyond the first."""
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    vmem = bm * bk + bk * bn + 4 * bm * bn + 4 * (bm + 2 * bn)
    feasible = vmem <= hw.onchip_bytes
    t = 2.0 * mp * kp * np_ / (hw.peak_ops_int8 * hw.util)
    steps = (mp // bm) * (np_ // bn) * (kp // bk)
    t += steps * hw.grid_step_s
    restream = 0.0 if resident else (mp // bm - 1) * float(kp * np_)
    return t, restream, feasible


def price_int8_conv(hw, batch: int, h: int, w: int, cin: int, kh: int,
                    kw: int, cout: int, stride: int, padding: str,
                    rows: int, bc: int, resident: bool
                    ) -> Tuple[float, float, bool]:
    """(seconds, restream_bytes, feasible) for a whole-batch int8
    shift-and-matmul conv at (rows_per_block, cout_per_block). Padded
    output rows (row-block coverage) and padded channels compute like
    real ones; each (sample, row-block, channel-block) grid step costs
    one sequencer dispatch; the VMEM working set is the resident image +
    one weight/output slice."""
    g = conv_geometry(h, w, kh, kw, stride, padding, rows)
    bc_eff = bc or _ceil_to(cout, 8)
    cout_pad = _ceil_to(cout, bc_eff)
    h_out_pad = g.n_row_blocks * g.rows
    macs = h_out_pad * g.w_out * cout_pad * kh * kw * cin
    t = 2.0 * macs * batch / (hw.peak_ops_int8 * hw.util)
    steps = batch * g.n_row_blocks * (cout_pad // bc_eff)
    t += steps * hw.grid_step_s
    vmem = (g.h_pad * g.w_pad * cin            # int8 image, resident
            + kh * kw * cin * bc_eff           # int8 weight slice
            + g.rows * g.w_out * bc_eff * 4    # fp32 output tile
            + 8 * bc_eff)                      # scale + bias
    feasible = vmem <= hw.onchip_bytes
    restream = (0.0 if resident
                else max(batch * g.n_row_blocks - 1, 0)
                * float(kh * kw * cin * cout_pad))
    return t, restream, feasible


def price_attention(hw, batch: int, sq: int, sk: int, hq: int, hkv: int,
                    hd: int, causal: bool, bq: int, bk: int
                    ) -> Tuple[float, float, bool]:
    """(seconds, kv_restream_bytes, feasible) for one whole-batch flash
    attention at blocks (bq, bk). Padded blocks compute like real ones;
    fully-masked causal blocks short-circuit (no MXU work) but still pay
    their sequencer dispatch; every query block beyond the first
    re-streams the K/V planes (the online-softmax scratch keeps only the
    running stats resident) — larger bq trades VMEM for fewer K/V
    passes, exactly the knob worth searching."""
    bq, bk = min(bq, _ceil_to(sq, 8)), min(bk, _ceil_to(sk, 8))
    sq_p, sk_p = _ceil_to(sq, bq), _ceil_to(sk, bk)
    n_q, n_kb = sq_p // bq, sk_p // bk
    blocks = sum(1 for i in range(n_q) for j in range(n_kb)
                 if not causal or j * bk <= i * bq + bq - 1)
    flops_per_block = 4 * bq * bk * hd + 5 * bq * bk
    t = batch * hq * blocks * flops_per_block / (hw.peak_flops_f32 * hw.util)
    t += batch * hq * n_q * n_kb * hw.grid_step_s
    # f32 working set: q/acc blocks + k/v blocks + running stats
    vmem = 4 * (2 * bq * hd + 2 * bk * hd + 2 * bq)
    feasible = vmem <= hw.onchip_bytes
    restream = (batch * hq * max(n_q - 1, 0)
                * 2.0 * sk_p * hd * 4)
    return t, restream, feasible


def price_ssd(hw, batch: int, s: int, h: int, p: int, n: int, chunk: int
              ) -> Tuple[float, float, bool]:
    """(seconds, 0, feasible) for one whole-batch chunked SSD scan. Work
    is chunk-independent (the recurrence is sequential over chunks); the
    chunk length trades per-chunk sequencer dispatches against the VMEM
    slice of inputs resident per grid step."""
    chunk = max(min(chunk, s), 1)
    flops = 7.0 * s * h * p * n            # 2 contractions + decay/blend
    t = batch * flops / (hw.peak_flops_f32 * hw.util)
    t += batch * -(-s // chunk) * hw.grid_step_s
    # f32 working set: state [h,p,n] + one chunk of x/B/C/dt + y chunk
    vmem = 4 * (h * p * n + chunk * (2 * h * p + 2 * n + h))
    feasible = vmem <= hw.onchip_bytes
    return t, 0.0, feasible


def price_hls(hw, batch: int, ops_per_sample: int, reduction: int,
              unroll: int) -> Tuple[float, float, bool]:
    """(seconds, 0, feasible) for one flex-analog dataflow layer at
    ``unroll`` parallel MACs/cycle. This is the synthesis-time schedule
    knob the paper's naive HLS designs pinned at 1: unroll is capped by
    the layer's reduction depth (the adder tree can't be wider than the
    dot product) and the DSP-lane budget. It changes the MODEL only —
    the flex backend's execution (XLA) is identical for every config."""
    feasible = unroll <= min(HLS_MAX_UNROLL, max(int(reduction), 1))
    t = ops_per_sample * batch / (hw.peak_flops_f32 * hw.util * unroll)
    return t, 0.0, feasible


# ---------------------------------------------------------------------------
# Candidate enumeration (deterministic; heuristic default is candidate #0)
# ---------------------------------------------------------------------------


def _al8(d: int) -> int:
    return _ceil_to(max(int(d), 1), 8)


def dense_candidates(m: int, k: int, n: int,
                     fixed: Optional[KernelConfig] = None
                     ) -> List[KernelConfig]:
    default = KernelConfig(*heuristic_blocks(m, k, n))
    if fixed is not None:
        # packed layout pins the weight dims (bn, bk); only the
        # activation block bm is free per rung
        bms = sorted({min(t, _al8(m)) for t in DENSE_TILES})
        out = [dataclasses.replace(default, bn=fixed.bn, bk=fixed.bk)]
        out += [KernelConfig(bm, fixed.bn, fixed.bk) for bm in bms]
    else:
        bms = sorted({min(t, _al8(m)) for t in DENSE_TILES})
        bns = sorted({min(t, _al8(n)) for t in DENSE_TILES})
        bks = sorted({min(t, _al8(k)) for t in DENSE_TILES})
        out = [default] + [KernelConfig(bm, bn, bk)
                           for bm in bms for bn in bns for bk in bks]
    seen, uniq = set(), []
    for c in out:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq


def conv_candidates(h_out: int, cout: int,
                    fixed: Optional[KernelConfig] = None
                    ) -> List[KernelConfig]:
    default = KernelConfig(rows_per_block=DEFAULT_CONV_ROWS)
    rows_cands = sorted({r for r in CONV_ROWS if r <= h_out} | {h_out})
    if fixed is not None:
        bcs = [fixed.cout_per_block]
        out = [dataclasses.replace(default,
                                   cout_per_block=fixed.cout_per_block)]
    else:
        bcs = [0] + sorted(c for c in CONV_COUT_BLOCKS if c < _al8(cout))
        out = [default]
    out += [KernelConfig(rows_per_block=r, cout_per_block=bc)
            for r in rows_cands for bc in bcs]
    seen, uniq = set(), []
    for c in out:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq


def hls_candidates(reduction: int) -> List[KernelConfig]:
    return [KernelConfig(unroll=u) for u in HLS_UNROLLS
            if u <= min(HLS_MAX_UNROLL, max(int(reduction), 1))]


def attention_candidates(sq: int, sk: int) -> List[KernelConfig]:
    """Flash-attention (bq, bk) pool. The kernel pads ragged lengths up
    to the block grid, so every pool entry is runnable; candidate #0 is
    the shipped kernel default (clamped, like the kernel clamps)."""
    default = KernelConfig(bq=min(DEFAULT_ATTN_BLOCK, sq),
                           bk=min(DEFAULT_ATTN_BLOCK, sk))
    out = [default] + [
        KernelConfig(bq=bq, bk=bk)
        for bq in sorted({min(t, sq) for t in ATTN_BLOCKS})
        for bk in sorted({min(t, sk) for t in ATTN_BLOCKS})]
    seen, uniq = set(), []
    for c in out:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq


def ssd_candidates(s: int) -> List[KernelConfig]:
    """SSD chunk pool: the kernel rounds a requested chunk down to the
    largest divisor of S, so only divisors are enumerated — the priced
    chunk is exactly the executed chunk."""
    divs = [d for d in range(1, s + 1) if s % d == 0]
    default = KernelConfig(chunk=max(d for d in divs
                                     if d <= min(DEFAULT_SSD_CHUNK, s)))
    pool = sorted({max(d for d in divs if d <= min(c, s))
                   for c in SSD_CHUNKS})
    out = [default] + [KernelConfig(chunk=c) for c in pool]
    seen, uniq = set(), []
    for c in out:
        if c not in seen:
            seen.add(c)
            uniq.append(c)
    return uniq


# ---------------------------------------------------------------------------
# Prepacked weight arenas
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PackedDense:
    """Tile-aligned dense weights: [kp, np] int8 padded to whole (bk, bn)
    tiles, neutral 1.0 scales / 0.0 biases on the padding columns."""
    w_q: jax.Array
    w_scale: jax.Array
    bias: Optional[jax.Array]
    k: int                         # logical dims (padded ones sliced off)
    n: int
    bk: int
    bn: int
    packed_bytes: int              # int8 weights + fp32 bias, padded


@dataclasses.dataclass
class PackedConv:
    """Channel-aligned conv weights: [KH, KW, Cin, cout_pad] int8 padded
    to whole cout_per_block blocks (0 = unpadded)."""
    w_q: jax.Array
    w_scale: jax.Array
    bias: Optional[jax.Array]
    cout: int
    cout_per_block: int
    packed_bytes: int


def build_packed_weights(plan, layouts: Dict[str, KernelConfig]
                         ) -> Dict[str, Any]:
    """One plan-time prepack per quantized node: alignment padding and
    neutral scale/bias extension happen HERE, once, producing device-
    resident buffers the ``prepacked=True`` kernel paths consume — the
    per-call `jnp.pad` of weight tiles is gone from the kernel bodies.
    Footprints are the padded bytes (int8 weights + fp32 bias), what
    `energy.weight_bytes` and the arena budget charge."""
    packed: Dict[str, Any] = {}
    for name, qp in plan.qplans.items():
        cfg = layouts.get(name)
        if cfg is None:
            continue
        has_bias = qp.bias is not None
        if qp.op == "dense":
            k, n = (int(d) for d in qp.w_q.shape)
            kp, np_ = _ceil_to(k, cfg.bk), _ceil_to(n, cfg.bn)
            w = qp.w_q
            if (kp, np_) != (k, n):
                w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
            ws, b = pad_channel_params(qp.w_scale, qp.bias, np_ - n)
            packed[name] = PackedDense(
                w_q=w, w_scale=ws, bias=b, k=k, n=n, bk=cfg.bk, bn=cfg.bn,
                packed_bytes=kp * np_ + (np_ * 4 if has_bias else 0))
        else:
            kh, kw, cin, cout = (int(d) for d in qp.w_q.shape)
            bc = cfg.cout_per_block
            cout_pad = _ceil_to(cout, bc) if bc else cout
            w = qp.w_q
            if cout_pad != cout:
                w = jnp.pad(w, ((0, 0), (0, 0), (0, 0),
                                (0, cout_pad - cout)))
            ws, b = pad_channel_params(qp.w_scale, qp.bias,
                                       cout_pad - cout)
            packed[name] = PackedConv(
                w_q=w, w_scale=ws, bias=b, cout=cout, cout_per_block=bc,
                packed_bytes=kh * kw * cin * cout_pad
                + (cout_pad * 4 if has_bias else 0))
    return packed


# ---------------------------------------------------------------------------
# The autotuner
# ---------------------------------------------------------------------------


def node_spec(plan, name: str, batch: int) -> Optional[Tuple[str, Tuple]]:
    """(kind, shape-signature) for a tunable node, or None. Signatures
    start with the batch rung — the whole (op, shape, dtype, backend,
    rung) cache identity lives here."""
    node = plan.graph.nodes[name]
    bop = base_op(node)
    # the LM kernels tune on either plan backend: unlike the hls knob,
    # (bq, bk) / chunk change the EXECUTED Pallas grid (numerics-neutral)
    if bop == "attention":
        sq, hq, hd = node.out_shape
        sk, hkv, _ = plan.graph.nodes[node.inputs[1]].out_shape
        return "attention", (batch, int(sq), int(sk), int(hq), int(hkv),
                             int(hd),
                             1 if node.attrs.get("causal", True) else 0)
    if bop == "ssd":
        s, h, p = node.out_shape
        n = plan.graph.nodes[node.inputs[1]].out_shape[-1]
        return "ssd", (batch, int(s), int(h), int(p), int(n))
    if plan.backend == "accel" and name in plan.qplans:
        qp = plan.qplans[name]
        in_shape = plan.graph.nodes[node.inputs[0]].out_shape or ()
        if qp.op == "dense":
            if qp.per_position:
                # token-wise GEMM: M = batch x positions, K = last axis
                m = batch * int(np.prod(in_shape[:-1], dtype=np.int64))
                return "int8_dense", (m, int(in_shape[-1]),
                                      int(qp.w_q.shape[1]))
            k = int(np.prod(in_shape, dtype=np.int64))
            return "int8_dense", (batch, k, int(qp.w_q.shape[1]))
        h, w, cin = in_shape
        kh, kw, _, cout = (int(d) for d in qp.w_q.shape)
        return "int8_conv", (batch, int(h), int(w), int(cin), kh, kw,
                             cout, int(qp.stride), qp.padding)
    if plan.backend == "flex" and bop in ("conv2d", "dense"):
        in_shape = plan.graph.nodes[node.inputs[0]].out_shape or ()
        if bop == "dense":
            red = (int(in_shape[-1])
                   if node.attrs.get("per_position", False)
                   else int(np.prod(in_shape, dtype=np.int64)))
        else:
            kh, kw = node.attrs["kernel"]
            red = int(kh) * int(kw) * int(in_shape[-1])
        return "hls", (batch, int(node.ops), red)
    return None


class Autotuner:
    """Cost-model-guided schedule search over a plan's tunable nodes.

    One instance per engine, shared across its backends' plans: the
    ``stats`` counters are the no-resarch contract the tests pin —
    a warm cache performs ZERO candidate evaluations."""

    def __init__(self, cache: Optional[TuningCache] = None,
                 measure: bool = False, measure_top_k: int = 3,
                 measure_repeats: int = 2):
        self.cache = cache if cache is not None else TuningCache(None)
        self.measure = measure
        self.measure_top_k = measure_top_k
        self.measure_repeats = measure_repeats
        self.stats = {"nodes": 0, "evaluated": 0, "cache_hits": 0,
                      "measured": 0}

    # -- search --------------------------------------------------------------

    def _price(self, kind: str, sig: Tuple, hw, cfg: KernelConfig,
               resident: bool) -> Tuple[float, float, bool]:
        if kind == "int8_dense":
            m, k, n = sig
            return price_int8_dense(hw, m, k, n, cfg.bm, cfg.bn, cfg.bk,
                                    resident)
        if kind == "int8_conv":
            batch, h, w, cin, kh, kw, cout, stride, padding = sig
            return price_int8_conv(hw, batch, h, w, cin, kh, kw, cout,
                                   stride, padding,
                                   cfg.rows_per_block or DEFAULT_CONV_ROWS,
                                   cfg.cout_per_block, resident)
        if kind == "attention":
            batch, sq, sk, hq, hkv, hd, causal = sig
            return price_attention(hw, batch, sq, sk, hq, hkv, hd,
                                   bool(causal),
                                   cfg.bq or DEFAULT_ATTN_BLOCK,
                                   cfg.bk or DEFAULT_ATTN_BLOCK)
        if kind == "ssd":
            batch, s, h, p, n = sig
            return price_ssd(hw, batch, s, h, p, n,
                             cfg.chunk or DEFAULT_SSD_CHUNK)
        batch, ops, red = sig
        return price_hls(hw, batch, ops, red, cfg.unroll)

    def _candidates(self, kind: str, sig: Tuple,
                    fixed: Optional[KernelConfig]) -> List[KernelConfig]:
        if kind == "int8_dense":
            m, k, n = sig
            return dense_candidates(m, k, n, fixed)
        if kind == "int8_conv":
            _, h, w, cin, kh, kw, cout, stride, padding = sig
            h_out = conv_geometry(h, w, kh, kw, stride, padding, 1).h_out
            return conv_candidates(h_out, cout, fixed)
        if kind == "attention":
            return attention_candidates(sig[1], sig[2])
        if kind == "ssd":
            return ssd_candidates(sig[1])
        _, _, red = sig
        return hls_candidates(red)

    def _search(self, kind: str, sig: Tuple, hw, resident: bool,
                fixed: Optional[KernelConfig]) -> TuningDecision:
        cands = self._candidates(kind, sig, fixed)
        best = None
        best_score = math.inf
        priced: List[Tuple[float, float, KernelConfig]] = []

        def score(t: float, extra: float) -> float:
            # candidates are ranked on compute time PLUS the restream
            # traffic's transfer time — for non-resident-weight models a
            # small-bm config that re-streams weights per M-block must
            # not beat the one-pass default on compute time alone
            return t + extra / hw.hbm_bw

        for i, cfg in enumerate(cands):
            t, extra, feasible = self._price(kind, sig, hw, cfg, resident)
            self.stats["evaluated"] += 1
            if i == 0:
                feasible = True            # the shipped heuristic always runs
            if not feasible:
                continue
            priced.append((t, extra, cfg))
            if score(t, extra) < best_score:
                best_score = score(t, extra)
                best = (t, extra, cfg)
        t, extra, cfg = best
        # default_s is always the price of the TRUE heuristic config
        # (unconstrained): under a pinned packed layout, candidate #0 is
        # the pinned-layout default, and reporting speedups against it
        # would overstate the win
        d_default = self._candidates(kind, sig, None)[0]
        default_s = self._price(kind, sig, hw, d_default, resident)[0]
        source = "model"
        if (self.measure and kind in INT8_KINDS
                and self.measure_top_k > 0 and len(priced) > 1):
            cfg = self._refine_measured(kind, sig, priced)
            t, extra, _ = self._price(kind, sig, hw, cfg, resident)
            source = "measured"
        return TuningDecision(kind=kind, config=cfg, modeled_s=t,
                              default_s=default_s, extra_bytes=extra,
                              source=source)

    # -- measured refinement (opt-in; interpret-mode on this host) -----------

    def _refine_measured(self, kind: str, sig: Tuple,
                         priced: List[Tuple[float, float, KernelConfig]]
                         ) -> KernelConfig:
        """Wall-clock the model's top-K candidates on synthetic data and
        keep the fastest. On a real TPU this measures Mosaic; on this
        host it measures the interpret-mode emulation — which is why it
        is opt-in (``--autotune-measure``) and never part of CI."""
        from repro.kernels import ops as kops
        top = sorted(priced, key=lambda p: p[0])[:self.measure_top_k]
        rng = np.random.default_rng(0)
        best_cfg, best_t = top[0][2], math.inf
        for _, _, cfg in top:
            if kind == "int8_dense":
                m, k, n = sig
                x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
                w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
                xs = jnp.ones((m,), jnp.float32)
                ws = jnp.ones((n,), jnp.float32)
                fn = lambda: kops.int8_matmul(x, w, xs, ws, bm=cfg.bm,
                                              bn=cfg.bn, bk=cfg.bk)
            else:
                batch, h, w_, cin, kh, kw, cout, stride, padding = sig
                x = jnp.asarray(
                    rng.integers(-127, 128, (batch, h, w_, cin)), jnp.int8)
                wq = jnp.asarray(
                    rng.integers(-127, 128, (kh, kw, cin, cout)), jnp.int8)
                ws = jnp.ones((cout,), jnp.float32)
                fn = lambda: kops.conv2d_int8(
                    x, wq, ws, stride=stride, padding=padding,
                    rows_per_block=cfg.rows_per_block or DEFAULT_CONV_ROWS,
                    cout_per_block=cfg.cout_per_block)
            jax.block_until_ready(fn())        # compile outside the timer
            t = math.inf
            for _ in range(self.measure_repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                t = min(t, time.perf_counter() - t0)
            self.stats["measured"] += 1
            if t < best_t:
                best_t, best_cfg = t, cfg
        return best_cfg

    # -- the plan entry point ------------------------------------------------

    def tune_plan(self, plan, batch: int,
                  layouts: Optional[Dict[str, KernelConfig]] = None
                  ) -> Dict[str, TuningDecision]:
        """Tuning decisions for every tunable node of ``plan`` at one
        batch rung. ``layouts`` pins the weight-layout dims (bn/bk or
        cout_per_block) to an existing packed arena — per-rung search
        then covers only the activation-schedule knobs."""
        hw = energy_mod.BACKEND_HW[plan.backend]
        w_bytes = energy_mod.weight_bytes(plan.graph, plan.backend,
                                          set(plan.qplans))
        resident = w_bytes <= hw.onchip_bytes
        decisions: Dict[str, TuningDecision] = {}
        for name in plan.graph.order:
            spec = node_spec(plan, name, batch)
            if spec is None:
                continue
            kind, sig = spec
            fixed = (layouts or {}).get(name)
            self.stats["nodes"] += 1
            key = cache_key(kind, sig, plan.backend, hw, fixed,
                            resident=resident,
                            measured=self.measure and kind in INT8_KINDS)
            ent = self.cache.get(key)
            if ent is not None:
                decisions[name] = TuningDecision(
                    kind=kind, config=KernelConfig.from_dict(ent["config"]),
                    modeled_s=ent["modeled_s"], default_s=ent["default_s"],
                    extra_bytes=ent.get("extra_bytes", 0.0), source="cache")
                self.stats["cache_hits"] += 1
                continue
            dec = self._search(kind, sig, hw, resident, fixed)
            self.cache.put(key, {
                "config": dec.config.to_dict(), "modeled_s": dec.modeled_s,
                "default_s": dec.default_s, "extra_bytes": dec.extra_bytes,
                "source": dec.source, "kind": kind, "sig": list(sig)})
            decisions[name] = dec
        self.cache.save()
        return decisions


def price_defaults(plan, batch: int) -> Dict[str, TuningDecision]:
    """Every tunable node priced at its heuristic DEFAULT config with the
    same kernel-level pricer — the apples-to-apples baseline the
    BENCH_autotune gates compare tuned picks against (the coarse roofline
    in `cost_signature` has no tile notion, so comparing against it would
    mix two models)."""
    hw = energy_mod.BACKEND_HW[plan.backend]
    w_bytes = energy_mod.weight_bytes(plan.graph, plan.backend,
                                      set(plan.qplans))
    resident = w_bytes <= hw.onchip_bytes
    tuner = Autotuner(TuningCache(None))
    out: Dict[str, TuningDecision] = {}
    for name in plan.graph.order:
        spec = node_spec(plan, name, batch)
        if spec is None:
            continue
        kind, sig = spec
        default = tuner._candidates(kind, sig, None)[0]
        t, extra, _ = tuner._price(kind, sig, hw, default, resident)
        out[name] = TuningDecision(kind=kind, config=default, modeled_s=t,
                                   default_s=t, extra_bytes=extra,
                                   source="default")
    return out
