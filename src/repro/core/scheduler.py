"""Continuous-batching serving scheduler over the staged plan cache.

The paper's motivating workload is a request *stream*: sensor frames
arrive continuously (FPI ion distributions every survey cycle, SHARP
magnetogram tiles, GOES channel samples) and are filtered on-board to
ease downlink pressure. The fixed-batch ``ServingPipeline`` consumes a
pre-materialized list at one batch size; this module adds the layer a
real deployment needs on top of it:

* **per-model request queues** with arrival timestamps and per-use-case
  latency *deadlines* (each mission cadence implies one — see
  ``DEFAULT_DEADLINES``),
* a precompiled **batch-size ladder** per (model, backend): one compiled
  executable per rung, built at ``register()`` time, so serving never
  traces (PR-1's plan-cache contract),
* a dispatch policy that **waits to fill**: a queue dispatches at the
  largest ladder rung once it holds a full top-rung batch, but the
  whole ragged tail is **flushed early into one padded batch** when the
  oldest request's deadline gets within a safety margin of the measured
  service time — batch-fill is traded for latency exactly when the
  deadline forces it,
* **round-robin fairness** across concurrently registered models (the
  on-board reality: one accelerator, several instruments), and
* per-model **telemetry**: p50/p99 latency, fps, batch-fill histogram
  per rung, deadline misses, and the selective-downlink reduction ratio.

Execution of one dispatched batch is delegated to
``ServingPipeline.execute_batch`` (core/pipeline.py) — the scheduler owns
*when and how many*, the pipeline owns *staging, padding, compute, and
the keep predicate*.

Two driving modes share the same ``step()`` core:

* ``serve_trace(trace)`` — deterministic virtual-clock simulation:
  arrivals happen at trace timestamps, service occupies the (measured)
  execution time of each dispatched plan call. This is what the
  benchmarks and property tests drive.
* ``start()/submit()/stop()`` — a background dispatcher thread against
  the wall clock, for asynchronous producers.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.pipeline import BatchResult, ServingPipeline

DEFAULT_LADDER = (1, 4, 16, 32)


def capped_ladder(top: int, base: Sequence[int] = DEFAULT_LADDER
                  ) -> Tuple[int, ...]:
    """``base`` clamped to a caller-chosen top rung (which joins the
    ladder if it isn't a base rung) — the one place launchers derive a
    ladder from a ``--batch`` flag."""
    if top < 1:
        raise ValueError(f"top rung must be >= 1, got {top}")
    return tuple(sorted({r for r in base if r < top} | {top}))

# Per-use-case latency deadlines (seconds), mirroring mission cadences:
# the MMS nets must keep up with FPI burst-mode distributions (150 ms
# cadence); ESPERTA scores proton-event features as they are derived;
# CNet ingests SDO full-disk images at ~1-min cadence; the VAE compresses
# SHARP magnetogram tiles (45 s product cadence). A result that misses
# the next sensor frame is stale, so the deadline is one cadence.
DEFAULT_DEADLINES = {
    "baseline_net": 0.150,
    "reduced_net": 0.150,
    "logistic_net": 0.150,
    "multi_esperta": 1.0,
    "cnet_plus_scalar": 2.0,
    "vae_encoder": 1.0,
}
FALLBACK_DEADLINE = 0.5


# ---------------------------------------------------------------------------
# Records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    model: str
    inputs: Dict[str, np.ndarray]
    arrival: float
    deadline: float                     # absolute completion deadline


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    model: str
    outputs: Dict[str, np.ndarray]
    kept: bool
    arrival: float
    finished: float
    rung: int                           # compiled batch size dispatched at
    n_real: int                         # real (non-padding) requests in it
    deadline: float

    @property
    def latency(self) -> float:
        return self.finished - self.arrival

    @property
    def missed_deadline(self) -> bool:
        return self.finished > self.deadline


@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    model: str
    rung: int
    n_real: int
    started: float
    service_time: float
    mode: str                           # 'full' | 'flush'

    @property
    def fill(self) -> float:
        return self.n_real / self.rung


@dataclasses.dataclass
class ModelTelemetry:
    model: str
    deadline_s: float
    n_submitted: int = 0
    n_completed: int = 0
    n_kept: int = 0
    deadline_misses: int = 0
    fps: float = 0.0
    p50_latency_ms: float = 0.0
    p99_latency_ms: float = 0.0
    mean_batch_fill: float = 0.0
    fill_hist: Dict[int, Dict[str, float]] = dataclasses.field(
        default_factory=dict)           # rung -> {dispatches, mean_fill}
    n_dispatches: int = 0

    @property
    def downlink_reduction(self) -> float:
        return 1.0 - self.n_kept / max(self.n_completed, 1)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["fill_hist"] = {str(k): v for k, v in self.fill_hist.items()}
        d["downlink_reduction"] = self.downlink_reduction
        return d


# ---------------------------------------------------------------------------
# Arrival traces (virtual-clock simulation inputs)
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_hz: float, n: int, seed: int = 0,
                     start: float = 0.0) -> List[float]:
    """``n`` Poisson-process arrival times at ``rate_hz`` (exp gaps)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return [float(t) for t in start + np.cumsum(gaps)]


def bursty_arrivals(n: int, burst_size: int, gap_s: float,
                    intra_s: float = 0.0, seed: int = 0,
                    start: float = 0.0) -> List[float]:
    """Bursts of ``burst_size`` back-to-back arrivals every ``gap_s``
    (the paper's regime: an instrument dumps a survey window at once).
    ``intra_s`` jitters samples inside a burst."""
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t = start
    while len(times) < n:
        for i in range(min(burst_size, n - len(times))):
            times.append(float(t + (rng.uniform(0, intra_s)
                                    if intra_s else 0.0)))
        t += gap_s
    return sorted(times)


# ---------------------------------------------------------------------------
# Per-model service state
# ---------------------------------------------------------------------------


class _ModelService:
    def __init__(self, name: str, pipelines: Dict[int, ServingPipeline],
                 deadline_s: float, flush_safety: float):
        self.name = name
        self.pipelines = pipelines
        self.ladder: Tuple[int, ...] = tuple(sorted(pipelines))
        self.deadline_s = deadline_s
        self.flush_safety = flush_safety
        self.queue: Deque[Request] = deque()
        self.n_submitted = 0
        # EWMA service-time estimate per rung (seeded by register warmup)
        self.est_service: Dict[int, float] = {}
        self._rng = jax.random.PRNGKey(
            int(np.frombuffer(name.encode()[:4].ljust(4, b"\0"),
                              np.uint32)[0]))

    def next_rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    def observe_service(self, rung: int, seconds: float) -> None:
        old = self.est_service.get(rung)
        self.est_service[rung] = (seconds if old is None
                                  else 0.5 * old + 0.5 * seconds)

    def flush_margin(self) -> float:
        """How long before the oldest deadline we must start computing:
        safety x the worst measured rung service time (0 until measured —
        then the first dispatch itself seeds the estimate)."""
        worst = max(self.est_service.values(), default=0.0)
        return self.flush_safety * worst

    def flush_time(self) -> Optional[float]:
        if not self.queue:
            return None
        return self.queue[0].deadline - self.flush_margin()

    def pick(self, now: float) -> Optional[Tuple[str, int, int]]:
        """(mode, rung, n_real) to dispatch at ``now``, or None to wait.

        * ``full``  — a full top-rung batch is waiting: dispatch it at
          100% fill (the largest ladder rung <= queue depth).
        * ``flush`` — the oldest request's deadline is within the safety
          margin: flush the WHOLE ragged tail as one batch, padded up to
          the smallest rung that holds it (its queue-mates' deadlines
          trail the oldest by arrival gaps, so one padded dispatch
          minimizes their worst-case latency too).
        """
        depth = len(self.queue)
        if depth == 0:
            return None
        top = self.ladder[-1]
        if depth >= top:
            return ("full", top, top)
        ft = self.flush_time()
        if ft is not None and ft <= now:
            n_real = min(depth, top)
            rung = self.ladder[bisect.bisect_left(self.ladder, n_real)]
            return ("flush", rung, n_real)
        return None


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


class ContinuousBatchingScheduler:
    """Co-serves several space models from one process: per-model queues,
    a precompiled batch ladder each, deadline-bounded batch filling, and
    round-robin dispatch across models."""

    def __init__(self, flush_safety: float = 2.0):
        self.flush_safety = flush_safety
        self._svcs: Dict[str, _ModelService] = {}
        self._order: List[str] = []     # round-robin rotation
        self._rr = 0
        self._next_rid = 0
        self._lock = threading.RLock()
        self.completions: List[Completion] = []
        self.dispatches: List[DispatchRecord] = []
        self._thread: Optional[threading.Thread] = None
        self._thread_error: Optional[BaseException] = None
        self._stop = threading.Event()

    # -- setup --------------------------------------------------------------

    def register(self, name: str, engine, backend: str = "flex",
                 ladder: Sequence[int] = DEFAULT_LADDER,
                 deadline_s: Optional[float] = None,
                 keep_predicate: Optional[Callable] = None,
                 warmup_sample: Optional[Dict[str, np.ndarray]] = None
                 ) -> None:
        """Precompile the batch ladder for ``(engine, backend)`` and open a
        queue. ``warmup_sample`` (one request dict) additionally runs every
        rung once, paying XLA first-call costs up front and seeding the
        service-time estimates the deadline-flush margin uses."""
        ladder = tuple(sorted(set(int(r) for r in ladder)))
        if not ladder or ladder[0] < 1:
            raise ValueError(f"bad ladder {ladder}")
        pipelines = {r: ServingPipeline(engine, backend=backend, batch_size=r,
                                        keep_predicate=keep_predicate)
                     for r in ladder}
        if deadline_s is None:
            deadline_s = DEFAULT_DEADLINES.get(name, FALLBACK_DEADLINE)
        svc = _ModelService(name, pipelines, deadline_s, self.flush_safety)
        if warmup_sample is not None:
            for rung in ladder:
                # first call pays XLA first-run costs; the second is the
                # steady-state service time the flush margin budgets for
                pipelines[rung].execute_batch([warmup_sample] * rung)
                t0 = time.perf_counter()
                pipelines[rung].execute_batch([warmup_sample] * rung)
                svc.observe_service(rung, time.perf_counter() - t0)
        with self._lock:
            if name in self._svcs:
                raise ValueError(f"model {name!r} already registered")
            self._svcs[name] = svc
            self._order.append(name)

    @property
    def models(self) -> List[str]:
        return list(self._order)

    # -- submission ---------------------------------------------------------

    def submit(self, model: str, inputs: Dict[str, np.ndarray],
               arrival: Optional[float] = None) -> int:
        """Enqueue one request; returns its id. ``arrival`` defaults to the
        wall clock (async mode); trace mode passes virtual timestamps."""
        with self._lock:
            svc = self._svcs[model]
            arrival = time.monotonic() if arrival is None else float(arrival)
            rid = self._next_rid
            self._next_rid += 1
            svc.queue.append(Request(rid, model, inputs, arrival,
                                     arrival + svc.deadline_s))
            svc.n_submitted += 1
            return rid

    # -- dispatch core ------------------------------------------------------

    def step(self, now: float, force: bool = False
             ) -> Optional[DispatchRecord]:
        """Dispatch at most ONE batch: scan models round-robin from the
        rotation pointer, serve the first one with a ready queue, advance
        the pointer past it. ``force`` flushes regardless of deadlines
        (used by drain). Returns the dispatch record, or None if every
        queue is waiting."""
        with self._lock:
            n = len(self._order)
            for k in range(n):
                name = self._order[(self._rr + k) % n]
                svc = self._svcs[name]
                picked = svc.pick(now)
                if picked is None and force and svc.queue:
                    depth = min(len(svc.queue), svc.ladder[-1])
                    rung = svc.ladder[bisect.bisect_left(svc.ladder, depth)]
                    picked = ("flush", rung, depth)
                if picked is None:
                    continue
                mode, rung, n_real = picked
                reqs = [svc.queue.popleft() for _ in range(n_real)]
                self._rr = (self._rr + k + 1) % n
                break
            else:
                return None
            rng = svc.next_rng()

        t0 = time.perf_counter()
        try:
            result: BatchResult = svc.pipelines[rung].execute_batch(
                [r.inputs for r in reqs], rng=rng)
        except BaseException:
            # no silent loss: put the popped batch back at the queue head
            # (original order) before surfacing the error
            with self._lock:
                svc.queue.extendleft(reversed(reqs))
            raise
        service = time.perf_counter() - t0

        with self._lock:
            svc.observe_service(rung, service)
            finished = now + service
            rec = DispatchRecord(svc.name, rung, n_real, now, service, mode)
            self.dispatches.append(rec)
            for i, req in enumerate(reqs):
                self.completions.append(Completion(
                    req.rid, req.model,
                    {k: v[i] for k, v in result.outputs.items()},
                    result.keep[i], req.arrival, finished, rung, n_real,
                    req.deadline))
            return rec

    def next_event_time(self) -> Optional[float]:
        """Earliest deadline-flush instant across nonempty queues."""
        with self._lock:
            times = [svc.flush_time() for svc in self._svcs.values()]
            times = [t for t in times if t is not None]
            return min(times) if times else None

    def pending(self) -> int:
        with self._lock:
            return sum(len(svc.queue) for svc in self._svcs.values())

    def drain(self, now: float) -> float:
        """Flush every queue to empty (end of stream); returns the final
        virtual time."""
        while self.pending():
            rec = self.step(now, force=True)
            if rec is not None:
                now += rec.service_time
        return now

    # -- virtual-clock trace serving ----------------------------------------

    def serve_trace(self, trace: Sequence[Tuple[float, str, Dict]],
                    start: float = 0.0) -> float:
        """Serve a pre-built arrival trace of ``(t, model, inputs)`` under a
        virtual clock: arrivals occur at trace time, each dispatch occupies
        its measured execution time. Deterministic given the trace; returns
        the final virtual time."""
        ev = sorted(trace, key=lambda e: e[0])
        now, i, n = start, 0, len(ev)
        while i < n or self.pending():
            while i < n and ev[i][0] <= now + 1e-12:
                self.submit(ev[i][1], ev[i][2], arrival=ev[i][0])
                i += 1
            rec = self.step(now)
            if rec is not None:
                now += rec.service_time         # server busy while computing
                continue
            nxt = ev[i][0] if i < n else None
            ft = self.next_event_time()
            if ft is not None:
                nxt = ft if nxt is None else min(nxt, ft)
            if nxt is None:
                break
            now = max(now, nxt)
        return now

    # -- asynchronous (wall-clock) mode -------------------------------------

    def start(self, poll_s: float = 0.001) -> None:
        """Run the dispatcher on a background thread against the wall
        clock; producers call :meth:`submit` concurrently."""
        if self._thread is not None:
            raise RuntimeError("scheduler already started")
        self._stop.clear()
        self._thread_error = None

        def loop():
            while not self._stop.is_set():
                try:
                    rec = self.step(time.monotonic())
                except BaseException as ex:     # batch re-queued by step()
                    self._thread_error = ex
                    return
                if rec is None:
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="cb-scheduler")
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the dispatcher thread; by default flush what's queued.
        Re-raises an error that killed the dispatcher (its batch was
        re-queued, so nothing was lost — but serving DID stop)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if self._thread_error is not None:
            err, self._thread_error = self._thread_error, None
            raise err
        if drain:
            self.drain(time.monotonic())

    # -- telemetry ----------------------------------------------------------

    def telemetry(self) -> Dict[str, ModelTelemetry]:
        with self._lock:
            out: Dict[str, ModelTelemetry] = {}
            for name, svc in self._svcs.items():
                tel = ModelTelemetry(name, svc.deadline_s,
                                     n_submitted=svc.n_submitted)
                comps = [c for c in self.completions if c.model == name]
                disps = [d for d in self.dispatches if d.model == name]
                tel.n_completed = len(comps)
                tel.n_kept = sum(c.kept for c in comps)
                tel.deadline_misses = sum(c.missed_deadline for c in comps)
                tel.n_dispatches = len(disps)
                if comps:
                    lat = np.array([c.latency for c in comps])
                    tel.p50_latency_ms = float(np.percentile(lat, 50) * 1e3)
                    tel.p99_latency_ms = float(np.percentile(lat, 99) * 1e3)
                    span = (max(c.finished for c in comps)
                            - min(c.arrival for c in comps))
                    tel.fps = len(comps) / max(span, 1e-12)
                if disps:
                    tel.mean_batch_fill = float(
                        np.mean([d.fill for d in disps]))
                    for rung in svc.ladder:
                        at = [d.fill for d in disps if d.rung == rung]
                        if at:
                            tel.fill_hist[rung] = {
                                "dispatches": len(at),
                                "mean_fill": float(np.mean(at))}
                out[name] = tel
            return out

    def summary(self) -> str:
        lines = []
        for name, tel in self.telemetry().items():
            lines.append(
                f"[{name}] {tel.n_completed}/{tel.n_submitted} served  "
                f"fps={tel.fps:.1f}  p50={tel.p50_latency_ms:.2f} ms  "
                f"p99={tel.p99_latency_ms:.2f} ms "
                f"(deadline {tel.deadline_s*1e3:.0f} ms, "
                f"{tel.deadline_misses} missed)  "
                f"fill={tel.mean_batch_fill:.0%} over {tel.n_dispatches} "
                f"dispatches  kept={tel.n_kept} "
                f"(downlink -{tel.downlink_reduction:.0%})")
        return "\n".join(lines)
