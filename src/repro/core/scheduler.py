"""Continuous-batching serving scheduler over the staged plan cache.

The paper's motivating workload is a request *stream*: sensor frames
arrive continuously (FPI ion distributions every survey cycle, SHARP
magnetogram tiles, GOES channel samples) and are filtered on-board to
ease downlink pressure. The fixed-batch ``ServingPipeline`` consumes a
pre-materialized list at one batch size; this module adds the layer a
real deployment needs on top of it:

* **per-model request queues** with arrival timestamps and per-use-case
  latency *deadlines* (each mission cadence implies one — see
  ``DEFAULT_DEADLINES``),
* a precompiled **batch-size ladder** per (model, backend): one compiled
  executable per rung, built at ``register()`` time, so serving never
  traces (PR-1's plan-cache contract),
* a dispatch policy that **waits to fill**: a queue dispatches at the
  largest ladder rung once it holds a full top-rung batch, but the
  whole ragged tail is **flushed early into one padded batch** when the
  oldest request's deadline gets within a safety margin of the measured
  service time — batch-fill is traded for latency exactly when the
  deadline forces it,
* **round-robin fairness** across concurrently registered models (the
  on-board reality: one accelerator, several instruments),
* an optional orbital **power envelope** (``core/energy.py``): a model
  may register SEVERAL backends (primary first); each (backend, rung)
  carries its plan-time cost signature, and every dispatch must be
  admitted by the envelope — the dispatcher picks the cheapest-energy
  admissible backend, falls back (DPU -> CPU/HLS) when the budget
  tightens, and *defers* (recording the deferral) when nothing fits,
  advancing the virtual clock to the envelope's next-admit time. With no
  envelope the dispatch sequence is exactly the PR-2 deadline policy on
  the primary backend, and
* per-model **telemetry**: p50/p99 latency, fps, batch-fill histogram
  per rung, deadline misses, the selective-downlink reduction ratio,
  and — per the envelope — modeled energy, J/inference, duty cycle,
  backend mix, and deferral counts.

Execution of one dispatched batch is delegated to
``ServingPipeline.execute_batch`` (core/pipeline.py) — the scheduler owns
*when and how many*, the pipeline owns *staging, padding, compute, and
the keep predicate*.

``pipeline=True`` (DESIGN.md §12) switches dispatch to the ASYNC ticket
path: ``execute_batch_async`` returns without forcing the outputs, up to
``staging_buffers`` dispatches stay in flight (each owning a reusable
host staging slot), and tickets retire lazily — at slot-pool pressure,
at every telemetry boundary, and at stream end. EWMA service times are
observed at ticket retirement. Dispatch DECISIONS are unchanged, and
under ``clock="modeled"`` pipelined serving is dispatch-for-dispatch and
bit-exact identical to ``pipeline=False``; the overlap a pipelined
deployment would realize is priced by a deterministic per-resource
occupancy ledger (``overlap_report()``).

Two driving modes share the same ``step()`` core:

* ``serve_trace(trace)`` — deterministic virtual-clock simulation:
  arrivals happen at trace timestamps, service occupies the (measured)
  execution time of each dispatched plan call. This is what the
  benchmarks and property tests drive.
* ``start()/submit()/stop()`` — a background dispatcher thread against
  the wall clock, for asynchronous producers.
"""
from __future__ import annotations

import bisect
import dataclasses
import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.energy import (CostSignature, Draw, PipelineTimeline,
                               PowerEnvelope, StageCost)
from repro.core.pipeline import (BatchResult, DispatchTicket,
                                 ServingPipeline)

DEFAULT_LADDER = (1, 4, 16, 32)
BACKENDS = ("cpu", "flex", "accel")


def capped_ladder(top: int, base: Sequence[int] = DEFAULT_LADDER
                  ) -> Tuple[int, ...]:
    """``base`` clamped to a caller-chosen top rung (which joins the
    ladder if it isn't a base rung) — the one place launchers derive a
    ladder from a ``--batch`` flag."""
    if top < 1:
        raise ValueError(f"top rung must be >= 1, got {top}")
    return tuple(sorted({r for r in base if r < top} | {top}))

# Per-use-case latency deadlines (seconds), mirroring mission cadences:
# the MMS nets must keep up with FPI burst-mode distributions (150 ms
# cadence); ESPERTA scores proton-event features as they are derived;
# CNet ingests SDO full-disk images at ~1-min cadence; the VAE compresses
# SHARP magnetogram tiles (45 s product cadence). A result that misses
# the next sensor frame is stale, so the deadline is one cadence.
DEFAULT_DEADLINES = {
    "baseline_net": 0.150,
    "reduced_net": 0.150,
    "logistic_net": 0.150,
    "multi_esperta": 1.0,
    "cnet_plus_scalar": 2.0,
    "vae_encoder": 1.0,
}
FALLBACK_DEADLINE = 0.5


# ---------------------------------------------------------------------------
# Records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    model: str
    inputs: Dict[str, np.ndarray]
    arrival: float
    deadline: float                     # absolute completion deadline


@dataclasses.dataclass(frozen=True)
class Completion:
    rid: int
    model: str
    outputs: Dict[str, np.ndarray]
    kept: bool
    arrival: float
    finished: float
    rung: int                           # compiled batch size dispatched at
    n_real: int                         # real (non-padding) requests in it
    deadline: float

    @property
    def latency(self) -> float:
        return self.finished - self.arrival

    @property
    def missed_deadline(self) -> bool:
        return self.finished > self.deadline


@dataclasses.dataclass(frozen=True)
class DispatchRecord:
    model: str
    rung: int
    n_real: int
    started: float
    service_time: float
    mode: str                           # 'full' | 'flush'
    backend: str = ""                   # backend the batch ran on
    energy_j: float = 0.0               # modeled energy of the dispatch
    power_w: float = 0.0                # modeled busy power while it ran
    failed: bool = False                # retirement raised; batch requeued

    @property
    def fill(self) -> float:
        return self.n_real / self.rung

    @property
    def modeled_latency_s(self) -> float:
        return self.energy_j / self.power_w if self.power_w > 0 else 0.0


@dataclasses.dataclass
class _Inflight:
    """A dispatched-but-unretired batch in pipelined mode: everything the
    scheduler needs to finish the bookkeeping (EWMA observation, the
    measured service rewrite, completions) when the ticket retires."""
    ticket: DispatchTicket
    reqs: List[Request]
    svc: "_ModelService"
    backend: str
    rung: int
    n_real: int
    started: float                      # virtual dispatch time
    sig: CostSignature
    draw: Optional[Draw]
    rec_idx: int                        # index into scheduler.dispatches
    t0: float                           # wall perf_counter at dispatch


@dataclasses.dataclass(frozen=True)
class DeferralRecord:
    """A dispatch opportunity the envelope refused: the model was due
    (full batch or deadline flush) but no backend's draw was admissible."""
    model: str
    time: float
    rung: int
    n_real: int


@dataclasses.dataclass
class ModelTelemetry:
    model: str
    deadline_s: float
    n_submitted: int = 0
    n_completed: int = 0
    n_kept: int = 0
    deadline_misses: int = 0
    fps: float = 0.0
    p50_latency_ms: float = 0.0
    p99_latency_ms: float = 0.0
    mean_batch_fill: float = 0.0
    fill_hist: Dict[int, Dict[str, float]] = dataclasses.field(
        default_factory=dict)           # rung -> {dispatches, mean_fill}
    n_dispatches: int = 0
    # -- energy accounting (modeled; populated from cost signatures) --------
    energy_j: float = 0.0               # total modeled J across dispatches
    j_per_inference: float = 0.0
    duty_cycle: float = 0.0             # modeled busy time / serving span
    n_deferrals: int = 0                # envelope-refused dispatch chances
    backend_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # -- degraded-mode accounting (DESIGN.md §13) ----------------------------
    n_staging_fallbacks: int = 0        # host arena pool misses (fresh alloc)
    n_failed_dispatches: int = 0        # dispatches whose retirement raised

    @property
    def downlink_reduction(self) -> float:
        return 1.0 - self.n_kept / max(self.n_completed, 1)

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["fill_hist"] = {str(k): v for k, v in self.fill_hist.items()}
        d["downlink_reduction"] = self.downlink_reduction
        return d


# ---------------------------------------------------------------------------
# Arrival traces (virtual-clock simulation inputs)
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_hz: float, n: int, seed: int = 0,
                     start: float = 0.0) -> List[float]:
    """``n`` Poisson-process arrival times at ``rate_hz`` (exp gaps)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return [float(t) for t in start + np.cumsum(gaps)]


def bursty_arrivals(n: int, burst_size: int, gap_s: float,
                    intra_s: float = 0.0, seed: int = 0,
                    start: float = 0.0) -> List[float]:
    """Bursts of ``burst_size`` back-to-back arrivals every ``gap_s``
    (the paper's regime: an instrument dumps a survey window at once).
    ``intra_s`` jitters samples inside a burst."""
    rng = np.random.default_rng(seed)
    times: List[float] = []
    t = start
    while len(times) < n:
        for i in range(min(burst_size, n - len(times))):
            times.append(float(t + (rng.uniform(0, intra_s)
                                    if intra_s else 0.0)))
        t += gap_s
    return sorted(times)


# ---------------------------------------------------------------------------
# Per-model service state
# ---------------------------------------------------------------------------


class _ModelService:
    def __init__(self, name: str,
                 pipelines: Dict[str, Dict[int, ServingPipeline]],
                 deadline_s: float, flush_safety: float):
        self.name = name
        # backend -> rung -> pipeline; insertion order = preference order
        # (primary first — what an unconstrained dispatch uses)
        self.pipelines = pipelines
        self.backends: Tuple[str, ...] = tuple(pipelines)
        self.ladder: Tuple[int, ...] = tuple(
            sorted(pipelines[self.backends[0]]))
        self.costs: Dict[Tuple[str, int], CostSignature] = {
            (b, r): p.cost
            for b, rungs in pipelines.items() for r, p in rungs.items()}
        # the plans' stage decompositions — what the pipelined overlap
        # ledger prices each dispatch with
        self.stages: Dict[Tuple[str, int], Tuple[StageCost, ...]] = {
            (b, r): p.stages
            for b, rungs in pipelines.items() for r, p in rungs.items()}
        self.deadline_s = deadline_s
        self.flush_safety = flush_safety
        self.queue: Deque[Request] = deque()
        self.n_submitted = 0
        self.n_deferred = 0
        self._last_deferred_rid: Optional[int] = None
        # EWMA service-time estimate per (backend, rung). Seeded at
        # register time from the plan's modeled CostSignature latency so
        # the very FIRST ragged-tail flush decision is cadence-correct
        # (the old cold-start margin of 0 made the first dispatch flush
        # exactly at the deadline, too late to compute). A seed is a
        # PRIOR: the first real observation replaces it outright (host
        # wall time and modeled ZCU104 time differ in scale); later
        # observations EWMA as before.
        self.est_service: Dict[Tuple[str, int], float] = {}
        self._seeded: set = set()
        # backends quarantined by the fault controller (demotion
        # recovery, DESIGN.md §13): dispatch skips them until repaired.
        # Empty set -> dispatch is identical to the unfaulted scheduler.
        self.quarantined: set = set()
        # arena protection mode applied by the fault controller
        # (DESIGN.md §16): 'none' until `apply_protection` swaps the
        # cost signatures for ECC/TMR-priced ones.
        self.protection: str = "none"
        self._rng = jax.random.PRNGKey(
            int(np.frombuffer(name.encode()[:4].ljust(4, b"\0"),
                              np.uint32)[0]))

    def next_rng(self) -> jax.Array:
        self._rng, sub = jax.random.split(self._rng)
        return sub

    @property
    def active_backends(self) -> Tuple[str, ...]:
        """Registration-ordered backends minus the quarantined set. If
        EVERY backend is quarantined, serving beats stopping: fall back
        to the full registration list rather than starve the queue."""
        act = tuple(b for b in self.backends if b not in self.quarantined)
        return act or self.backends

    def seed_service(self, backend: str, rung: int, seconds: float) -> None:
        """Install a modeled prior for the flush margin; replaced (not
        averaged) by the first real observation."""
        self.est_service[(backend, rung)] = seconds
        self._seeded.add((backend, rung))

    def observe_service(self, backend: str, rung: int,
                        seconds: float) -> None:
        key = (backend, rung)
        old = self.est_service.get(key)
        if old is None or key in self._seeded:
            self._seeded.discard(key)
            self.est_service[key] = seconds
        else:
            self.est_service[key] = 0.5 * old + 0.5 * seconds

    def flush_margin(self) -> float:
        """How long before the oldest deadline we must start computing:
        safety x the worst estimated rung service time on the PRIMARY
        backend (fallback backends may be orders slower — budgeting for
        them would flush everything immediately). Every rung is seeded
        with its modeled CostSignature latency at register time, so the
        margin is cadence-correct from the very first flush decision;
        real observations replace the seeds as dispatches happen."""
        primary = self.active_backends[0]
        worst = max((t for (b, _), t in self.est_service.items()
                     if b == primary), default=0.0)
        return self.flush_safety * worst

    def flush_time(self) -> Optional[float]:
        if not self.queue:
            return None
        return self.queue[0].deadline - self.flush_margin()

    def pick(self, now: float) -> Optional[Tuple[str, int, int]]:
        """(mode, rung, n_real) to dispatch at ``now``, or None to wait.

        * ``full``  — a full top-rung batch is waiting: dispatch it at
          100% fill (the largest ladder rung <= queue depth).
        * ``flush`` — the oldest request's deadline is within the safety
          margin: flush the WHOLE ragged tail as one batch, padded up to
          the smallest rung that holds it (its queue-mates' deadlines
          trail the oldest by arrival gaps, so one padded dispatch
          minimizes their worst-case latency too).
        """
        depth = len(self.queue)
        if depth == 0:
            return None
        top = self.ladder[-1]
        if depth >= top:
            return ("full", top, top)
        ft = self.flush_time()
        if ft is not None and ft <= now:
            n_real = min(depth, top)
            rung = self.ladder[bisect.bisect_left(self.ladder, n_real)]
            return ("flush", rung, n_real)
        return None


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


class ContinuousBatchingScheduler:
    """Co-serves several space models from one process: per-model queues,
    a precompiled batch ladder each, deadline-bounded batch filling, and
    round-robin dispatch across models.

    ``envelope`` (a :class:`~repro.core.energy.PowerEnvelope`) makes
    dispatch energy-budget-aware: every dispatch charges the envelope
    with the plan-time modeled (W, latency) of its cost signature, and a
    model registered with several backends falls back to the cheapest
    admissible one. With ``envelope=None`` the dispatch sequence is
    byte-for-byte the PR-2 deadline policy on the primary backend.

    ``clock`` selects what one dispatch *occupies* on the virtual clock:
    ``"measured"`` (default) uses this host's wall time per batch —
    honest for host benchmarking; ``"modeled"`` uses the cost signature's
    analytic latency, making ``serve_trace`` a deterministic,
    machine-independent simulation of the modeled deployment timeline
    (what the energy benchmarks and CI gates drive).
    """

    def __init__(self, flush_safety: float = 2.0,
                 envelope: Optional[PowerEnvelope] = None,
                 clock: str = "measured",
                 pipeline: bool = False,
                 staging_buffers: int = 2):
        if clock not in ("measured", "modeled"):
            raise ValueError(f"clock must be measured|modeled, got {clock}")
        if staging_buffers < 1:
            raise ValueError(
                f"staging_buffers must be >= 1, got {staging_buffers}")
        self.flush_safety = flush_safety
        self.envelope = envelope
        self.clock = clock
        self.pipeline = bool(pipeline)
        self.staging_buffers = int(staging_buffers)
        # dispatched-but-unretired tickets, FIFO in dispatch order; depth
        # is capped at staging_buffers (retiring the oldest frees its
        # host slot before a new dispatch would need one)
        self._inflight: Deque[_Inflight] = deque()
        self.timeline: Optional[PipelineTimeline] = (
            PipelineTimeline() if pipeline else None)
        self._svcs: Dict[str, _ModelService] = {}
        self._order: List[str] = []     # round-robin rotation
        self._rr = 0
        self._next_rid = 0
        self._lock = threading.RLock()
        self.completions: List[Completion] = []
        self.dispatches: List[DispatchRecord] = []
        self.deferrals: List[DeferralRecord] = []
        self._thread: Optional[threading.Thread] = None
        self._thread_error: Optional[BaseException] = None
        self._stop = threading.Event()
        # optional degraded-mode controller (core/faults.py); None keeps
        # serve_trace byte-for-byte the unfaulted loop
        self._faults = None

    # -- setup --------------------------------------------------------------

    def register(self, name: str, engine, backend="flex",
                 ladder: Sequence[int] = DEFAULT_LADDER,
                 deadline_s: Optional[float] = None,
                 keep_predicate: Optional[Callable] = None,
                 warmup_sample: Optional[Dict[str, np.ndarray]] = None
                 ) -> None:
        """Precompile the batch ladder for every backend and open a queue.

        ``backend`` is one backend name or a preference-ordered sequence
        (primary first); under an envelope the dispatcher may fall back
        to any of them. ``warmup_sample`` (one request dict) additionally
        runs every (backend, rung) once, paying XLA first-call costs up
        front and seeding the service-time estimates the deadline-flush
        margin uses."""
        backends = ((backend,) if isinstance(backend, str)
                    else tuple(backend))
        if not backends or any(b not in BACKENDS for b in backends):
            raise ValueError(f"bad backend(s) {backends}; "
                             f"choose from {BACKENDS}")
        if len(set(backends)) != len(backends):
            raise ValueError(f"duplicate backends {backends}")
        ladder = tuple(sorted(set(int(r) for r in ladder)))
        if not ladder or ladder[0] < 1:
            raise ValueError(f"bad ladder {ladder}")
        pipelines = {
            b: {r: ServingPipeline(engine, backend=b, batch_size=r,
                                   keep_predicate=keep_predicate,
                                   staging_buffers=self.staging_buffers)
                for r in ladder}
            for b in backends}
        if deadline_s is None:
            deadline_s = DEFAULT_DEADLINES.get(name, FALLBACK_DEADLINE)
        svc = _ModelService(name, pipelines, deadline_s, self.flush_safety)
        if self.envelope is not None:
            # the envelope must be able to admit at least ONE backend's
            # smallest-rung dispatch in some budget regime, or this model
            # could never be served
            bottom = ladder[0]
            if not any(self.envelope.feasible_ever(
                    svc.costs[(b, bottom)].power_w,
                    svc.costs[(b, bottom)].latency_s) for b in backends):
                raise ValueError(
                    f"power envelope can never admit any backend of "
                    f"{name!r} (smallest rung {bottom}); widen the budget "
                    f"or register a lower-power backend")
        # seed every (backend, rung) estimate from its plan-time cost
        # signature so the first flush decision is cadence-correct even
        # before any observation exists (a warmup or the first dispatch
        # REPLACES the seed — it is a prior, not a measurement)
        for key, sig in svc.costs.items():
            svc.seed_service(key[0], key[1], sig.latency_s)
        if warmup_sample is not None:
            for b in backends:
                for rung in ladder:
                    # first call pays XLA first-run costs; the second is
                    # the steady-state service time the flush margin
                    # budgets for
                    pipelines[b][rung].execute_batch([warmup_sample] * rung)
                    t0 = time.perf_counter()
                    pipelines[b][rung].execute_batch([warmup_sample] * rung)
                    svc.observe_service(b, rung, time.perf_counter() - t0)
        if self.clock == "modeled":
            # the modeled clock serves on the cost signature's timeline —
            # estimates come from the plan, not this host (re-seeded so a
            # wall-clock warmup above cannot leak host time into the
            # deterministic simulation)
            for key, sig in svc.costs.items():
                svc.seed_service(key[0], key[1], sig.latency_s)
        with self._lock:
            if name in self._svcs:
                raise ValueError(f"model {name!r} already registered")
            self._svcs[name] = svc
            self._order.append(name)

    @property
    def models(self) -> List[str]:
        return list(self._order)

    def attach_faults(self, controller) -> None:
        """Attach a :class:`~repro.core.faults.FaultController`:
        ``serve_trace`` will tick it every scheduling round (injection +
        due self-tests) and let its pending event times drive the idle
        virtual-clock jumps."""
        self._faults = controller

    def apply_protection(self, model: str, mode: str,
                         costs: Dict[Tuple[str, int], CostSignature]
                         ) -> None:
        """Swap a model's cost signatures for protection-priced ones
        (DESIGN.md §16): the fault controller re-prices the protected
        (backend, rung) cells through `energy.protected_signature` and
        installs them here, so backend ranking, envelope admission, and
        the modeled clock all see the ECC decode drag / TMR power
        tripling. Unlisted cells keep their unprotected signatures.
        Under the modeled clock the affected service estimates are
        re-seeded — the simulation serves on the protected timeline."""
        with self._lock:
            svc = self._svcs[model]
            for key, sig in costs.items():
                if key not in svc.costs:
                    raise KeyError(f"{model!r} has no (backend, rung) "
                                   f"cell {key}")
                svc.costs[key] = sig
                if self.clock == "modeled":
                    svc.seed_service(key[0], key[1], sig.latency_s)
            svc.protection = mode

    # -- submission ---------------------------------------------------------

    def submit(self, model: str, inputs: Dict[str, np.ndarray],
               arrival: Optional[float] = None) -> int:
        """Enqueue one request; returns its id. ``arrival`` defaults to the
        wall clock (async mode); trace mode passes virtual timestamps."""
        with self._lock:
            svc = self._svcs[model]
            arrival = time.monotonic() if arrival is None else float(arrival)
            rid = self._next_rid
            self._next_rid += 1
            svc.queue.append(Request(rid, model, inputs, arrival,
                                     arrival + svc.deadline_s))
            svc.n_submitted += 1
            return rid

    # -- dispatch core ------------------------------------------------------

    @staticmethod
    def _forced_pick(svc: _ModelService) -> Optional[Tuple[str, int, int]]:
        if not svc.queue:
            return None
        depth = min(len(svc.queue), svc.ladder[-1])
        rung = svc.ladder[bisect.bisect_left(svc.ladder, depth)]
        return ("flush", rung, depth)

    def _select_backend(self, svc: _ModelService, rung: int, now: float
                        ) -> Tuple[Optional[str], Optional[Draw]]:
        """The energy-aware backend decision for one picked dispatch:
        no envelope -> the primary backend, unconditionally (PR-2
        behavior). Under an envelope -> the admissible backend with the
        lowest modeled dispatch energy (ties resolve to registration
        order), charging the envelope; (None, None) means defer.
        Quarantined backends (fault demotion) are skipped entirely."""
        if self.envelope is None:
            return svc.active_backends[0], None
        ranked = sorted(svc.active_backends,
                        key=lambda b: svc.costs[(b, rung)].energy_j)
        for b in ranked:
            sig = svc.costs[(b, rung)]
            draw = self.envelope.admit(now, sig.power_w, sig.latency_s,
                                       tag=f"{svc.name}/{b}/b{rung}")
            if draw is not None:
                return b, draw
        return None, None

    def step(self, now: float, force: bool = False
             ) -> Optional[DispatchRecord]:
        """Dispatch at most ONE batch: scan models round-robin from the
        rotation pointer, serve the first one with a ready queue AND an
        envelope-admissible backend, advance the pointer past it. A due
        model whose every backend the envelope refuses is *deferred*
        (recorded; retried on the next step). ``force`` flushes
        regardless of deadlines (used by drain) but still respects the
        envelope. Returns the dispatch record, or None if every queue is
        waiting or deferred."""
        with self._lock:
            n = len(self._order)
            for k in range(n):
                name = self._order[(self._rr + k) % n]
                svc = self._svcs[name]
                picked = svc.pick(now)
                if picked is None and force:
                    picked = self._forced_pick(svc)
                if picked is None:
                    continue
                mode, rung, n_real = picked
                # envelope refusals degrade the rung: a smaller batch is a
                # shorter draw, so tight budgets serve smaller duty-cycled
                # chunks instead of deadlocking behind one big dispatch
                backend = draw = None
                for r in [x for x in reversed(svc.ladder) if x <= rung]:
                    backend, draw = self._select_backend(svc, r, now)
                    if backend is not None:
                        rung, n_real = r, min(n_real, r)
                        break
                if backend is None:
                    # one deferral per blocked batch-head, not per poll:
                    # the async dispatcher re-tries every poll_s and must
                    # not grow the record list unboundedly
                    head = svc.queue[0].rid
                    if head != svc._last_deferred_rid:
                        svc._last_deferred_rid = head
                        svc.n_deferred += 1
                        self.deferrals.append(
                            DeferralRecord(name, now, rung, n_real))
                    continue
                svc._last_deferred_rid = None
                reqs = [svc.queue.popleft() for _ in range(n_real)]
                self._rr = (self._rr + k + 1) % n
                break
            else:
                return None
            rng = svc.next_rng()
            sig = svc.costs[(backend, rung)]

        if self.pipeline:
            return self._step_pipelined(svc, reqs, backend, rung, n_real,
                                        mode, now, sig, draw, rng)

        t0 = time.perf_counter()
        try:
            result: BatchResult = svc.pipelines[backend][rung].execute_batch(
                [r.inputs for r in reqs], rng=rng)
        except BaseException:
            # no silent loss: put the popped batch back at the queue head
            # (original order) and refund the envelope draw before
            # surfacing the error
            with self._lock:
                svc.queue.extendleft(reversed(reqs))
                if draw is not None:
                    self.envelope.remove(draw)
            raise
        measured = time.perf_counter() - t0
        service = sig.latency_s if self.clock == "modeled" else measured

        with self._lock:
            svc.observe_service(backend, rung, service)
            finished = now + service
            rec = DispatchRecord(svc.name, rung, n_real, now, service, mode,
                                 backend=backend, energy_j=sig.energy_j,
                                 power_w=sig.power_w)
            self.dispatches.append(rec)
            for i, req in enumerate(reqs):
                self.completions.append(Completion(
                    req.rid, req.model,
                    {k: v[i] for k, v in result.outputs.items()},
                    result.keep[i], req.arrival, finished, rung, n_real,
                    req.deadline))
            return rec

    # -- pipelined dispatch (DESIGN.md §12) ---------------------------------

    def _step_pipelined(self, svc: _ModelService, reqs: List[Request],
                        backend: str, rung: int, n_real: int, mode: str,
                        now: float, sig: CostSignature,
                        draw: Optional[Draw], rng: jax.Array
                        ) -> DispatchRecord:
        """The non-blocking tail of one picked dispatch: issue an async
        ticket, append the dispatch record immediately, and defer EWMA +
        completions to retirement. The dispatch DECISION (queue pops,
        envelope draw, rung) already happened in `step` — identical to
        the synchronous path by construction, and under the modeled
        clock every recorded number (service_time, finished) is the same
        cost-signature latency the synchronous path records, so
        pipelined serving is dispatch-for-dispatch and bit-exact
        identical to ``pipeline=False``."""
        # retiring the oldest ticket(s) first keeps at most
        # staging_buffers dispatches in flight — so every pipeline's
        # slot pool can double-buffer instead of falling back to fresh
        # allocations
        self._drain_inflight(self.staging_buffers - 1)
        t0 = time.perf_counter()
        try:
            ticket = svc.pipelines[backend][rung].execute_batch_async(
                [r.inputs for r in reqs], rng=rng)
        except BaseException:
            # staging runs synchronously inside the async dispatch, so a
            # poison request surfaces HERE — same recovery as the
            # synchronous path: batch back at the queue head, draw
            # refunded
            with self._lock:
                svc.queue.extendleft(reversed(reqs))
                if draw is not None:
                    self.envelope.remove(draw)
            raise
        dispatch_s = time.perf_counter() - t0
        # modeled clock: the dispatch occupies its modeled latency (the
        # identical virtual-clock advance the synchronous path makes).
        # measured clock: the server is only busy for the non-blocking
        # dispatch call — overlap is the point — and the record's
        # service_time is rewritten to the true dispatch->retirement
        # time when the ticket retires.
        service = sig.latency_s if self.clock == "modeled" else dispatch_s
        with self._lock:
            rec = DispatchRecord(svc.name, rung, n_real, now, service, mode,
                                 backend=backend, energy_j=sig.energy_j,
                                 power_w=sig.power_w)
            rec_idx = len(self.dispatches)
            self.dispatches.append(rec)
            self._inflight.append(_Inflight(
                ticket, reqs, svc, backend, rung, n_real, now, sig, draw,
                rec_idx, t0))
            if self.timeline is not None:
                # overlap accounting: the pipelined deployment could
                # start this batch's staging as soon as its data had
                # arrived and the host channel was free
                self.timeline.add(svc.stages[(backend, rung)],
                                  earliest=max(r.arrival for r in reqs))
        return rec

    def _retire(self, inf: _Inflight) -> None:
        """Finish one in-flight dispatch: force its outputs (releasing
        the staging slot), observe the EWMA service time from ticket
        retirement, and emit its completions (FIFO retirement keeps
        completion order identical to the synchronous path)."""
        try:
            result = inf.ticket.retire()
        except BaseException:
            # no silent loss on an async failure either: batch back at
            # the queue head in original order, with the ORIGINAL arrival
            # timestamps and deadlines (Request objects are frozen), and
            # the draw refunded. The dispatch record is marked failed so
            # the inevitable re-dispatch cannot double-count the batch in
            # p50/p99, fill-histogram, or energy telemetry.
            with self._lock:
                inf.svc.queue.extendleft(reversed(inf.reqs))
                if inf.draw is not None:
                    self.envelope.remove(inf.draw)
                self.dispatches[inf.rec_idx] = dataclasses.replace(
                    self.dispatches[inf.rec_idx], failed=True)
            raise
        measured = time.perf_counter() - inf.t0
        service = inf.sig.latency_s if self.clock == "modeled" else measured
        with self._lock:
            inf.svc.observe_service(inf.backend, inf.rung, service)
            if self.clock != "modeled":
                # telemetry should report the true dispatch->retirement
                # service; the virtual clock already advanced by the
                # non-blocking dispatch time at dispatch
                self.dispatches[inf.rec_idx] = dataclasses.replace(
                    self.dispatches[inf.rec_idx], service_time=service)
            finished = inf.started + service
            for i, req in enumerate(inf.reqs):
                self.completions.append(Completion(
                    req.rid, req.model,
                    {k: v[i] for k, v in result.outputs.items()},
                    result.keep[i], req.arrival, finished, inf.rung,
                    inf.n_real, req.deadline))

    def _drain_inflight(self, keep: int = 0) -> None:
        """Retire oldest-first until at most ``keep`` remain in flight."""
        while True:
            with self._lock:
                if len(self._inflight) <= keep:
                    return
                inf = self._inflight.popleft()
            self._retire(inf)

    def sync(self) -> None:
        """Retire every in-flight ticket — the telemetry/stream barrier.
        A no-op in synchronous mode (nothing is ever in flight)."""
        self._drain_inflight(0)

    def _earliest_admit(self, svc: _ModelService, rung: int, now: float
                        ) -> Optional[float]:
        """Earliest time the envelope could admit SOME (backend, rung <=
        picked rung) of a due dispatch — how far a blocked virtual clock
        advances (step degrades rungs the same way)."""
        times = []
        for b in svc.active_backends:
            for r in svc.ladder:
                if r > rung:
                    break
                sig = svc.costs[(b, r)]
                t = self.envelope.next_admit(now, sig.power_w, sig.latency_s)
                if t is not None:
                    times.append(t)
        return min(times) if times else None

    def next_event_time(self, now: Optional[float] = None
                        ) -> Optional[float]:
        """Earliest instant the dispatch decision can change: the next
        deadline flush — or, for a queue that is due *now* but
        envelope-blocked, the envelope's next-admit time."""
        with self._lock:
            times = []
            for svc in self._svcs.values():
                picked = svc.pick(now) if now is not None else None
                if picked is not None and self.envelope is not None:
                    t = self._earliest_admit(svc, picked[1], now)
                    if t is not None:
                        times.append(max(t, now + 1e-9))
                    continue
                ft = svc.flush_time()
                if ft is not None:
                    times.append(ft)
            return min(times) if times else None

    def pending(self) -> int:
        with self._lock:
            return sum(len(svc.queue) for svc in self._svcs.values())

    def drain(self, now: float) -> float:
        """Flush every queue to empty (end of stream); returns the final
        virtual time. Under an envelope a blocked drain advances the
        clock to the next admissible instant instead of spinning."""
        while self.pending():
            rec = self.step(now, force=True)
            if rec is not None:
                now += rec.service_time
                continue
            if self.envelope is None:       # unreachable without envelope
                raise RuntimeError("drain stalled with requests pending")
            admits = []
            with self._lock:
                for svc in self._svcs.values():
                    picked = self._forced_pick(svc)
                    if picked is None:
                        continue
                    t = self._earliest_admit(svc, picked[1], now)
                    if t is not None:
                        admits.append(t)
            if not admits:
                raise RuntimeError(
                    "power envelope can never admit the remaining queued "
                    "dispatches; widen the budget")
            now = max(min(admits), now + 1e-9)
        self.sync()                     # end of stream: retire everything
        return now

    # -- virtual-clock trace serving ----------------------------------------

    def serve_trace(self, trace: Sequence[Tuple[float, str, Dict]],
                    start: float = 0.0,
                    stop_at: Optional[float] = None) -> float:
        """Serve a pre-built arrival trace of ``(t, model, inputs)`` under a
        virtual clock: arrivals occur at trace time, each dispatch occupies
        its measured execution time. Deterministic given the trace; returns
        the final virtual time.

        ``stop_at`` halts the loop once the clock reaches that instant —
        the watchdog-reboot cut point (DESIGN.md §13): every arrival with
        ``t <= `` the returned time has been submitted (accepted into a
        queue, hence checkpointable), in-flight tickets are retired, and
        queued-but-undispatched requests stay queued. The caller resumes
        by replaying the remaining trace events (``t >`` the returned
        time) into a restored scheduler."""
        ev = sorted(trace, key=lambda e: e[0])
        now, i, n = start, 0, len(ev)
        while i < n or self.pending():
            while i < n and ev[i][0] <= now + 1e-12:
                self.submit(ev[i][1], ev[i][2], arrival=ev[i][0])
                i += 1
            if stop_at is not None and now >= stop_at - 1e-12:
                break                           # accepted, not yet served
            if self._faults is not None:
                now = self._faults.tick(self, now)
            rec = self.step(now)
            if rec is not None:
                now += rec.service_time         # server busy while computing
                continue
            nxt = ev[i][0] if i < n else None
            ft = self.next_event_time(now)
            if ft is not None:
                nxt = ft if nxt is None else min(nxt, ft)
            if self._faults is not None:
                et = self._faults.next_event_time(now)
                if et is not None:
                    nxt = et if nxt is None else min(nxt, et)
            if nxt is None:
                if self.pending():
                    # only reachable under an envelope whose remaining
                    # schedule can never admit the queued dispatches —
                    # surface it, never strand requests silently
                    raise RuntimeError(
                        "power envelope can never admit the remaining "
                        "queued dispatches; widen the budget")
                break
            # guarantee progress: a blocked queue's next event must move
            # the clock strictly forward
            now = max(now + 1e-9, nxt) if nxt <= now else nxt
        self.sync()                     # end of stream: retire everything
        if self._faults is not None and stop_at is None:
            now = self._faults.finalize(self, now)
        return now

    # -- checkpoint/restore (DESIGN.md §13) ---------------------------------

    @staticmethod
    def _raw_key(key: jax.Array) -> np.ndarray:
        if jax.dtypes.issubdtype(key.dtype, jax.dtypes.prng_key):
            key = jax.random.key_data(key)
        return np.asarray(key)

    def state_dict(self) -> Dict:
        """The scheduler ledger as a plain-python/numpy tree: accepted
        queues (request ids, inputs, ORIGINAL arrivals and deadlines),
        EWMA service state, per-model RNG, quarantine sets, dispatch and
        deferral records, and completion METADATA (outputs are not
        checkpointed — completed results were already delivered, and the
        restored records keep p50/p99/fill telemetry exact).

        In-flight tickets are retired first (``sync()``): a checkpoint
        cut is a quiesce point, never a torn dispatch. Compiled plans,
        packed weights, and the pipeline timeline are NOT state — a
        reboot reloads the bitstream and re-registers the same models,
        then :meth:`load_state_dict` overlays this ledger."""
        self.sync()
        with self._lock:
            models = {}
            for name, svc in self._svcs.items():
                models[name] = {
                    "deadline_s": svc.deadline_s,
                    "backends": list(svc.backends),
                    "ladder": list(svc.ladder),
                    "n_submitted": svc.n_submitted,
                    "n_deferred": svc.n_deferred,
                    "last_deferred_rid": svc._last_deferred_rid,
                    "queue": [
                        {"rid": r.rid, "arrival": r.arrival,
                         "deadline": r.deadline,
                         "inputs": {k: np.asarray(v)
                                    for k, v in r.inputs.items()}}
                        for r in svc.queue],
                    "est_service": [[b, r, t] for (b, r), t
                                    in svc.est_service.items()],
                    "seeded": [[b, r] for (b, r) in sorted(svc._seeded)],
                    "rng": self._raw_key(svc._rng),
                    "quarantined": sorted(svc.quarantined),
                }
            return {
                "version": 1,
                "flush_safety": self.flush_safety,
                "clock": self.clock,
                "pipeline": self.pipeline,
                "next_rid": self._next_rid,
                "rr": self._rr,
                "order": list(self._order),
                "models": models,
                "dispatches": [dataclasses.asdict(d)
                               for d in self.dispatches],
                "deferrals": [dataclasses.asdict(d)
                              for d in self.deferrals],
                "completions": [
                    {"rid": c.rid, "model": c.model, "kept": bool(c.kept),
                     "arrival": c.arrival, "finished": c.finished,
                     "rung": c.rung, "n_real": c.n_real,
                     "deadline": c.deadline}
                    for c in self.completions],
            }

    def load_state_dict(self, state: Dict) -> None:
        """Overlay a :meth:`state_dict` ledger onto a freshly constructed
        scheduler with the SAME models registered (same backends and
        ladders — validated): the reboot protocol is re-register from
        pristine plans, then restore. Restored completions carry their
        metadata with empty ``outputs`` (already delivered pre-reboot)."""
        if state.get("version") != 1:
            raise ValueError(
                f"unsupported scheduler checkpoint version "
                f"{state.get('version')!r}")
        with self._lock:
            if sorted(self._svcs) != sorted(state["models"]):
                raise ValueError(
                    f"checkpoint models {sorted(state['models'])} do not "
                    f"match registered models {sorted(self._svcs)}")
            for name, ms in state["models"].items():
                svc = self._svcs[name]
                if (list(svc.backends) != list(ms["backends"])
                        or list(svc.ladder) != list(ms["ladder"])):
                    raise ValueError(
                        f"checkpoint for {name!r} was taken with backends="
                        f"{ms['backends']} ladder={ms['ladder']}; "
                        f"re-register to match before restoring")
                svc.deadline_s = float(ms["deadline_s"])
                svc.n_submitted = int(ms["n_submitted"])
                svc.n_deferred = int(ms["n_deferred"])
                lr = ms["last_deferred_rid"]
                svc._last_deferred_rid = None if lr is None else int(lr)
                svc.queue.clear()
                for q in ms["queue"]:
                    svc.queue.append(Request(
                        int(q["rid"]), name,
                        {k: np.asarray(v) for k, v in q["inputs"].items()},
                        float(q["arrival"]), float(q["deadline"])))
                svc.est_service = {(str(b), int(r)): float(t)
                                   for b, r, t in ms["est_service"]}
                svc._seeded = {(str(b), int(r)) for b, r in ms["seeded"]}
                raw = np.asarray(ms["rng"], dtype=np.uint32)
                if jax.dtypes.issubdtype(svc._rng.dtype,
                                         jax.dtypes.prng_key):
                    svc._rng = jax.random.wrap_key_data(jax.numpy.asarray(raw))
                else:
                    svc._rng = jax.numpy.asarray(raw)
                svc.quarantined = set(ms["quarantined"])
            self._next_rid = int(state["next_rid"])
            self._rr = int(state["rr"])
            self._order = list(state["order"])
            self.dispatches = [DispatchRecord(**d)
                               for d in state["dispatches"]]
            self.deferrals = [DeferralRecord(**d)
                              for d in state["deferrals"]]
            self.completions = [Completion(outputs={}, **c)
                                for c in state["completions"]]

    # -- asynchronous (wall-clock) mode -------------------------------------

    def start(self, poll_s: float = 0.001) -> None:
        """Run the dispatcher on a background thread against the wall
        clock; producers call :meth:`submit` concurrently."""
        if self._thread is not None:
            raise RuntimeError("scheduler already started")
        self._stop.clear()
        self._thread_error = None

        def loop():
            while not self._stop.is_set():
                try:
                    rec = self.step(time.monotonic())
                except BaseException as ex:     # batch re-queued by step()
                    self._thread_error = ex
                    return
                if rec is None:
                    time.sleep(poll_s)

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="cb-scheduler")
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the dispatcher thread; by default flush what's queued.
        Re-raises an error that killed the dispatcher (its batch was
        re-queued, so nothing was lost — but serving DID stop)."""
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join()
        self._thread = None
        if self._thread_error is not None:
            err, self._thread_error = self._thread_error, None
            raise err
        if drain:
            self.drain(time.monotonic())    # drain() ends with sync()
        else:
            self.sync()

    # -- telemetry ----------------------------------------------------------

    def telemetry(self) -> Dict[str, ModelTelemetry]:
        self.sync()     # telemetry boundary: retire in-flight tickets first
        with self._lock:
            out: Dict[str, ModelTelemetry] = {}
            for name, svc in self._svcs.items():
                tel = ModelTelemetry(name, svc.deadline_s,
                                     n_submitted=svc.n_submitted)
                comps = [c for c in self.completions if c.model == name]
                # failed dispatches were requeued and re-dispatched: only
                # the records that actually produced completions count,
                # or the retried batch double-counts fill/energy/p99
                disps = [d for d in self.dispatches
                         if d.model == name and not d.failed]
                tel.n_failed_dispatches = sum(
                    1 for d in self.dispatches
                    if d.model == name and d.failed)
                tel.n_staging_fallbacks = sum(
                    p.arena.n_fallback
                    for rungs in svc.pipelines.values()
                    for p in rungs.values())
                tel.n_completed = len(comps)
                tel.n_kept = sum(c.kept for c in comps)
                tel.deadline_misses = sum(c.missed_deadline for c in comps)
                tel.n_dispatches = len(disps)
                span = ((max(c.finished for c in comps)
                         - min(c.arrival for c in comps)) if comps else 0.0)
                if comps:
                    lat = np.array([c.latency for c in comps])
                    tel.p50_latency_ms = float(np.percentile(lat, 50) * 1e3)
                    tel.p99_latency_ms = float(np.percentile(lat, 99) * 1e3)
                    tel.fps = len(comps) / max(span, 1e-12)
                if disps:
                    tel.mean_batch_fill = float(
                        np.mean([d.fill for d in disps]))
                    for rung in svc.ladder:
                        at = [d.fill for d in disps if d.rung == rung]
                        if at:
                            tel.fill_hist[rung] = {
                                "dispatches": len(at),
                                "mean_fill": float(np.mean(at))}
                    tel.energy_j = float(sum(d.energy_j for d in disps))
                    tel.j_per_inference = tel.energy_j / max(tel.n_completed,
                                                             1)
                    for d in disps:
                        tel.backend_counts[d.backend] = (
                            tel.backend_counts.get(d.backend, 0) + 1)
                    busy = sum(d.modeled_latency_s for d in disps)
                    tel.duty_cycle = busy / span if span > 0 else 0.0
                tel.n_deferrals = svc.n_deferred
                out[name] = tel
            return out

    def envelope_report(self) -> Optional[Dict]:
        """The envelope's ledger audit (None when serving unbudgeted):
        total J, duty cycle, max trailing-window W, and the violation
        count — which admission-time checking keeps at zero."""
        return None if self.envelope is None else self.envelope.audit()

    def overlap_report(self) -> Optional[Dict]:
        """The pipelined overlap ledger (None when pipeline=False):
        pipelined vs serialized makespan of the dispatched stage chains,
        the effective-throughput speedup, and per-resource occupancy.
        Deterministic and machine-independent under clock="modeled"."""
        return None if self.timeline is None else self.timeline.report()

    def summary(self) -> str:
        lines = []
        for name, tel in self.telemetry().items():
            lines.append(
                f"[{name}] {tel.n_completed}/{tel.n_submitted} served  "
                f"fps={tel.fps:.1f}  p50={tel.p50_latency_ms:.2f} ms  "
                f"p99={tel.p99_latency_ms:.2f} ms "
                f"(deadline {tel.deadline_s*1e3:.0f} ms, "
                f"{tel.deadline_misses} missed)  "
                f"fill={tel.mean_batch_fill:.0%} over {tel.n_dispatches} "
                f"dispatches  kept={tel.n_kept} "
                f"(downlink -{tel.downlink_reduction:.0%})")
            if tel.energy_j > 0:
                mix = " ".join(f"{b}:{c}" for b, c in
                               sorted(tel.backend_counts.items()))
                lines.append(
                    f"    energy={tel.energy_j:.4f} J "
                    f"({tel.j_per_inference*1e3:.4f} mJ/inf)  "
                    f"duty={tel.duty_cycle:.1%}  "
                    f"deferrals={tel.n_deferrals}  backends[{mix}]")
        rep = self.envelope_report()
        if rep is not None:
            lines.append(
                f"[envelope] {rep['total_j']:.4f} J over "
                f"{rep['n_draws']} draws  duty={rep['duty_cycle']:.1%}  "
                f"max-window={rep['max_window_w']:.2f} W  "
                f"violations={rep['n_violations']}")
        ov = self.overlap_report()
        if ov is not None and ov["n_dispatches"]:
            occ = " ".join(f"{r}:{o:.0%}" for r, o in
                           sorted(ov["occupancy"].items()))
            lines.append(
                f"[pipeline] modeled overlap {ov['overlap_speedup_x']:.2f}x "
                f"({ov['serial_span_s']:.4f} s serial -> "
                f"{ov['pipelined_span_s']:.4f} s pipelined over "
                f"{ov['n_dispatches']} dispatches)  occupancy[{occ}]")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# LM serving: the prefill/decode rung ladder (DESIGN.md §15)
# ---------------------------------------------------------------------------
#
# Autoregressive decode is a different shape of workload from the frame
# stream above: a request is admitted ONCE (prefill — compute-bound, rides
# the same compiled batch-size ladder as the CNNs), then produces tokens
# over MANY small steps (decode — memory-bound, batched across every
# in-flight request at its KV slot). ``LMScheduler`` owns that loop:
#
# * prefill dispatches at the largest ladder rung the waiting queue
#   fills, flushing a ragged tail early when the oldest waiting request's
#   deadline slack falls under a safety margin of the estimated remaining
#   work (EWMA-measured prefill + per-token decode times) — the same
#   wait-to-fill / deadline-flush trade the frame scheduler makes;
# * decode steps batch ALL in-flight requests at the smallest decode rung
#   that holds them, padding dead lanes to the engine's scratch slot, so
#   rung programs are traced once and steady-state decode never re-traces
#   and never allocates (the LMEngine's n_traces / KVSlotAllocator
#   contract);
# * tokens stream out as they are produced (``TokenEvent`` carries a
#   wall timestamp), and telemetry reports tokens/s plus per-phase
#   latency percentiles — time-to-first-token, prefill service, decode
#   step — the serving numbers an on-board LM deployment is sized by.


@dataclasses.dataclass(frozen=True)
class LMRequest:
    rid: int
    x: np.ndarray                       # [S, D] prompt window
    deadline_s: float = 10.0            # completion deadline from submit
    max_new_tokens: int = 8             # tokens to generate (incl. first)


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token: emitted the moment its dispatch retires."""
    rid: int
    index: int                          # 0-based position in the response
    token: int
    time: float                         # wall perf_counter timestamp
    phase: str                          # 'prefill' (first token) | 'decode'


@dataclasses.dataclass(frozen=True)
class LMCompletion:
    rid: int
    tokens: Tuple[int, ...]
    submitted: float
    first_token_t: float
    finished: float
    deadline: float

    @property
    def ttft_s(self) -> float:
        return self.first_token_t - self.submitted

    @property
    def latency_s(self) -> float:
        return self.finished - self.submitted

    @property
    def missed_deadline(self) -> bool:
        return self.finished > self.deadline


@dataclasses.dataclass
class _LMInflight:
    req: LMRequest
    slot: int
    hidden: np.ndarray                  # [D] feedback features
    tokens: List[int]
    submitted: float
    first_token_t: float


@dataclasses.dataclass
class LMTelemetry:
    n_submitted: int = 0
    n_completed: int = 0
    n_tokens: int = 0
    tokens_per_s: float = 0.0
    ttft_p50_ms: float = 0.0
    prefill_p50_ms: float = 0.0         # per-dispatch prefill service
    decode_step_p50_ms: float = 0.0     # per-dispatch decode service
    deadline_misses: int = 0
    n_prefill_dispatches: int = 0
    n_decode_dispatches: int = 0
    n_deadline_flushes: int = 0         # ragged prefills a deadline forced
    mean_prefill_fill: float = 0.0
    mean_decode_fill: float = 0.0
    n_slot_assigns: int = 0
    slot_high_water: int = 0
    n_traces: int = 0                   # steady-state serving: constant

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def _p50(xs: List[float]) -> float:
    return float(np.percentile(xs, 50)) if xs else 0.0


class LMScheduler:
    """Prefill/decode scheduler over one :class:`~repro.core.lm.LMEngine`.

    ``prefill_ladder`` rungs are compiled-plan batch sizes (capped at the
    engine's slot count — a prefill lane needs a slot); ``decode_ladder``
    rungs are decode-program widths. ``flush_margin`` scales the
    deadline-flush test: a ragged prefill dispatches once the oldest
    waiting request's slack drops under ``margin * estimated remaining
    work``.
    """

    def __init__(self, lm, prefill_ladder: Optional[Sequence[int]] = None,
                 decode_ladder: Optional[Sequence[int]] = None,
                 flush_margin: float = 2.0):
        self.lm = lm
        top = lm.n_slots
        self.prefill_ladder = tuple(
            prefill_ladder if prefill_ladder is not None
            else capped_ladder(top))
        self.decode_ladder = tuple(
            decode_ladder if decode_ladder is not None
            else capped_ladder(top, base=(1, 2, 4, 8, 16)))
        if max(self.prefill_ladder) > top:
            raise ValueError(
                f"prefill rung {max(self.prefill_ladder)} exceeds "
                f"{top} KV slot(s)")
        self.flush_margin = flush_margin
        self.waiting: Deque[Tuple[LMRequest, float]] = deque()
        self.inflight: List[_LMInflight] = []
        self.completions: List[LMCompletion] = []
        self.events: List[TokenEvent] = []
        # EWMA service estimates (seed pessimistically; first dispatches
        # correct them)
        self._prefill_ewma = 0.1
        self._decode_ewma = 0.02
        self._prefill_times: List[float] = []
        self._decode_times: List[float] = []
        self._prefill_fills: List[float] = []
        self._decode_fills: List[float] = []
        self._n_flushes = 0
        self._t_start: Optional[float] = None
        self._t_end: Optional[float] = None

    # -- submission ----------------------------------------------------------

    def submit(self, req: LMRequest) -> None:
        if req.x.shape != (self.lm.seq_len, self.lm.d_model):
            raise ValueError(
                f"prompt window must be [{self.lm.seq_len}, "
                f"{self.lm.d_model}], got {req.x.shape}")
        if req.max_new_tokens > self.lm.max_new_tokens:
            raise ValueError(
                f"max_new_tokens {req.max_new_tokens} exceeds the KV "
                f"plan's decode budget {self.lm.max_new_tokens}")
        self.waiting.append((req, time.perf_counter()))

    # -- scheduling core -----------------------------------------------------

    def _free_slots(self) -> int:
        return self.lm.n_slots - self.lm.slots.in_use

    def _rung(self, ladder: Sequence[int], n: int) -> int:
        """Smallest rung holding ``n`` (the largest rung caps n)."""
        for r in ladder:
            if r >= n:
                return r
        return max(ladder)

    def _urgent(self, now: float) -> bool:
        """Deadline-flush test on the oldest waiting request."""
        if not self.waiting:
            return False
        req, sub = self.waiting[0]
        remaining = (self._prefill_ewma
                     + req.max_new_tokens * self._decode_ewma)
        return (sub + req.deadline_s) - now < self.flush_margin * remaining

    def _should_prefill(self, now: float) -> bool:
        n_admit = min(len(self.waiting), self._free_slots())
        if n_admit == 0:
            return False
        if n_admit >= max(self.prefill_ladder):
            return True                 # a full top rung never waits
        if not self.inflight:
            return True                 # nothing else to run
        return self._urgent(now)        # ragged tail: only when forced

    def step(self) -> bool:
        """One scheduling decision (a prefill or a decode dispatch).
        Returns False when there is nothing left to do."""
        now = time.perf_counter()
        if self._t_start is None:
            self._t_start = now
        if self._should_prefill(now):
            self._dispatch_prefill()
        elif self.inflight:
            self._dispatch_decode()
        elif self.waiting:
            # waiting requests but no free slot and nothing in flight
            # cannot happen (in-flight requests own the slots) — guard
            # against a stuck queue anyway
            raise RuntimeError("waiting requests with no runnable work")
        else:
            return False
        self._t_end = time.perf_counter()
        return True

    def run(self) -> List[LMCompletion]:
        """Drive to idle: serve every submitted request to completion."""
        while self.step():
            pass
        return self.completions

    # -- dispatches ----------------------------------------------------------

    def _dispatch_prefill(self) -> None:
        n_admit = min(len(self.waiting), self._free_slots())
        rung = self._rung(self.prefill_ladder, n_admit)
        n_real = min(n_admit, rung)
        batch: List[Tuple[LMRequest, float]] = [
            self.waiting.popleft() for _ in range(n_real)]
        slots = [self.lm.assign_slot(req.rid) for req, _ in batch]
        x = np.zeros((rung, self.lm.seq_len, self.lm.d_model), np.float32)
        slot_ids = np.full((rung,), self.lm.scratch_slot, np.int32)
        for i, (req, _) in enumerate(batch):
            x[i] = req.x
            slot_ids[i] = slots[i]
        t0 = time.perf_counter()
        res = self.lm.prefill(x, slot_ids)
        t1 = time.perf_counter()
        self._prefill_ewma = 0.7 * self._prefill_ewma + 0.3 * (t1 - t0)
        self._prefill_times.append(t1 - t0)
        self._prefill_fills.append(n_real / rung)
        if n_real < rung:
            self._n_flushes += 1
        for i, (req, sub) in enumerate(batch):
            tok = int(res.tokens[i])
            self.events.append(TokenEvent(req.rid, 0, tok, t1, "prefill"))
            fl = _LMInflight(req=req, slot=slots[i], hidden=res.hidden[i],
                             tokens=[tok], submitted=sub, first_token_t=t1)
            if req.max_new_tokens <= 1:
                self._retire(fl, t1)
            else:
                self.inflight.append(fl)

    def _dispatch_decode(self) -> None:
        rung = self._rung(self.decode_ladder, len(self.inflight))
        active = self.inflight[:rung]
        hidden = np.zeros((rung, self.lm.d_model), np.float32)
        slot_ids = np.full((rung,), self.lm.scratch_slot, np.int32)
        for i, fl in enumerate(active):
            hidden[i] = fl.hidden
            slot_ids[i] = fl.slot
        t0 = time.perf_counter()
        res = self.lm.decode_step(hidden, slot_ids)
        t1 = time.perf_counter()
        self._decode_ewma = 0.7 * self._decode_ewma + 0.3 * (t1 - t0)
        self._decode_times.append(t1 - t0)
        self._decode_fills.append(len(active) / rung)
        done: List[_LMInflight] = []
        for i, fl in enumerate(active):
            fl.tokens.append(int(res.tokens[i]))
            fl.hidden = res.hidden[i]
            self.events.append(TokenEvent(
                fl.req.rid, len(fl.tokens) - 1, fl.tokens[-1], t1,
                "decode"))
            if len(fl.tokens) >= fl.req.max_new_tokens:
                done.append(fl)
        for fl in done:
            self.inflight.remove(fl)
            self._retire(fl, t1)

    def _retire(self, fl: _LMInflight, t: float) -> None:
        self.lm.release_slot(fl.req.rid)
        self.completions.append(LMCompletion(
            rid=fl.req.rid, tokens=tuple(fl.tokens),
            submitted=fl.submitted, first_token_t=fl.first_token_t,
            finished=t, deadline=fl.submitted + fl.req.deadline_s))

    # -- reporting -----------------------------------------------------------

    def telemetry(self) -> LMTelemetry:
        tel = LMTelemetry()
        tel.n_submitted = (len(self.completions) + len(self.inflight)
                           + len(self.waiting))
        tel.n_completed = len(self.completions)
        tel.n_tokens = (sum(len(c.tokens) for c in self.completions)
                        + sum(len(f.tokens) for f in self.inflight))
        span = ((self._t_end or 0.0) - (self._t_start or 0.0))
        tel.tokens_per_s = tel.n_tokens / span if span > 0 else 0.0
        tel.ttft_p50_ms = _p50(
            [c.ttft_s for c in self.completions]) * 1e3
        tel.prefill_p50_ms = _p50(self._prefill_times) * 1e3
        tel.decode_step_p50_ms = _p50(self._decode_times) * 1e3
        tel.deadline_misses = sum(
            1 for c in self.completions if c.missed_deadline)
        tel.n_prefill_dispatches = len(self._prefill_times)
        tel.n_decode_dispatches = len(self._decode_times)
        tel.n_deadline_flushes = self._n_flushes
        tel.mean_prefill_fill = (float(np.mean(self._prefill_fills))
                                 if self._prefill_fills else 0.0)
        tel.mean_decode_fill = (float(np.mean(self._decode_fills))
                                if self._decode_fills else 0.0)
        tel.n_slot_assigns = self.lm.slots.n_assigns
        tel.slot_high_water = self.lm.slots.high_water
        tel.n_traces = self.lm.n_traces
        return tel

    def summary(self) -> str:
        tel = self.telemetry()
        return (
            f"[lm] {tel.n_completed}/{tel.n_submitted} served  "
            f"{tel.n_tokens} tokens @ {tel.tokens_per_s:.1f} tok/s  "
            f"ttft p50={tel.ttft_p50_ms:.2f} ms  "
            f"prefill p50={tel.prefill_p50_ms:.2f} ms  "
            f"decode-step p50={tel.decode_step_p50_ms:.2f} ms  "
            f"misses={tel.deadline_misses}\n"
            f"     {tel.n_prefill_dispatches} prefill "
            f"(fill={tel.mean_prefill_fill:.0%}, "
            f"{tel.n_deadline_flushes} deadline flushes) + "
            f"{tel.n_decode_dispatches} decode "
            f"(fill={tel.mean_decode_fill:.0%}) dispatches  "
            f"slots hw={tel.slot_high_water}/{self.lm.n_slots} "
            f"assigns={tel.n_slot_assigns}  traces={tel.n_traces}")
