"""Graph-compiler pass pipeline — rewrites the op graph before lowering
(DESIGN.md §10).

The paper's custom HLS designs beat op-by-op DPU dispatch because they
stream layer outputs through on-chip buffers instead of round-tripping
DDR between every operator. The seed planner lowered one node at a time:
each int8 conv/dense dequantized to fp32, wrote a full activation, and
the next node requantized it. This module is the missing middle stage —
a small multi-pass graph compiler the `ExecutionPlan` runs between the
inspector's backend assignment and segment partitioning:

* **constant folding** — subgraphs with no path from any graph input are
  evaluated once at plan time and replaced by ``const`` nodes.
* **dead-node elimination** — nodes from which no graph output is
  reachable are dropped.
* **epilogue fusion** — a sole-consumer relu/sigmoid folds into its
  producing conv2d/dense as a ``fused`` node (the act node's *name*, so
  downstream references and graph outputs keep resolving; parameters
  stay keyed under the producer via ``param_of``). On the accel path a
  sigmoid epilogue runs inside the int8 kernel's fp32 epilogue — the
  HLS idiom of streaming the activation right after the MAC array.
* **requant fusion** — the headline: an int8 producer whose value flows
  (possibly through int8-safe ``maxpool2d``/``flatten``) only into int8
  consumers gets a ``requant_scale``: the kernel re-quantizes its output
  to int8 *in the epilogue* at the consumers' calibration scale, the
  chain ops run in the int8 domain, and the consumers take int8 input
  directly — no fp32 dequant round-trip ever touches DDR. Exactness:
  ``clip(round(x/s))`` is monotone, so it commutes with max-pooling and
  reshape bit-for-bit; the consumer sees the very same int8 values the
  unfused plan would have computed.

Every pass records what it did in a :class:`PassReport`; the
`ExecutionPlan.summary()` prints the fusion groups, and a ``fuse=False``
engine skips this module entirely (the escape hatch that reproduces the
pre-pass plans node-for-node).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.opgraph import (FUSABLE_EPILOGUES, RANDOM_OPS, Graph,
                                Node, base_op, consumers, param_node)

# ops whose value the requant-fusion pass may keep in the int8 domain:
# max-pooling commutes with the monotone quantizer, flatten is a reshape.
INT8_SAFE_CHAIN_OPS = frozenset({"maxpool2d", "flatten"})

# ops that cannot be constant-folded at plan time (need per-call state)
UNFOLDABLE = RANDOM_OPS | {"input", "const", "fused"}


@dataclasses.dataclass(frozen=True)
class FusionGroup:
    """One epilogue fusion: (producer + act) -> fused node ``name``."""
    name: str                       # the fused node (the act node's name)
    base: str                       # conv2d | dense
    param_of: str                   # original producer (params key)
    epilogue: Tuple[str, ...]       # ('relu',) | ('sigmoid',)
    backend: str


@dataclasses.dataclass(frozen=True)
class RequantGroup:
    """One int8 producer->consumer fusion: ``producer`` requantizes in
    its epilogue, ``chain`` runs int8, ``consumers`` take int8 input."""
    producer: str
    chain: Tuple[str, ...]
    consumers: Tuple[str, ...]
    scale: float


@dataclasses.dataclass
class PassReport:
    folded: List[str] = dataclasses.field(default_factory=list)
    eliminated: List[str] = dataclasses.field(default_factory=list)
    fusion_groups: List[FusionGroup] = dataclasses.field(default_factory=list)
    requant_groups: List[RequantGroup] = dataclasses.field(
        default_factory=list)
    kv_int8_nodes: List[str] = dataclasses.field(default_factory=list)

    @property
    def n_rewrites(self) -> int:
        return (len(self.folded) + len(self.eliminated)
                + len(self.fusion_groups) + len(self.requant_groups)
                + len(self.kv_int8_nodes))

    def summary(self) -> str:
        lines = []
        if self.folded:
            lines.append(f"  const-folded: {self.folded}")
        if self.eliminated:
            lines.append(f"  dead nodes eliminated: {self.eliminated}")
        for fg in self.fusion_groups:
            lines.append(f"  fused [{fg.backend}] {fg.param_of} + "
                         f"{'+'.join(fg.epilogue)} -> {fg.name}")
        for rq in self.requant_groups:
            via = f" via {list(rq.chain)}" if rq.chain else ""
            lines.append(f"  int8-chain {rq.producer}{via} -> "
                         f"{list(rq.consumers)} (requant s={rq.scale:.3g})")
        if self.kv_int8_nodes:
            lines.append(f"  int8 KV stream: {self.kv_int8_nodes}")
        return "\n".join(lines) if lines else "  (no rewrites)"


@dataclasses.dataclass
class PassContext:
    """Everything a pass may consult or update. ``assignment`` is the
    inspector's per-node backend map (post PTQ-demotion) and is kept in
    sync with rewrites; ``quant``/``act_absmax`` are the PTQ constants
    (None / empty on flex plans)."""
    params: Dict[str, Dict[str, Any]]
    assignment: Dict[str, str]
    quant: Optional[Dict[str, Any]] = None
    act_absmax: Optional[Dict[str, float]] = None


def _is_quantized_compute(node: Node, ctx: PassContext) -> bool:
    """Does this node run on the int8 accel kernels under ``ctx``?"""
    return (base_op(node) in ("conv2d", "dense")
            and ctx.quant is not None
            and param_node(node) in ctx.quant
            and ctx.assignment.get(node.name) == "accel")


# ---------------------------------------------------------------------------
# Passes
# ---------------------------------------------------------------------------


def constant_fold(graph: Graph, ctx: PassContext,
                  report: PassReport) -> Graph:
    """Evaluate nodes with no transitive dependence on a graph input once
    at plan time; replace each with a ``const`` node of the same name."""
    from repro.core.engine import OP_IMPLS      # late: engine imports plan

    values: Dict[str, np.ndarray] = {}
    for name in graph.order:
        node = graph.nodes[name]
        if node.op == "const":
            values[name] = np.asarray(node.attrs["value"])
            continue
        if node.op in UNFOLDABLE or not node.inputs:
            continue
        if not all(i in values for i in node.inputs):
            continue
        out = OP_IMPLS[node.op]([values[i] for i in node.inputs],
                                ctx.params.get(name, {}), node.attrs, None)
        values[name] = np.asarray(out)
        folded = Node(name, "const", [], {"value": values[name]},
                      out_shape=tuple(values[name].shape))
        graph.nodes[name] = folded
        ctx.assignment[name] = ctx.assignment.get(name, "flex")
        report.folded.append(name)
    return graph


def eliminate_dead_nodes(graph: Graph, ctx: PassContext,
                         report: PassReport) -> Graph:
    """Drop nodes from which no graph output is reachable (inputs stay —
    they define the lowered call signature; random ops stay too, dead or
    not: each one advances the per-sample RNG split chain, so removing
    one would shift every later random node's keys and break the
    fused==unfused bit-exactness contract)."""
    live = set(graph.outputs) | {n.name for n in graph.nodes.values()
                                 if n.op in RANDOM_OPS}
    for name in reversed(graph.order):
        if name in live:
            live.update(graph.nodes[name].inputs)
    removed = [n for n in graph.order
               if n not in live and graph.nodes[n].op != "input"]
    for name in removed:
        del graph.nodes[name]
        graph.order.remove(name)
        ctx.assignment.pop(name, None)
        report.eliminated.append(name)
    return graph


def fuse_epilogues(graph: Graph, ctx: PassContext,
                   report: PassReport) -> Graph:
    """Fold a sole-consumer relu/sigmoid into its producing conv2d/dense.

    The rewritten node takes the ACT node's name (so downstream inputs
    and graph outputs keep resolving) and points at the producer's
    parameters via ``param_of``. Quantized producers may absorb any
    fusable epilogue — it runs inside the kernel's fp32 epilogue — which
    pulls e.g. ESPERTA's sigmoid onto the accel segment; fp32 producers
    only fuse with an act already assigned to the same backend.
    """
    cons = consumers(graph)
    for name in list(graph.order):
        node = graph.nodes.get(name)
        if node is None or node.op not in ("conv2d", "dense"):
            continue
        if name in graph.outputs or len(cons[name]) != 1:
            continue
        act_name = cons[name][0]
        act = graph.nodes[act_name]
        if act.op not in FUSABLE_EPILOGUES:
            continue
        quantized = _is_quantized_compute(node, ctx)
        backend = ctx.assignment.get(name, "flex")
        if not quantized and ctx.assignment.get(act_name) != backend:
            continue
        attrs = dict(node.attrs)
        attrs.update(base_op=node.op, epilogue=(act.op,), param_of=name)
        fused = Node(act_name, "fused", list(node.inputs), attrs)
        from repro.core.opgraph import _infer
        _infer(fused, [graph.nodes[i] for i in node.inputs])
        # the fused node takes the PRODUCER's slot (its inputs are the
        # producer's, so defining it early keeps their liveness tight);
        # the act's original slot is deleted
        idx = graph.order.index(name)
        graph.order[idx] = act_name
        del graph.order[graph.order.index(act_name, idx + 1)]
        del graph.nodes[name]
        graph.nodes[act_name] = fused
        ctx.assignment.pop(name, None)
        ctx.assignment[act_name] = backend
        # keep the consumer map usable for later candidates in this walk
        cons[act_name] = cons.get(act_name, [])
        report.fusion_groups.append(FusionGroup(
            act_name, attrs["base_op"], name, attrs["epilogue"], backend))
    return graph


def fuse_requant(graph: Graph, ctx: PassContext,
                 report: PassReport) -> Graph:
    """Keep int8 producer->consumer values on-chip: the producer
    requantizes in its kernel epilogue at the consumers' calibration
    scale, int8-safe chain ops stay in the int8 domain, and consumers
    skip their own quantize step. Bit-exact vs the unfused plan because
    the quantizer is monotone (commutes with maxpool) and flatten is a
    reshape — see module docstring."""
    if ctx.quant is None or not ctx.act_absmax:
        return graph
    cons = consumers(graph)
    for name in graph.order:
        node = graph.nodes[name]
        if not _is_quantized_compute(node, ctx) or name in graph.outputs:
            continue
        if node.attrs.get("requant_scale") is not None:
            continue
        chain: List[str] = []
        cur = name
        endpoints: Tuple[str, ...] = ()
        while True:
            cs = cons.get(cur, [])
            if not cs:
                break
            if (len(cs) == 1 and graph.nodes[cs[0]].op in INT8_SAFE_CHAIN_OPS
                    and cs[0] not in graph.outputs
                    and ctx.assignment.get(cs[0]) == "accel"):
                chain.append(cs[0])
                cur = cs[0]
                continue
            if all(_is_quantized_compute(graph.nodes[c], ctx)
                   and not graph.nodes[c].attrs.get("int8_input")
                   for c in cs):
                endpoints = tuple(cs)
            break
        if not endpoints:
            continue
        absmax = ctx.act_absmax.get(cur)
        if absmax is None:
            continue
        # the exact scale the unfused consumers would quantize with
        from repro.core.quantize import act_scale
        scale = act_scale(absmax)
        node.attrs["requant_scale"] = scale
        for t in chain:
            graph.nodes[t].attrs["int8"] = True
        for e in endpoints:
            graph.nodes[e].attrs["int8_input"] = True
        report.requant_groups.append(
            RequantGroup(name, tuple(chain), endpoints, scale))
    return graph


def annotate_kv_int8(graph: Graph, ctx: PassContext,
                     report: PassReport) -> Graph:
    """INT8 KV-stream annotation (LM serving — DESIGN.md §15): on a
    quantized (accel) plan, every attention node's K/V values go through
    the `lm_quant.quantize_kv`/`dequantize_kv` per-(position, head)
    round-trip — the same codes the KV-cache arena stores at decode
    time, applied already in the prefill graph so prefill attention
    output is bit-identical to what cached decode reconstructs. A
    builder may pin ``kv_int8=False`` on a node to opt it out. (The
    ``fuse=False`` escape hatch skips this pass like any other, so an
    unfused accel LM plan streams fp32 K/V — the LM engine requires the
    pass pipeline.)"""
    if ctx.quant is None:
        return graph
    for name in graph.order:
        node = graph.nodes[name]
        if base_op(node) == "attention" and "kv_int8" not in node.attrs:
            node.attrs["kv_int8"] = True
            report.kv_int8_nodes.append(name)
    return graph


# ---------------------------------------------------------------------------
# Manager
# ---------------------------------------------------------------------------

PassFn = Callable[[Graph, PassContext, PassReport], Graph]

DEFAULT_PASSES: Tuple[Tuple[str, PassFn], ...] = (
    ("constant_fold", constant_fold),
    ("dead_node_elimination", eliminate_dead_nodes),
    ("epilogue_fusion", fuse_epilogues),
    ("requant_fusion", fuse_requant),
    ("kv_int8_annotation", annotate_kv_int8),
)


class PassManager:
    """Runs an ordered pass list over a CLONE of the graph (the engine's
    source graph is never mutated) and returns the rewritten graph plus
    the report the plan summary prints."""

    def __init__(self,
                 passes: Optional[Sequence[Tuple[str, PassFn]]] = None):
        self.passes = tuple(passes if passes is not None else DEFAULT_PASSES)

    def run(self, graph: Graph, ctx: PassContext
            ) -> Tuple[Graph, PassReport]:
        g = graph.clone()
        report = PassReport()
        for _, fn in self.passes:
            g = fn(g, ctx, report)
        _check_consistency(g)
        return g, report


def _check_consistency(graph: Graph) -> None:
    """Pass-pipeline invariants: order is a permutation of nodes, every
    input reference resolves, outputs resolve, topological order holds."""
    assert sorted(graph.order) == sorted(graph.nodes), "order != nodes"
    seen = set()
    for name in graph.order:
        for i in graph.nodes[name].inputs:
            assert i in seen, f"{name} reads {i} before its definition"
        seen.add(name)
    for o in graph.outputs:
        assert o in graph.nodes, f"output {o} does not resolve"
