"""Staged execution plans: Planned -> Lowered -> Compiled (DESIGN.md §7).

The paper's deployment flow is ahead-of-time by construction: the
inspector partitions the model, the quantizer folds scales, the compiler
emits a bitstream, and serving only ever *runs*. The seed engine instead
re-derived all of that per call. This module is the JaCe-style staged
chain that moves every decision to plan time:

* :class:`ExecutionPlan` (**Planned**) — built once per (engine, backend):
  the inspector's backend assignment, the contiguous accel/flex
  *segments*, PTQ weight/activation scales and fused ReLU epilogues all
  folded into per-node constants, plus the PTQ fidelity gate (nodes whose
  calibration-time quantization error is too large are demoted to the
  flex path — the mixed-precision analog of the paper's partial offload).
* :class:`LoweredPlan` (**Lowered**) — the plan traced for one concrete
  batch size: a single jitted callable over ``[B, ...]`` inputs; every op
  implementation is natively batched (no per-sample ``x[None]``).
* :class:`CompiledPlan` (**Compiled**) — the XLA executable. Calling it
  never re-traces; the engine caches one per (backend, batch-size), so
  steady-state serving runs at whatever rate the hardware allows.

Random ops thread a per-sample key array ``rngs [B, 2]`` through the plan
(split per random node, vmapped over the batch), so row *i* of a batched
run is bit-identical to a single-sample run with key ``rngs[i]``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import energy as energy_mod
from repro.core.opgraph import Graph, Node
from repro.kernels import ops as kops

RANDOM_OPS = frozenset({"sample_normal"})


# ---------------------------------------------------------------------------
# Batched fp32 op implementations (leading batch dim everywhere)
# ---------------------------------------------------------------------------


def _conv2d_b(x, p, a):
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(a.get("stride", 1),) * 2,
        padding=a.get("padding", "SAME"),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + p["b"]


def _conv3d_b(x, p, a):
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(a.get("stride", 1),) * 3,
        padding=a.get("padding", "SAME"),
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"))
    return out + p["b"]


def _pool_b(x, a, ndim, op):
    k, s = a["kernel"], a.get("stride", a["kernel"])
    window = (1,) + (k,) * ndim + (1,)
    strides = (1,) + (s,) * ndim + (1,)
    if op == "max":
        return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, window,
                                     strides, "VALID")
    out = jax.lax.reduce_window(x, 0.0, jax.lax.add, window, strides, "VALID")
    return out / (k ** ndim)


def _dense_b(x, p, a):
    out = x.reshape(x.shape[0], -1) @ p["w"]
    if "b" in p:
        out = out + p["b"]
    return out


def _concat_axis(a) -> int:
    ax = a.get("axis", -1)
    return ax + 1 if ax >= 0 else ax


def _sample_normal_b(xs, rngs):
    mu, logvar = xs
    eps = jax.vmap(lambda k, m: jax.random.normal(k, m.shape))(rngs, mu)
    return mu + jnp.exp(0.5 * logvar) * eps


BATCHED_OP_IMPLS: Dict[str, Callable] = {
    "conv2d": lambda x, p, a, rng: _conv2d_b(x[0], p, a),
    "conv3d": lambda x, p, a, rng: _conv3d_b(x[0], p, a),
    "maxpool2d": lambda x, p, a, rng: _pool_b(x[0], a, 2, "max"),
    "avgpool2d": lambda x, p, a, rng: _pool_b(x[0], a, 2, "avg"),
    "maxpool3d": lambda x, p, a, rng: _pool_b(x[0], a, 3, "max"),
    "avgpool3d": lambda x, p, a, rng: _pool_b(x[0], a, 3, "avg"),
    "dense": lambda x, p, a, rng: _dense_b(x[0], p, a),
    "flatten": lambda x, p, a, rng: x[0].reshape(x[0].shape[0], -1),
    "relu": lambda x, p, a, rng: jnp.maximum(x[0], 0.0),
    "leaky_relu": lambda x, p, a, rng: jnp.where(
        x[0] > 0, x[0], a.get("alpha", 0.01) * x[0]),
    "sigmoid": lambda x, p, a, rng: jax.nn.sigmoid(x[0]),
    "tanh": lambda x, p, a, rng: jnp.tanh(x[0]),
    "softplus": lambda x, p, a, rng: jax.nn.softplus(x[0]),
    "exp": lambda x, p, a, rng: jnp.exp(x[0]),
    "concat": lambda x, p, a, rng: jnp.concatenate(x, axis=_concat_axis(a)),
    "add": lambda x, p, a, rng: x[0] + x[1],
    "sub": lambda x, p, a, rng: x[0] - x[1],
    "mul": lambda x, p, a, rng: x[0] * x[1],
    "greater": lambda x, p, a, rng: (x[0] > a["threshold"]).astype(
        jnp.float32),
    "sample_normal": lambda x, p, a, rng: _sample_normal_b(x, rng),
    "argmax": lambda x, p, a, rng: jnp.argmax(
        x[0].reshape(x[0].shape[0], -1), axis=1).astype(jnp.int32),
}


# ---------------------------------------------------------------------------
# Plan-time folding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous run of nodes on one backend (the paper's partial
    offload unit — e.g. the VAE's sampling tail on the flex path)."""
    backend: str                    # 'accel' | 'flex'
    nodes: Tuple[str, ...]


@dataclasses.dataclass
class QuantNodePlan:
    """PTQ constants folded into a quantized node at plan time."""
    op: str                         # 'conv2d' | 'dense'
    w_q: jax.Array                  # dense: [K, N]; conv: [KH, KW, Cin, Cout]
    w_scale: jax.Array              # [N] per-output-channel
    bias: Optional[jax.Array]
    act_scale: float                # static per-tensor input scale
    fused_relu: bool                # ReLU epilogue folded in
    stride: int = 1
    padding: str = "SAME"


def partition_segments(graph: Graph, assignment: Dict[str, str]
                       ) -> List[Segment]:
    """Group ``graph.order`` into contiguous same-backend runs."""
    segs: List[Segment] = []
    run: List[str] = []
    cur: Optional[str] = None
    for name in graph.order:
        if graph.nodes[name].op == "input":
            continue
        b = assignment[name]
        if b != cur and run:
            segs.append(Segment(cur, tuple(run)))
            run = []
        cur = b
        run.append(name)
    if run:
        segs.append(Segment(cur, tuple(run)))
    return segs


def _consumers(graph: Graph) -> Dict[str, List[str]]:
    out: Dict[str, List[str]] = {n: [] for n in graph.nodes}
    for name in graph.order:
        for i in graph.nodes[name].inputs:
            out[i].append(name)
    return out


class ExecutionPlan:
    """**Planned** stage: everything derivable without a batch size.

    Holds the folded graph program; :meth:`lower` binds a batch size and
    traces, :meth:`compile` (on the lowered stage) produces the reusable
    executable. ``n_traces`` counts lowerings — steady-state serving must
    not grow it.
    """

    def __init__(self, graph: Graph, params: Dict[str, Dict[str, jax.Array]],
                 backend: str,
                 quant: Optional[Dict[str, Any]] = None,
                 act_absmax: Optional[Dict[str, float]] = None,
                 ptq_err: Optional[Dict[str, float]] = None,
                 ptq_demote_threshold: float = 0.2):
        from repro.core import inspector as inspector_mod
        self.graph = graph
        self.params = params
        self.backend = backend
        self.n_traces = 0

        assignment = inspector_mod.assign_backends(graph)
        self.demoted: List[str] = []
        self.qplans: Dict[str, QuantNodePlan] = {}
        self.fused_into: Dict[str, str] = {}    # relu node -> producer

        if backend == "accel":
            if quant is None:
                raise RuntimeError(
                    "accel backend needs calibrate() first (PTQ)")
            consumers = _consumers(graph)
            for name in graph.order:
                node = graph.nodes[name]
                if (assignment[name] != "accel"
                        or node.op not in ("conv2d", "dense")
                        or name not in quant):
                    continue
                # PTQ fidelity gate: calibration-time quantization error too
                # large -> run this node fp32 on the flex path instead
                # (the engine-level analog of the paper's QAT remark).
                err = (ptq_err or {}).get(name, 0.0)
                if err > ptq_demote_threshold:
                    assignment[name] = "flex"
                    self.demoted.append(name)
                    continue
                q = quant[name]
                inp = node.inputs[0]
                absmax = (act_absmax or {}).get(inp)
                if absmax is None:
                    raise RuntimeError(
                        f"no calibration absmax for {inp!r} (accel plan)")
                act_scale = float(absmax) / 127.0 + 1e-12
                # fuse a sole-consumer ReLU into the kernel epilogue
                fused = False
                cons = consumers[name]
                if (len(cons) == 1 and graph.nodes[cons[0]].op == "relu"
                        and name not in graph.outputs
                        and assignment.get(cons[0]) == "accel"):
                    fused = True
                    self.fused_into[cons[0]] = name
                if node.op == "conv2d":
                    w4 = q.w_q.reshape(params[name]["w"].shape)
                    self.qplans[name] = QuantNodePlan(
                        "conv2d", w4, q.w_scale, q.bias, act_scale, fused,
                        stride=node.attrs.get("stride", 1),
                        padding=node.attrs.get("padding", "SAME"))
                else:
                    self.qplans[name] = QuantNodePlan(
                        "dense", q.w_q, q.w_scale, q.bias, act_scale, fused)
        else:
            assignment = {n: "flex" for n in assignment}

        self.assignment = assignment
        self.segments = partition_segments(graph, assignment)
        self._lowered: Dict[int, "LoweredPlan"] = {}

    # -- the batched program -------------------------------------------------

    def batched_fn(self) -> Callable:
        """The plan as a python callable ``f(inputs[B,...], rngs[B,2])``."""
        graph, params = self.graph, self.params
        qplans, fused_into = self.qplans, self.fused_into

        def f(inputs: Dict[str, jax.Array], rngs: jax.Array
              ) -> Dict[str, jax.Array]:
            vals: Dict[str, jax.Array] = {}
            for name in graph.graph_inputs:
                vals[name] = inputs[name].astype(jnp.float32)
            for seg in self.segments:
                for name in seg.nodes:
                    node = graph.nodes[name]
                    if name in fused_into:      # ReLU folded into producer
                        vals[name] = vals[fused_into[name]]
                        continue
                    xs = [vals[i] for i in node.inputs]
                    if name in qplans:
                        vals[name] = _run_quantized(qplans[name], xs[0])
                        continue
                    sub = None
                    if node.op in RANDOM_OPS:
                        nxt = jax.vmap(jax.random.split)(rngs)  # [B, 2, 2]
                        rngs, sub = nxt[:, 0], nxt[:, 1]
                    vals[name] = BATCHED_OP_IMPLS[node.op](
                        xs, params.get(name, {}), node.attrs, sub)
            return {o: vals[o] for o in graph.outputs}

        return f

    # -- staging -------------------------------------------------------------

    def lower(self, batch_size: int) -> "LoweredPlan":
        if batch_size in self._lowered:
            return self._lowered[batch_size]
        in_sds = {
            name: jax.ShapeDtypeStruct((batch_size,) + tuple(shape),
                                       jnp.float32)
            for name, shape in self.graph.graph_inputs.items()}
        rng_sds = jax.ShapeDtypeStruct((batch_size, 2), jnp.uint32)
        lowered = jax.jit(self.batched_fn()).lower(in_sds, rng_sds)
        self.n_traces += 1
        lp = LoweredPlan(self, batch_size, lowered)
        self._lowered[batch_size] = lp
        return lp

    def cost_signature(self, batch_size: int,
                       backend: Optional[str] = None
                       ) -> energy_mod.CostSignature:
        """Plan-time modeled cost of one ``batch_size`` dispatch on this
        plan's backend (``backend`` overrides for the cpu/EagerPlan view,
        which executes the flex plan on the eager baseline hardware)."""
        return energy_mod.cost_signature(
            self.graph, backend or self.backend, batch_size)

    def summary(self) -> str:
        lines = [f"ExecutionPlan[{self.graph.name}/{self.backend}]: "
                 f"{len(self.segments)} segment(s), "
                 f"{len(self.qplans)} quantized node(s), "
                 f"{len(self.fused_into)} fused epilogue(s)"]
        for seg in self.segments:
            lines.append(f"  [{seg.backend:5s}] {seg.nodes[0]} .. "
                         f"{seg.nodes[-1]} ({len(seg.nodes)} nodes)")
        if self.demoted:
            lines.append(f"  PTQ-demoted to flex: {self.demoted}")
        return "\n".join(lines)


def _run_quantized(qp: QuantNodePlan, x: jax.Array) -> jax.Array:
    """One fused kernel per quantized layer: static-scale requantize ->
    int8 MXU matmul/conv -> dequant (+bias, +ReLU) epilogue.

    Static scales are the DPU contract (and what makes the plan a fixed
    program): activations beyond the calibration-set absmax SATURATE at
    +-127, exactly as on the real accelerator — serve-time inputs must be
    covered by a representative calibration set (DESIGN.md §7)."""
    s = qp.act_scale
    if qp.op == "dense":
        b = x.shape[0]
        x_q = jnp.clip(jnp.round(x.reshape(b, -1) / s), -127, 127
                       ).astype(jnp.int8)
        return kops.int8_matmul(
            x_q, qp.w_q, jnp.full((b,), s, jnp.float32), qp.w_scale,
            qp.bias, relu=qp.fused_relu)
    x_q = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
    return kops.conv2d_int8(
        x_q, qp.w_q, qp.w_scale, qp.bias, x_scale=s,
        stride=qp.stride, padding=qp.padding, relu=qp.fused_relu)


class LoweredPlan:
    """**Lowered** stage: traced for one batch size, not yet an executable."""

    def __init__(self, plan: ExecutionPlan, batch_size: int, lowered):
        self.plan = plan
        self.batch_size = batch_size
        self.lowered = lowered
        self._compiled: Optional[CompiledPlan] = None

    def as_text(self) -> str:
        return self.lowered.as_text()

    def compile(self) -> "CompiledPlan":
        if self._compiled is None:
            self._compiled = CompiledPlan(self.plan, self.batch_size,
                                          self.lowered.compile())
        return self._compiled


class CompiledPlan:
    """**Compiled** stage: an XLA executable — calling it never re-traces.
    Carries its plan-time :class:`~repro.core.energy.CostSignature`: the
    modeled FLOPs / bytes / J-per-inference / W of one dispatch at this
    batch size, so a dispatcher can rank and power-budget candidates
    without ever measuring (DESIGN.md §9)."""

    def __init__(self, plan: ExecutionPlan, batch_size: int, executable):
        self.plan = plan
        self.batch_size = batch_size
        self._executable = executable
        self.cost = plan.cost_signature(batch_size)

    @property
    def n_traces(self) -> int:
        return self.plan.n_traces

    def __call__(self, inputs: Dict[str, jax.Array], rngs: jax.Array
                 ) -> Dict[str, jax.Array]:
        return self._executable(inputs, rngs)


class EagerPlan:
    """The cpu-backend stage: the same batched program, run op-by-op with
    jit disabled (the paper's ARM-CPU '1x' baseline analog)."""

    def __init__(self, plan: ExecutionPlan, batch_size: int):
        self.plan = plan
        self.batch_size = batch_size
        self._fn = plan.batched_fn()
        self.cost = plan.cost_signature(batch_size, backend="cpu")

    @property
    def n_traces(self) -> int:
        return self.plan.n_traces

    def __call__(self, inputs: Dict[str, jax.Array], rngs: jax.Array
                 ) -> Dict[str, jax.Array]:
        with jax.disable_jit():
            return self._fn(inputs, rngs)
