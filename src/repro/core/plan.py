"""Staged execution plans: Planned -> Lowered -> Compiled (DESIGN.md §7,
§10).

The paper's deployment flow is ahead-of-time by construction: the
inspector partitions the model, the quantizer folds scales, the compiler
emits a bitstream, and serving only ever *runs*. The seed engine instead
re-derived all of that per call. This module is the JaCe-style staged
chain that moves every decision to plan time:

* :class:`ExecutionPlan` (**Planned**) — built once per (engine, backend):
  the inspector's backend assignment, the PTQ fidelity gate (nodes whose
  calibration-time quantization error is too large are demoted to the
  flex path), then the graph-compiler **pass pipeline**
  (`core/passes.py`: constant folding, dead-node elimination, epilogue
  fusion, int8 producer->consumer requant fusion), the contiguous
  accel/flex *segments* over the REWRITTEN graph, PTQ weight/activation
  scales folded into per-node constants, and the static BRAM/DDR
  activation arena (`core/memory.py`) that prices the plan's
  :class:`~repro.core.energy.CostSignature`. ``fuse=False`` skips the
  pass pipeline entirely and reproduces the pre-pass plans node-for-node
  (the escape hatch the conformance suite pins).
* :class:`LoweredPlan` (**Lowered**) — the plan traced for one concrete
  batch size: a single jitted callable over ``[B, ...]`` inputs; every op
  implementation is natively batched (no per-sample ``x[None]``).
* :class:`CompiledPlan` (**Compiled**) — the XLA executable. Calling it
  never re-traces; the engine caches one per (backend, batch-size), so
  steady-state serving runs at whatever rate the hardware allows.

Random ops thread a per-sample key array ``rngs [B, 2]`` through the plan
(split per random node, vmapped over the batch), so row *i* of a batched
run is bit-identical to a single-sample run with key ``rngs[i]``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune as autotune_mod
from repro.core import energy as energy_mod
from repro.core import memory as memory_mod
from repro.core.opgraph import (RANDOM_OPS, Graph, Node, base_op,
                                consumers, param_node)
from repro.core.passes import PassContext, PassManager, PassReport
from repro.kernels import ops as kops
from repro.kernels.conv2d import conv_geometry, pad_input


# ---------------------------------------------------------------------------
# Batched fp32 op implementations (leading batch dim everywhere)
# ---------------------------------------------------------------------------


def _conv2d_b(x, p, a):
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(a.get("stride", 1),) * 2,
        padding=a.get("padding", "SAME"),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=a.get("groups", 1))
    return out + p["b"]


def _conv3d_b(x, p, a):
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), p["w"].astype(jnp.float32),
        window_strides=(a.get("stride", 1),) * 3,
        padding=a.get("padding", "SAME"),
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC"))
    return out + p["b"]


def _pool_b(x, a, ndim, op):
    k, s = a["kernel"], a.get("stride", a["kernel"])
    window = (1,) + (k,) * ndim + (1,)
    strides = (1,) + (s,) * ndim + (1,)
    if op == "max":
        # dtype-aware identity: the int8-domain chain pools int8 exactly
        # (max commutes with the monotone quantizer — DESIGN.md §10)
        init = (jnp.iinfo(x.dtype).min
                if jnp.issubdtype(x.dtype, jnp.integer) else -jnp.inf)
        return jax.lax.reduce_window(x, jnp.asarray(init, x.dtype),
                                     jax.lax.max, window, strides, "VALID")
    out = jax.lax.reduce_window(x, 0.0, jax.lax.add, window, strides, "VALID")
    return out / (k ** ndim)


def _dense_b(x, p, a):
    if a.get("per_position", False):
        # token-wise projection: contract the LAST axis only; leading
        # (batch, position) axes broadcast through jnp.matmul
        out = x @ p["w"]
    else:
        out = x.reshape(x.shape[0], -1) @ p["w"]
    if "b" in p:
        out = out + p["b"]
    return out


def _reshape_b(x, a):
    tgt = list(a["shape"])
    if -1 in tgt:
        rest = int(np.prod([d for d in tgt if d != -1]))
        tgt[tgt.index(-1)] = int(np.prod(x.shape[1:])) // rest
    return x.reshape((x.shape[0],) + tuple(tgt))


def _attention_b(xs, a, config=None):
    """Batched flash attention over [B, S, H, hd] q/k/v. ``kv_int8``
    round-trips K/V through the per-(pos, head) int8 quantizer — the
    same codes the KV-cache arena stores, so prefill output is
    bit-identical to what cached decode reconstructs."""
    q, k, v = (t.astype(jnp.float32) for t in xs)
    if a.get("kv_int8", False):
        from repro.core import lm_quant
        k = lm_quant.dequantize_kv(*lm_quant.quantize_kv(k), jnp.float32)
        v = lm_quant.dequantize_kv(*lm_quant.quantize_kv(v), jnp.float32)
    bq = config.bq if config is not None and config.bq else a.get("bq", 256)
    bk = config.bk if config is not None and config.bk else a.get("bk", 256)
    return kops.flash_attention(q, k, v, causal=a.get("causal", True),
                                bq=bq, bk=bk)


def _ssd_b(xs, p, a, config=None):
    x, B_, C_, dt = (t.astype(jnp.float32) for t in xs)
    chunk = (config.chunk if config is not None and config.chunk
             else a.get("chunk", 256))
    y, _ = kops.ssd(x, B_, C_, dt, p["A"], chunk=chunk)
    return y


def _concat_axis(a) -> int:
    ax = a.get("axis", -1)
    return ax + 1 if ax >= 0 else ax


def _sample_normal_b(xs, rngs):
    mu, logvar = xs
    eps = jax.vmap(lambda k, m: jax.random.normal(k, m.shape))(rngs, mu)
    return mu + jnp.exp(0.5 * logvar) * eps


BATCHED_OP_IMPLS: Dict[str, Callable] = {
    "conv2d": lambda x, p, a, rng: _conv2d_b(x[0], p, a),
    "conv3d": lambda x, p, a, rng: _conv3d_b(x[0], p, a),
    "maxpool2d": lambda x, p, a, rng: _pool_b(x[0], a, 2, "max"),
    "avgpool2d": lambda x, p, a, rng: _pool_b(x[0], a, 2, "avg"),
    "maxpool3d": lambda x, p, a, rng: _pool_b(x[0], a, 3, "max"),
    "avgpool3d": lambda x, p, a, rng: _pool_b(x[0], a, 3, "avg"),
    "dense": lambda x, p, a, rng: _dense_b(x[0], p, a),
    "attention": lambda x, p, a, rng: _attention_b(x, a),
    "ssd": lambda x, p, a, rng: _ssd_b(x, p, a),
    "reshape": lambda x, p, a, rng: _reshape_b(x[0], a),
    "flatten": lambda x, p, a, rng: x[0].reshape(x[0].shape[0], -1),
    "relu": lambda x, p, a, rng: jnp.maximum(x[0], 0.0),
    "leaky_relu": lambda x, p, a, rng: jnp.where(
        x[0] > 0, x[0], a.get("alpha", 0.01) * x[0]),
    "sigmoid": lambda x, p, a, rng: jax.nn.sigmoid(x[0]),
    "tanh": lambda x, p, a, rng: jnp.tanh(x[0]),
    "softplus": lambda x, p, a, rng: jax.nn.softplus(x[0]),
    "exp": lambda x, p, a, rng: jnp.exp(x[0]),
    "concat": lambda x, p, a, rng: jnp.concatenate(x, axis=_concat_axis(a)),
    "add": lambda x, p, a, rng: x[0] + x[1],
    "sub": lambda x, p, a, rng: x[0] - x[1],
    "mul": lambda x, p, a, rng: x[0] * x[1],
    "greater": lambda x, p, a, rng: (x[0] > a["threshold"]).astype(
        jnp.float32),
    "sample_normal": lambda x, p, a, rng: _sample_normal_b(x, rng),
    "argmax": lambda x, p, a, rng: jnp.argmax(
        x[0].reshape(x[0].shape[0], -1), axis=1).astype(jnp.int32),
}


def _run_fused_f32(node: Node, xs, params) -> jax.Array:
    """An fp32 ``fused`` node: the base op, then its element-wise
    epilogue(s) — identical math to the unfused node pair, one plan node
    (what XLA fuses anyway; here it also fuses the *plan*, so the arena
    never allocates the intermediate)."""
    y = BATCHED_OP_IMPLS[node.attrs["base_op"]](
        xs, params.get(param_node(node), {}), node.attrs, None)
    for e in node.attrs.get("epilogue", ()):
        y = BATCHED_OP_IMPLS[e]([y], {}, {}, None)
    return y


# ---------------------------------------------------------------------------
# Plan-time folding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous run of nodes on one backend (the paper's partial
    offload unit — e.g. the VAE's sampling tail on the flex path)."""
    backend: str                    # 'accel' | 'flex'
    nodes: Tuple[str, ...]


@dataclasses.dataclass
class QuantNodePlan:
    """PTQ constants folded into a quantized node at plan time."""
    op: str                         # 'conv2d' | 'dense' (base compute op)
    w_q: jax.Array                  # dense: [K, N]; conv: [KH, KW, Cin, Cout]
    w_scale: jax.Array              # [N] per-output-channel
    bias: Optional[jax.Array]
    act_scale: float                # static per-tensor input scale
    act: Optional[str] = None       # fused activation epilogue
    requant_scale: Optional[float] = None   # int8 output at this scale
    int8_input: bool = False        # producer already delivered int8
    stride: int = 1
    padding: str = "SAME"
    per_position: bool = False      # dense over the last axis only (LM)


def partition_segments(graph: Graph, assignment: Dict[str, str]
                       ) -> List[Segment]:
    """Group ``graph.order`` into contiguous same-backend runs. Inputs
    and plan-time constants are structural — they move no data at run
    time, so they must never split a contiguous backend run (the arena
    charges real DDR round-trips at segment boundaries)."""
    segs: List[Segment] = []
    run: List[str] = []
    cur: Optional[str] = None
    for name in graph.order:
        if graph.nodes[name].op in ("input", "const"):
            continue
        b = assignment[name]
        if b != cur and run:
            segs.append(Segment(cur, tuple(run)))
            run = []
        cur = b
        run.append(name)
    if run:
        segs.append(Segment(cur, tuple(run)))
    return segs


class ExecutionPlan:
    """**Planned** stage: everything derivable without a batch size.

    Holds the folded graph program; :meth:`lower` binds a batch size and
    traces, :meth:`compile` (on the lowered stage) produces the reusable
    executable. ``n_traces`` counts lowerings — steady-state serving must
    not grow it.

    With ``fuse=True`` (the default) the op graph is rewritten by the
    pass pipeline before partitioning, and a static activation arena
    prices the plan's cost signature. ``fuse=False`` reproduces the
    pre-pass per-node plans exactly.
    """

    def __init__(self, graph: Graph, params: Dict[str, Dict[str, jax.Array]],
                 backend: str,
                 quant: Optional[Dict[str, Any]] = None,
                 act_absmax: Optional[Dict[str, float]] = None,
                 ptq_err: Optional[Dict[str, float]] = None,
                 ptq_demote_threshold: float = 0.2,
                 fuse: bool = True,
                 pass_manager: Optional[PassManager] = None,
                 tuner: Optional[autotune_mod.Autotuner] = None,
                 pack_batch: int = 32):
        from repro.core import inspector as inspector_mod
        self.source_graph = graph
        self.params = params
        self.backend = backend
        self.fuse = fuse
        self.n_traces = 0
        # plan-time autotuning (DESIGN.md §11): tuner=None is the escape
        # hatch that reproduces the heuristic kernels bit-for-bit.
        # Weight-layout dims are tuned ONCE at `pack_batch` (weights are
        # packed once for the mission); per-rung tuning covers only the
        # activation-schedule knobs against that fixed layout.
        self.tuner = tuner
        self.pack_batch = pack_batch
        self._tuning: Dict[int, Dict[str, autotune_mod.TuningDecision]] = {}
        self._layouts: Optional[Dict[str, autotune_mod.KernelConfig]] = None
        self.packed: Dict[str, Any] = {}
        self._packed_bytes: Dict[str, int] = {}
        # live int8 weight buffers (fed to executables as ARGUMENTS, not
        # baked-in trace constants) + pristine host copies for re-pack
        # recovery (DESIGN.md §13)
        self._weight_arena: Optional[Dict[str, jax.Array]] = None
        self._host_weights: Dict[str, np.ndarray] = {}

        assignment = inspector_mod.assign_backends(graph)
        self.demoted: List[str] = []
        self.qplans: Dict[str, QuantNodePlan] = {}
        self.fused_into: Dict[str, str] = {}    # legacy: relu node -> producer
        self.pass_report: Optional[PassReport] = None
        self.arena: Optional[memory_mod.ArenaPlan] = None
        # static KV-cache arena (LM decode) — attached post-construction
        # by the LM engine via attach_kv_plan()
        self.kv_plan: Optional[memory_mod.KVCachePlan] = None

        if backend == "accel":
            if quant is None:
                raise RuntimeError(
                    "accel backend needs calibrate() first (PTQ)")
            # PTQ fidelity gate first, on the source graph: calibration-
            # time quantization error too large -> run fp32 on the flex
            # path (the engine-level analog of the paper's QAT remark).
            for name in graph.order:
                node = graph.nodes[name]
                if (assignment[name] != "accel"
                        or node.op not in ("conv2d", "dense")
                        or name not in quant):
                    continue
                err = (ptq_err or {}).get(name, 0.0)
                if err > ptq_demote_threshold:
                    assignment[name] = "flex"
                    self.demoted.append(name)
        else:
            assignment = {n: "flex" for n in assignment}

        if fuse:
            ctx = PassContext(
                params=params, assignment=assignment,
                quant=quant if backend == "accel" else None,
                act_absmax=act_absmax if backend == "accel" else None)
            self.graph, self.pass_report = (
                pass_manager or PassManager()).run(graph, ctx)
            assignment = ctx.assignment
            if backend == "accel":
                self._fold_quant_fused(quant, act_absmax, assignment)
        else:
            self.graph = graph
            if backend == "accel":
                self._fold_quant_legacy(quant, act_absmax, assignment)

        self.assignment = assignment
        self.segments = partition_segments(self.graph, assignment)
        if fuse:
            self.arena = self._plan_arena()
        self._lowered: Dict[int, "LoweredPlan"] = {}

    # -- PTQ folding ---------------------------------------------------------

    def _act_scale(self, act_absmax: Optional[Dict[str, float]],
                   inp: str) -> float:
        from repro.core.quantize import act_scale
        absmax = (act_absmax or {}).get(inp)
        if absmax is None:
            raise RuntimeError(
                f"no calibration absmax for {inp!r} (accel plan)")
        return act_scale(absmax)

    def _fold_quant_fused(self, quant, act_absmax, assignment) -> None:
        """Quantized-node constants over the pass-rewritten graph: the
        fusion decisions arrive as node attrs (epilogue / requant_scale /
        int8_input) and fold straight into the QuantNodePlan."""
        for name in self.graph.order:
            node = self.graph.nodes[name]
            bop = base_op(node)
            if (assignment.get(name) != "accel"
                    or bop not in ("conv2d", "dense")):
                continue
            pkey = param_node(node)
            if pkey not in quant:
                continue
            q = quant[pkey]
            s = self._act_scale(act_absmax, node.inputs[0])
            epi = node.attrs.get("epilogue", ())
            common = dict(
                w_scale=q.w_scale, bias=q.bias, act_scale=s,
                act=epi[0] if epi else None,
                requant_scale=node.attrs.get("requant_scale"),
                int8_input=bool(node.attrs.get("int8_input")),
                per_position=bool(node.attrs.get("per_position")))
            if bop == "conv2d":
                w4 = q.w_q.reshape(self.params[pkey]["w"].shape)
                self.qplans[name] = QuantNodePlan(
                    "conv2d", w4, stride=node.attrs.get("stride", 1),
                    padding=node.attrs.get("padding", "SAME"), **common)
            else:
                self.qplans[name] = QuantNodePlan("dense", q.w_q, **common)

    def _fold_quant_legacy(self, quant, act_absmax, assignment) -> None:
        """The pre-pass (fuse=False) folding: per-node quantization with
        sole-consumer ReLU epilogues recorded as node aliases
        (``fused_into``) — node-for-node what the seed planner built."""
        cons = consumers(self.graph)
        for name in self.graph.order:
            node = self.graph.nodes[name]
            if (assignment[name] != "accel"
                    or node.op not in ("conv2d", "dense")
                    or name not in quant):
                continue
            q = quant[name]
            s = self._act_scale(act_absmax, node.inputs[0])
            fused = False
            cs = cons[name]
            if (len(cs) == 1 and self.graph.nodes[cs[0]].op == "relu"
                    and name not in self.graph.outputs
                    and assignment.get(cs[0]) == "accel"):
                fused = True
                self.fused_into[cs[0]] = name
            act = "relu" if fused else None
            if node.op == "conv2d":
                w4 = q.w_q.reshape(self.params[name]["w"].shape)
                self.qplans[name] = QuantNodePlan(
                    "conv2d", w4, q.w_scale, q.bias, s, act=act,
                    stride=node.attrs.get("stride", 1),
                    padding=node.attrs.get("padding", "SAME"))
            else:
                self.qplans[name] = QuantNodePlan(
                    "dense", q.w_q, q.w_scale, q.bias, s, act=act,
                    per_position=bool(node.attrs.get("per_position")))

    # -- arena ---------------------------------------------------------------

    def _quantized_names(self) -> set:
        return set(self.qplans)

    def _plan_arena(self) -> memory_mod.ArenaPlan:
        hw = energy_mod.BACKEND_HW[self.backend]
        w_bytes = energy_mod.weight_bytes(self.graph, self.backend,
                                          self._quantized_names(),
                                          self._packed_bytes or None)
        # BRAM-resident KV slots shrink the activation budget exactly
        # like resident weights do
        kv_bram = self.kv_plan.bram_bytes if self.kv_plan is not None else 0
        resident = w_bytes + kv_bram
        budget = max(int(hw.onchip_bytes) - resident, 0) \
            if resident <= hw.onchip_bytes else int(hw.onchip_bytes)
        act_dtype = {}
        for name, node in self.graph.nodes.items():
            if (node.attrs.get("int8")
                    or node.attrs.get("requant_scale") is not None):
                act_dtype[name] = 1     # int8-domain value
        return memory_mod.plan_arena(self.graph, self.segments, budget,
                                     act_dtype, backend=self.backend,
                                     weight_bytes=w_bytes)

    # -- autotuning (DESIGN.md §11) ------------------------------------------

    def _ensure_autotuned(self, batch_size: int) -> None:
        """Tune (and, on the accel path, prepack) once per batch rung.
        The packing step runs first, at ``pack_batch``: it fixes the
        weight-layout dims, builds the tile-aligned device buffers, and
        re-budgets the activation arena against the PACKED footprint —
        then every rung's schedule search is constrained to that layout."""
        if self.tuner is None or batch_size in self._tuning:
            return
        if self.backend == "accel" and self._layouts is None:
            pack = self.tuner.tune_plan(self, self.pack_batch)
            self._layouts = {
                n: d.config for n, d in pack.items()
                if d.kind in autotune_mod.INT8_KINDS}
            self.packed = autotune_mod.build_packed_weights(
                self, self._layouts)
            self._packed_bytes = {n: p.packed_bytes
                                  for n, p in self.packed.items()}
            self._weight_arena = None       # rebuild over packed buffers
            if self.arena is not None:
                self.arena = self._plan_arena()
            self._tuning[self.pack_batch] = pack
            if batch_size == self.pack_batch:
                return
        layouts = self._layouts if self.backend == "accel" else None
        self._tuning[batch_size] = self.tuner.tune_plan(
            self, batch_size, layouts=layouts)

    # -- the live weight arena (DESIGN.md §13) -------------------------------

    @property
    def weight_arena(self) -> Dict[str, jax.Array]:
        """Live int8 weight buffers, one per quantized node (the packed
        tile-aligned buffer when a prepacked entry exists, the raw
        ``w_q`` otherwise). Executables receive this dict as a RUNTIME
        argument on every call, so a bit flip injected here (or a
        re-pack recovery) takes effect without re-tracing — exactly the
        on-device weight memory an SEU would hit. Scales and biases stay
        trace-time constants: they are small fp32 host-derived tables,
        outside the modeled SEU cross-section."""
        if self._weight_arena is None:
            arena: Dict[str, jax.Array] = {}
            for name, qp in self.qplans.items():
                pk = self.packed.get(name)
                arena[name] = pk.w_q if pk is not None else qp.w_q
            self._weight_arena = arena
            self._host_weights = {n: np.array(a) for n, a in arena.items()}
        return self._weight_arena

    @property
    def host_weights(self) -> Dict[str, np.ndarray]:
        """Pristine host-side copies of the arena (captured at arena
        build, before any fault could touch device state) — the re-pack
        recovery source."""
        self.weight_arena
        return self._host_weights

    def repack_weights(self, names: Optional[List[str]] = None) -> int:
        """Restore arena entries from the pristine host copies (the
        recovery ladder's 're-pack' rung). Returns the bytes rewritten,
        which the fault controller prices as recovery work."""
        arena = self.weight_arena
        total = 0
        for name in (names if names is not None else list(arena)):
            arena[name] = jnp.asarray(self._host_weights[name])
            total += self._host_weights[name].nbytes
        return total

    # -- the batched program -------------------------------------------------

    def batched_fn(self, tuning: Optional[Dict[str, Any]] = None
                   ) -> Callable:
        """The plan as a python callable
        ``f(inputs[B,...], rngs[B,2], weights)``. ``tuning`` (node ->
        TuningDecision, one batch rung) binds the autotuned tile configs;
        ``weights`` is the live :attr:`weight_arena` dict — quantized
        nodes consume their int8 buffer from it at run time (prepacked
        entries arrive tile-aligned; no per-call weight padding)."""
        graph, params = self.graph, self.params
        qplans, fused_into = self.qplans, self.fused_into
        packed = self.packed

        def f(inputs: Dict[str, jax.Array], rngs: jax.Array,
              weights: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
            vals: Dict[str, jax.Array] = {}
            batch = rngs.shape[0]
            for name in graph.graph_inputs:
                vals[name] = inputs[name].astype(jnp.float32)
            # plan-time constants are structural (outside the segments,
            # like inputs): materialize them up front, keeping the dtype
            # the folded op produced (a folded bool/int result must not
            # silently become float32 — fuse=False would return its own)
            for name in graph.order:
                node = graph.nodes[name]
                if node.op == "const":
                    v = jnp.asarray(node.attrs["value"])
                    vals[name] = jnp.broadcast_to(v, (batch,) + v.shape)
            for seg in self.segments:
                for name in seg.nodes:
                    node = graph.nodes[name]
                    if name in fused_into:      # ReLU folded into producer
                        vals[name] = vals[fused_into[name]]
                        continue
                    xs = [vals[i] for i in node.inputs]
                    if name in qplans:
                        dec = tuning.get(name) if tuning else None
                        vals[name] = _run_quantized(
                            qplans[name], xs[0],
                            config=dec.config if dec else None,
                            packed=packed.get(name),
                            w_q=weights[name])
                        continue
                    if node.op == "fused":      # fp32 fused (flex path)
                        vals[name] = _run_fused_f32(node, xs, params)
                        continue
                    if node.op in ("attention", "ssd"):
                        # LM kernels take their tuned block shapes from
                        # the rung's decision set (numerics-neutral)
                        dec = tuning.get(name) if tuning else None
                        cfg = dec.config if dec else None
                        vals[name] = (
                            _attention_b(xs, node.attrs, cfg)
                            if node.op == "attention" else
                            _ssd_b(xs, params.get(name, {}),
                                   node.attrs, cfg))
                        continue
                    sub = None
                    if node.op in RANDOM_OPS:
                        nxt = jax.vmap(jax.random.split)(rngs)  # [B, 2, 2]
                        rngs_, sub = nxt[:, 0], nxt[:, 1]
                        rngs = rngs_
                    vals[name] = BATCHED_OP_IMPLS[node.op](
                        xs, params.get(name, {}), node.attrs, sub)
            return {o: vals[o] for o in graph.outputs}

        return f

    # -- staging -------------------------------------------------------------

    def lower(self, batch_size: int) -> "LoweredPlan":
        if batch_size in self._lowered:
            return self._lowered[batch_size]
        self._ensure_autotuned(batch_size)
        in_sds = {
            name: jax.ShapeDtypeStruct((batch_size,) + tuple(shape),
                                       jnp.float32)
            for name, shape in self.graph.graph_inputs.items()}
        rng_sds = jax.ShapeDtypeStruct((batch_size, 2), jnp.uint32)
        w_sds = {name: jax.ShapeDtypeStruct(a.shape, a.dtype)
                 for name, a in self.weight_arena.items()}
        lowered = jax.jit(
            self.batched_fn(self._tuning.get(batch_size))).lower(
                in_sds, rng_sds, w_sds)
        self.n_traces += 1
        lp = LoweredPlan(self, batch_size, lowered)
        self._lowered[batch_size] = lp
        return lp

    def cost_signature(self, batch_size: int,
                       backend: Optional[str] = None
                       ) -> energy_mod.CostSignature:
        """Plan-time modeled cost of one ``batch_size`` dispatch on this
        plan's backend (``backend`` overrides for the cpu/EagerPlan view,
        which executes the flex plan on the eager baseline hardware).

        Fused plans price DDR traffic from the static arena; the eager
        cpu view and unfused plans keep the op-by-op bytes model — every
        activation round-trips DDR, exactly what per-node dispatch does.
        """
        # always pass the exact quantized set — an accel plan whose nodes
        # were ALL PTQ-demoted runs fp32 and must be priced at fp32
        # widths, not the assume-int8 graph-only approximation
        if backend is None and self.tuner is not None:
            self._ensure_autotuned(batch_size)
            return self._charge_kv(self.tuned_cost_signature(
                batch_size, self._tuning[batch_size],
                packed_bytes=self._packed_bytes or None))
        if self.arena is not None and backend is None:
            return self._charge_kv(energy_mod.plan_cost_signature(
                self.graph, self.backend, batch_size, self.arena,
                quantized=self._quantized_names()))
        return self._charge_kv(energy_mod.cost_signature(
            self.graph, backend or self.backend, batch_size,
            quantized=self._quantized_names()))

    def attach_kv_plan(self, kv_plan: memory_mod.KVCachePlan) -> None:
        """Charge a static KV-cache arena to this plan: BRAM-resident
        slots shrink the activation-arena budget exactly like resident
        weights, and every cost signature reports the packed KV
        footprint (``kv_resident_bytes``)."""
        self.kv_plan = kv_plan
        if self.arena is not None:
            self.arena = self._plan_arena()

    def _charge_kv(self, sig: energy_mod.CostSignature
                   ) -> energy_mod.CostSignature:
        if self.kv_plan is None:
            return sig
        return dataclasses.replace(
            sig, kv_resident_bytes=float(self.kv_plan.total_bytes))

    def stage_costs(self, batch_size: int,
                    backend: Optional[str] = None
                    ) -> Tuple[energy_mod.StageCost, ...]:
        """The plan's pipeline-stage decomposition at ``batch_size``
        (DESIGN.md §12): host stage_in -> one stage per segment on its
        backend resource -> host readback. Priced from the same node
        times (tuned when the autotuner ran) and the same bytes model as
        the serial signature. The ``backend`` override (the EagerPlan
        cpu view) has no staging channel or segment pipeline — it is one
        monolithic eager stage."""
        if backend is not None and backend != self.backend:
            sig = self.cost_signature(batch_size, backend=backend)
            return (energy_mod.StageCost("eager", backend, sig.latency_s),)
        node_times = None
        if self.tuner is not None:
            self._ensure_autotuned(batch_size)
            node_times = {n: d.modeled_s
                          for n, d in self._tuning[batch_size].items()}
        return energy_mod.stage_costs(
            self.graph, self.backend, batch_size, self.segments,
            arena=self.arena, quantized=self._quantized_names(),
            node_times=node_times,
            packed_bytes=self._packed_bytes or None)

    def pipelined_cost_signature(self, batch_size: int,
                                 backend: Optional[str] = None
                                 ) -> energy_mod.CostSignature:
        """`cost_signature` with the pipelined-latency term filled in:
        the longest stage of `stage_costs` — the steady-state per-batch
        interval when staging, segment compute, and readback overlap
        across batches. Every other field (latency_s, energy_j, ...) is
        byte-for-byte the serial signature."""
        sig = self.cost_signature(batch_size, backend=backend)
        stages = self.stage_costs(batch_size, backend=backend)
        return dataclasses.replace(
            sig, pipelined_latency_s=max(s.seconds for s in stages))

    def default_cost_signature(self, batch_size: int
                               ) -> energy_mod.CostSignature:
        """The heuristic-default configs priced through the SAME
        kernel-level pricer (and the same packed footprint) as the tuned
        signature — THE baseline every default-vs-tuned comparison uses
        (benchmarks/autotune.py, benchmarks/throughput.py): comparing
        tuned numbers against the coarse roofline would mix two models."""
        return self.tuned_cost_signature(
            batch_size, autotune_mod.price_defaults(self, batch_size),
            packed_bytes=self._packed_bytes or None)

    def tuned_cost_signature(self, batch_size: int,
                             decisions: Dict[str, Any],
                             packed_bytes: Optional[Dict[str, int]] = None
                             ) -> energy_mod.CostSignature:
        """The plan's cost signature with the kernel-level pricing of a
        decision set substituted for the coarse per-node roofline term —
        the one pricer both the tuned plan AND the benchmark's
        heuristic-default baseline (`autotune.price_defaults`) go
        through, so default-vs-tuned comparisons never mix models."""
        node_times = {n: d.modeled_s for n, d in decisions.items()}
        extra = sum(d.extra_bytes for d in decisions.values())
        if self.arena is not None:
            return energy_mod.plan_cost_signature(
                self.graph, self.backend, batch_size, self.arena,
                quantized=self._quantized_names(), node_times=node_times,
                extra_bytes=extra, packed_bytes=packed_bytes)
        return energy_mod.cost_signature(
            self.graph, self.backend, batch_size,
            quantized=self._quantized_names(), node_times=node_times,
            extra_bytes=extra, packed_bytes=packed_bytes)

    # -- reporting -----------------------------------------------------------

    def summary(self) -> str:
        n_fused = sum(1 for n in self.graph.nodes.values()
                      if n.op == "fused")
        lines = [f"ExecutionPlan[{self.graph.name}/{self.backend}]: "
                 f"{len(self.segments)} segment(s), "
                 f"{len(self.qplans)} quantized node(s), "
                 f"{n_fused + len(self.fused_into)} fused epilogue(s), "
                 f"fuse={'on' if self.fuse else 'off'}"]
        for seg in self.segments:
            lines.append(f"  [{seg.backend:5s}] {seg.nodes[0]} .. "
                         f"{seg.nodes[-1]} ({len(seg.nodes)} nodes)")
        if self.pass_report is not None and self.pass_report.n_rewrites:
            lines.append("  passes:")
            lines.append(self.pass_report.summary())
        if self.demoted:
            lines.append(f"  PTQ-demoted to flex: {self.demoted}")
        if self.arena is not None:
            a = self.arena
            lines.append(
                f"  arena: peak {a.bram_peak:,}/{a.bram_budget:,} B BRAM, "
                f"{a.n_spilled} spill(s), "
                f"{a.ddr_bytes_per_sample:,} DDR B/sample")
        if self.kv_plan is not None:
            lines.append("  " + self.kv_plan.summary())
        return "\n".join(lines)

    def as_text(self) -> str:
        """Full textual plan dump: the rewritten graph, per-node backend
        and quantization state, fusion groups, and the arena table."""
        lines = [self.summary(), "", self.graph.summary()]
        if self.qplans:
            lines.append("")
            for name, qp in self.qplans.items():
                bits = [f"s_in={qp.act_scale:.3g}"]
                if qp.act:
                    bits.append(f"act={qp.act}")
                if qp.requant_scale is not None:
                    bits.append(f"requant={qp.requant_scale:.3g}")
                if qp.int8_input:
                    bits.append("int8-in")
                lines.append(f"  int8 {name:24s} {qp.op:7s} "
                             + " ".join(bits))
        if self._tuning:
            lines.append("")
            for bsz in sorted(self._tuning):
                lines.append(f"  autotune @ batch {bsz}:")
                for name, d in self._tuning[bsz].items():
                    cfg = d.config
                    if d.kind == "int8_dense":
                        desc = f"tile {cfg.bm}x{cfg.bn}x{cfg.bk}"
                    elif d.kind == "int8_conv":
                        desc = f"rows/blk {cfg.rows_per_block}"
                        if cfg.cout_per_block:
                            desc += f" cout/blk {cfg.cout_per_block}"
                    elif d.kind == "attention":
                        desc = f"blocks bq={cfg.bq} bk={cfg.bk}"
                    elif d.kind == "ssd":
                        desc = f"chunk {cfg.chunk}"
                    else:
                        desc = f"unroll x{cfg.unroll}"
                    pk = self.packed.get(name)
                    pb = (f"  packed={pk.packed_bytes:,} B"
                          if pk is not None else "")
                    lines.append(
                        f"    {name:24s} {desc:20s} "
                        f"t={d.modeled_s*1e6:9.2f} us "
                        f"(default {d.default_s*1e6:9.2f} us, "
                        f"x{d.speedup:.2f}) [{d.source}]{pb}")
        if self.arena is not None:
            lines.append("")
            lines.append(self.arena.summary())
        return "\n".join(lines)


def _run_quantized(qp: QuantNodePlan, x: jax.Array,
                   config: Optional[Any] = None,
                   packed: Optional[Any] = None,
                   w_q: Optional[jax.Array] = None) -> jax.Array:
    """One fused kernel per quantized layer: static-scale requantize ->
    int8 MXU matmul/conv -> dequant (+bias, +act, +requantize) epilogue.

    Static scales are the DPU contract (and what makes the plan a fixed
    program): activations beyond the calibration-set absmax SATURATE at
    +-127, exactly as on the real accelerator — serve-time inputs must be
    covered by a representative calibration set (DESIGN.md §7). When the
    producer already requantized (``int8_input``), the incoming int8
    values are consumed directly — the fp32 intermediate never existed.

    With ``packed`` (a prepacked weight-arena entry, DESIGN.md §11) the
    kernels consume tile-aligned device buffers directly: weight padding
    happened once at plan time, input staging (quantize + the conv's
    SAME pad, geometry computed once at lowering) is all that remains
    per call. ``config`` binds the rung's autotuned tile schedule; both
    paths are bit-exact to the heuristic default.

    ``w_q`` is the node's live weight-arena buffer (a runtime argument
    of the traced program — DESIGN.md §13); when omitted, the plan-time
    constant (``packed.w_q`` / ``qp.w_q``) is baked in as before.
    """
    s = qp.act_scale
    wq = w_q if w_q is not None else (
        packed.w_q if packed is not None else qp.w_q)
    if qp.op == "dense":
        # per_position folds every leading (batch, position) axis into
        # the matmul M dim — one int8 GEMM for the whole token batch —
        # and restores the leading axes afterwards
        lead = x.shape[:-1] if qp.per_position else (x.shape[0],)
        x2 = (x.reshape(-1, x.shape[-1]) if qp.per_position
              else x.reshape(x.shape[0], -1))
        x_q = x2 if qp.int8_input else jnp.clip(
            jnp.round(x2 / s), -127, 127).astype(jnp.int8)
        scales = jnp.full((x2.shape[0],), s, jnp.float32)
        if packed is not None:
            out = kops.int8_matmul(
                x_q, wq, scales, packed.w_scale, packed.bias,
                act=qp.act, requant_scale=qp.requant_scale,
                bm=(config.bm if config and config.bm else 128),
                bn=packed.bn, bk=packed.bk, prepacked=True,
                n_out=packed.n)
        else:
            out = kops.int8_matmul(
                x_q, wq, scales, qp.w_scale,
                qp.bias, act=qp.act, requant_scale=qp.requant_scale)
        if qp.per_position:
            out = out.reshape(tuple(lead) + (out.shape[-1],))
        return out
    x_q = x if qp.int8_input else jnp.clip(
        jnp.round(x / s), -127, 127).astype(jnp.int8)
    if packed is not None:
        h, w = int(x_q.shape[1]), int(x_q.shape[2])
        kh, kw = int(wq.shape[0]), int(wq.shape[1])
        rows = (config.rows_per_block
                if config and config.rows_per_block else 8)
        geom = conv_geometry(h, w, kh, kw, qp.stride, qp.padding, rows)
        x_q = pad_input(x_q, geom)       # plan-time geometry, one pad op
        return kops.conv2d_int8(
            x_q, wq, packed.w_scale, packed.bias, x_scale=s,
            stride=qp.stride, padding=qp.padding, act=qp.act,
            requant_scale=qp.requant_scale, rows_per_block=rows,
            cout_per_block=packed.cout_per_block, cout=packed.cout,
            pre_padded=True, in_hw=(h, w))
    return kops.conv2d_int8(
        x_q, wq, qp.w_scale, qp.bias, x_scale=s,
        stride=qp.stride, padding=qp.padding, act=qp.act,
        requant_scale=qp.requant_scale)


class LoweredPlan:
    """**Lowered** stage: traced for one batch size, not yet an executable."""

    def __init__(self, plan: ExecutionPlan, batch_size: int, lowered):
        self.plan = plan
        self.batch_size = batch_size
        self.lowered = lowered
        self._compiled: Optional[CompiledPlan] = None

    def as_text(self) -> str:
        return self.lowered.as_text()

    def compile(self) -> "CompiledPlan":
        if self._compiled is None:
            self._compiled = CompiledPlan(self.plan, self.batch_size,
                                          self.lowered.compile())
        return self._compiled


class CompiledPlan:
    """**Compiled** stage: an XLA executable — calling it never re-traces.
    Carries its plan-time :class:`~repro.core.energy.CostSignature`: the
    modeled FLOPs / bytes / J-per-inference / W of one dispatch at this
    batch size, so a dispatcher can rank and power-budget candidates
    without ever measuring (DESIGN.md §9). Fused plans price their DDR
    bytes from the static arena (§10), so fusion shifts the dispatcher's
    energy ranking."""

    def __init__(self, plan: ExecutionPlan, batch_size: int, executable):
        self.plan = plan
        self.batch_size = batch_size
        self._executable = executable
        self.cost = plan.pipelined_cost_signature(batch_size)
        self.stages = plan.stage_costs(batch_size)

    @property
    def n_traces(self) -> int:
        return self.plan.n_traces

    def __call__(self, inputs: Dict[str, jax.Array], rngs: jax.Array
                 ) -> Dict[str, jax.Array]:
        # the weight arena is read LIVE on every call: SEU injection and
        # re-pack recovery swap entries without touching the executable
        return self._executable(inputs, rngs, self.plan.weight_arena)


class EagerPlan:
    """The cpu-backend stage: the same batched program, run op-by-op with
    jit disabled (the paper's ARM-CPU '1x' baseline analog)."""

    def __init__(self, plan: ExecutionPlan, batch_size: int):
        self.plan = plan
        self.batch_size = batch_size
        self._fn = plan.batched_fn()
        self.cost = plan.pipelined_cost_signature(batch_size, backend="cpu")
        self.stages = plan.stage_costs(batch_size, backend="cpu")

    @property
    def n_traces(self) -> int:
        return self.plan.n_traces

    def __call__(self, inputs: Dict[str, jax.Array], rngs: jax.Array
                 ) -> Dict[str, jax.Array]:
        with jax.disable_jit():
            return self._fn(inputs, rngs, self.plan.weight_arena)
