"""Operator-coverage inspector — the Vitis-AI 'inspector' analog.

The paper's workflow: *"run the inspector to verify that all layers are
supported"* before committing a model to the DPU; unsupported models
(ESPERTA's sigmoid/greater, MMS's 3-D conv/pool) go to HLS instead. Here
the same decision is per-*node*: nodes whose op is in ACCEL_SUPPORTED run
the INT8 Pallas path, everything else runs the flexible fp32 path — with
segment analysis so partial offload (the paper's VAE sampling/exp tail on
CPU) falls out naturally.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.core.opgraph import Graph, Node, base_op

# The DPU-analog op table. Deliberately restrictive, mirroring DPUCZDX8G:
# CNN ops + ReLU only — no sigmoid/tanh/softplus, no comparators, no 3-D
# layers, no sampling, no exp. (INT8 MXU kernels exist for conv2d/dense.)
# `reshape` is structural data movement the DPU's DMA handles. The LM
# kernels (`attention`, `ssd`) are NOT in the table: like the paper's
# sigmoid tail they run on the flexible path, so a decoder block
# partitions into accel QKV/MLP projections around flex attention/SSM
# segments — operator coverage is exactly the survey's binding
# constraint for DPU-style accelerators.
ACCEL_SUPPORTED = {
    "conv2d", "dense", "relu", "maxpool2d", "avgpool2d", "flatten",
    "concat", "add", "reshape",
}

# Ops the accel path *executes quantized* (the rest of ACCEL_SUPPORTED are
# structural / fused into epilogues).
ACCEL_QUANTIZED = {"conv2d", "dense"}

# kinds that move no data at run time: never compute, never counted in
# operator-coverage reports, never split a backend segment
STRUCTURAL_KINDS = ("input", "const")


def accel_supports(node: Node) -> bool:
    """Per-NODE accel support — the op table plus attr-level restrictions
    the int8 kernels carry: grouped (e.g. depthwise) conv2d has no
    shift-and-matmul kernel, so it runs on the flex path even though
    plain conv2d is supported."""
    bop = base_op(node)
    if bop not in ACCEL_SUPPORTED:
        return False
    if bop == "conv2d" and node.attrs.get("groups", 1) != 1:
        return False
    return True


@dataclasses.dataclass
class InspectionReport:
    graph_name: str
    supported: List[str]
    unsupported: List[str]
    fully_supported: bool
    mac_coverage: float             # fraction of MACs accel can take
    segments: List[dict]            # contiguous backend runs, in order

    def summary(self) -> str:
        status = "ACCEL (fully supported)" if self.fully_supported else \
            f"PARTIAL ({self.mac_coverage:.1%} of MACs on accel)"
        lines = [f"{self.graph_name}: {status}"]
        if self.unsupported:
            lines.append(f"  unsupported ops: "
                         f"{sorted(set(self.unsupported))}")
        for seg in self.segments:
            lines.append(f"  [{seg['backend']:5s}] {seg['first']} .. "
                         f"{seg['last']} ({seg['n']} nodes)")
        return "\n".join(lines)


def assign_backends(graph: Graph) -> Dict[str, str]:
    out = {}
    for name in graph.order:
        node = graph.nodes[name]
        if node.op in STRUCTURAL_KINDS:         # structural, no compute
            out[name] = "accel"
            continue
        # a fused node goes where its base compute op goes (its epilogue
        # runs inside the kernel — DESIGN.md §10)
        out[name] = "accel" if accel_supports(node) else "flex"
    return out


def inspect(graph: Graph) -> InspectionReport:
    assignment = assign_backends(graph)
    supported, unsupported = [], []
    for name in graph.order:
        node = graph.nodes[name]
        if node.op in STRUCTURAL_KINDS:
            # const nodes (constant folding; tracer-captured literals)
            # are structural like inputs — counting them into supported/
            # fully_supported would report plan-time values as compute
            # ops the accelerator "runs"
            continue
        (supported if assignment[name] == "accel" else unsupported
         ).append(node.op)
    macs = graph.n_macs or 1
    accel_macs = sum(n.macs for n in graph.nodes.values()
                     if assignment[n.name] == "accel")

    from repro.core.plan import partition_segments
    segments = [{"backend": seg.backend, "first": seg.nodes[0],
                 "last": seg.nodes[-1], "n": len(seg.nodes)}
                for seg in partition_segments(graph, assignment)]
    return InspectionReport(
        graph_name=graph.name,
        supported=supported,
        unsupported=unsupported,
        fully_supported=not unsupported,
        mac_coverage=accel_macs / macs,
        segments=segments,
    )
