"""LM serving engine — autoregressive decode over the compiled op graph.

``LMEngine`` wraps an :class:`~repro.core.engine.Engine` holding a
decoder-block graph (``models/lm.py``) and makes decode a first-class,
statically-planned workload (DESIGN.md §15):

* **Prefill** runs THE compiled plan — the same Planned -> Lowered ->
  Compiled chain the CNNs use, one executable per batch rung. The graph
  exposes its KV/state capture points as outputs (``k_heads`` /
  ``v_heads`` / ``ssm_heads`` / ``b_proj`` / ``dt``); a per-rung jitted
  *commit* program quantizes K/V (``lm_quant.quantize_kv`` — int8 codes
  + f16 per-token-head scale planes) and scatters them into the
  request's KV slot, and folds the SSD scan's final state into the
  slot's state buffer.

* **Decode** is a per-rung jitted single-token program over the SAME
  rewritten plan (same ``QuantNodePlan`` constants, same fused nodes,
  same live weight arena), with the ``attention`` node replaced by a
  masked attend over the dequantized int8 cache and the ``ssd`` node by
  the one-step SSD recurrence on the cached state. Decode attention is
  deliberately plain ``jnp`` (not the Pallas flash kernel): a decode
  step is a memory-bound GEMV over a dynamic prefix length — there is
  no tiling to win, and the flash kernel's ``kv_len`` is static.

* **KV slots** come from the static planner
  (:func:`~repro.core.memory.plan_kv_cache`): fixed-capacity,
  tile-aligned int8 K/V arenas charged to the plan's BRAM/DDR budget and
  its :class:`~repro.core.energy.CostSignature` like prepacked weights.
  Slot assign/release is the only per-request state transition — after
  each rung's programs exist, steady-state decode performs **zero
  re-traces and zero arena allocations** (``n_traces`` /
  ``KVSlotAllocator.n_assigns`` are the observability surface).

The K/V cache is int8 ALWAYS — on quantized plans the pass pipeline's
``kv_int8`` annotation makes the prefill attention node round-trip its
K/V through the same quantizer, so prefill math matches what decode
reads back; unquantized (flex) plans stream fp32 K/V in prefill and pay
a one-time int8 rounding at the cache boundary (the documented
``fuse=False`` caveat in core/passes.py).

Prompts are full fixed-length windows (``graph_inputs['x'][0]``
positions): the SSD prefill state is the scan's final state, which is
only the request's state when the prompt fills the window.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as energy_mod
from repro.core import lm_quant
from repro.core import memory as memory_mod
from repro.core.engine import Engine
from repro.core.opgraph import RANDOM_OPS, base_op
from repro.core.plan import (BATCHED_OP_IMPLS, _run_fused_f32,
                             _run_quantized)

NEG_INF = -2.0e38                      # matches kernels/flash_attention.py


@dataclasses.dataclass(frozen=True)
class StepResult:
    """One prefill/decode dispatch's outputs, already on host."""
    tokens: np.ndarray                  # [B] int32 argmax tokens
    hidden: np.ndarray                  # [B, D] next-step input features


class LMEngine:
    """Scheduler-facing serving facade over one decoder-block engine."""

    def __init__(self, engine: Engine, backend: str = "accel",
                 n_slots: int = 4, max_new_tokens: int = 32,
                 logits_node: str = "head", hidden_node: str = "resid2"):
        if not engine.fuse:
            raise ValueError(
                "LMEngine requires fuse=True (the kv_int8 annotation and "
                "epilogue/requant fusion live in the pass pipeline)")
        self.engine = engine
        self.backend = backend
        self.logits_node = logits_node
        self.hidden_node = hidden_node
        self.plan = engine.planned(backend)
        graph = self.plan.graph
        bad = [n for n in graph.order
               if graph.nodes[n].op in RANDOM_OPS]
        if bad:
            raise ValueError(f"LM decode cannot replay RANDOM_OPS: {bad}")
        for out in (logits_node, hidden_node):
            if out not in graph.outputs:
                raise ValueError(f"{out!r} must be a graph output")
        self.seq_len = int(graph.graph_inputs["x"][0])
        self.d_model = int(graph.graph_inputs["x"][1])
        self.max_new_tokens = int(max_new_tokens)
        self.n_slots = int(n_slots)

        # capture-point bookkeeping: every attention k/v input and every
        # ssd x/B/dt input must be a graph output (prefill visibility)
        self._attn_nodes = [n for n in graph.order
                            if base_op(graph.nodes[n]) == "attention"]
        self._ssd_nodes = [n for n in graph.order
                           if base_op(graph.nodes[n]) == "ssd"]
        missing = []
        for n in self._attn_nodes:
            missing += [i for i in graph.nodes[n].inputs[1:3]
                        if i not in graph.outputs]
        for n in self._ssd_nodes:
            node = graph.nodes[n]
            missing += [i for i in (node.inputs[0], node.inputs[1],
                                    node.inputs[3])
                        if i not in graph.outputs]
        if missing:
            raise ValueError(
                f"KV/state capture inputs must be graph outputs: {missing}")

        # the static KV arena: charged to the plan's budget + signature
        hw = energy_mod.BACKEND_HW[backend]
        self.kv_plan = memory_mod.plan_kv_cache(
            graph, n_slots, self.seq_len + self.max_new_tokens,
            bram_available=hw.onchip_bytes)
        self.plan.attach_kv_plan(self.kv_plan)
        self.capacity = self.kv_plan.capacity
        self.slots = memory_mod.KVSlotAllocator(n_slots)

        # slot arenas: n_slots real rows + one scratch row (index
        # n_slots) that padding lanes in a partially-filled rung target
        self.caches: Dict[str, Any] = self._init_caches()
        # per-rung jitted programs; building one is a counted trace
        self._commit: Dict[int, Callable] = {}
        self._decode: Dict[int, Callable] = {}
        self.lm_traces = 0

    # -- cache arenas --------------------------------------------------------

    def _init_caches(self) -> Dict[str, Any]:
        rows = self.n_slots + 1
        cap = self.capacity
        caches: Dict[str, Any] = {
            "pos": jnp.zeros((rows,), jnp.int32)}
        graph = self.plan.graph
        for n in self._attn_nodes:
            _, hkv, hd = graph.nodes[graph.nodes[n].inputs[1]].out_shape
            caches[n] = {
                "k_codes": jnp.zeros((rows, cap, hkv, hd), jnp.int8),
                "k_scale": jnp.ones((rows, cap, hkv), jnp.float16),
                "v_codes": jnp.zeros((rows, cap, hkv, hd), jnp.int8),
                "v_scale": jnp.ones((rows, cap, hkv), jnp.float16)}
        for n in self._ssd_nodes:
            node = graph.nodes[n]
            _, h, p = graph.nodes[node.inputs[0]].out_shape
            nstate = graph.nodes[node.inputs[1]].out_shape[-1]
            caches[n] = {
                "state": jnp.zeros((rows, h, p, nstate), jnp.float32)}
        return caches

    @property
    def scratch_slot(self) -> int:
        """The slot id padding lanes write to (never read back)."""
        return self.n_slots

    @property
    def n_traces(self) -> int:
        """Total trace count: plan lowerings + LM commit/decode builds.
        Steady-state serving must not grow it."""
        return self.plan.n_traces + self.lm_traces

    # -- slot lifecycle (driven by the scheduler) ----------------------------

    def assign_slot(self, request_id) -> Optional[int]:
        return self.slots.assign(request_id)

    def release_slot(self, request_id) -> int:
        return self.slots.release(request_id)

    # -- prefill -------------------------------------------------------------

    def prefill(self, x: np.ndarray, slot_ids: np.ndarray) -> StepResult:
        """Run one prefill rung: ``x`` [B, S, D] prompt windows,
        ``slot_ids`` [B] KV slots (``scratch_slot`` for padding lanes).
        Commits quantized K/V + SSD state into the slots and returns each
        lane's first generated token + feedback features."""
        b = int(x.shape[0])
        outs = self.engine.run_batch(
            {"x": jnp.asarray(x, jnp.float32)}, self.backend)
        if b not in self._commit:
            self._commit[b] = jax.jit(self._commit_fn)
            self.lm_traces += 1
        self.caches = self._commit[b](
            outs, jnp.asarray(slot_ids, jnp.int32), self.caches)
        logits = np.asarray(outs[self.logits_node])
        hidden = np.asarray(outs[self.hidden_node])
        return StepResult(
            tokens=np.argmax(logits[:, -1], axis=-1).astype(np.int32),
            hidden=hidden[:, -1])

    def _commit_fn(self, outs, slot_ids, caches):
        graph, params = self.plan.graph, self.plan.params
        s, cap = self.seq_len, self.capacity
        new = dict(caches)
        for n in self._attn_nodes:
            node = graph.nodes[n]
            d = dict(caches[n])
            for which, src in (("k", node.inputs[1]), ("v", node.inputs[2])):
                codes, scale = lm_quant.quantize_kv(outs[src])
                d[f"{which}_codes"] = caches[n][f"{which}_codes"].at[
                    slot_ids].set(jnp.pad(
                        codes, ((0, 0), (0, cap - s), (0, 0), (0, 0))))
                d[f"{which}_scale"] = caches[n][f"{which}_scale"].at[
                    slot_ids].set(jnp.pad(
                        scale.astype(jnp.float16),
                        ((0, 0), (0, cap - s), (0, 0)),
                        constant_values=1.0))
            new[n] = d
        for n in self._ssd_nodes:
            node = graph.nodes[n]
            xh = outs[node.inputs[0]]               # [B, S, H, P]
            bp = outs[node.inputs[1]]               # [B, S, N]
            dt = outs[node.inputs[3]]               # [B, S, H]
            a = params[n]["A"]

            def step(state, inp):
                xt, bt, dtt = inp
                decay = jnp.exp(dtt * a)
                state = (state * decay[..., None, None]
                         + jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt))
                return state, None

            init = jnp.zeros(
                (xh.shape[0],) + caches[n]["state"].shape[1:], jnp.float32)
            state, _ = jax.lax.scan(
                step, init, (xh.swapaxes(0, 1), bp.swapaxes(0, 1),
                             dt.swapaxes(0, 1)))
            new[n] = {"state": caches[n]["state"].at[slot_ids].set(state)}
        new["pos"] = caches["pos"].at[slot_ids].set(s)
        return new

    # -- decode --------------------------------------------------------------

    def decode_step(self, hidden: np.ndarray, slot_ids: np.ndarray
                    ) -> StepResult:
        """One decode rung: ``hidden`` [R, D] feedback features,
        ``slot_ids`` [R] slots (``scratch_slot`` for padding lanes).
        Appends each lane's new K/V at its position counter and returns
        the next token + feedback features. Zero re-traces once the rung
        is warm; zero slot allocations ever."""
        r = int(hidden.shape[0])
        if r not in self._decode:
            self._decode[r] = jax.jit(self._make_decode())
            self.lm_traces += 1
        tok, hid, self.caches = self._decode[r](
            jnp.asarray(hidden, jnp.float32),
            jnp.asarray(slot_ids, jnp.int32),
            self.caches, self.plan.weight_arena)
        return StepResult(tokens=np.asarray(tok), hidden=np.asarray(hid))

    def _make_decode(self) -> Callable:
        plan = self.plan
        graph, params = plan.graph, plan.params
        qplans, packed = plan.qplans, plan.packed
        fused_into = plan.fused_into
        cap = self.capacity

        def step(x, slot_ids, caches, weights):
            vals: Dict[str, jax.Array] = {"x": x.astype(jnp.float32)}
            pos = caches["pos"][slot_ids]           # [R] tokens cached
            pos_w = jnp.minimum(pos, cap - 1)       # clamped write index
            new = dict(caches)
            for name in graph.order:
                node = graph.nodes[name]
                if node.op == "input":
                    continue
                if node.op == "const":
                    v = jnp.asarray(node.attrs["value"])
                    vals[name] = jnp.broadcast_to(
                        v, (x.shape[0],) + v.shape)
                    continue
                if name in fused_into:
                    vals[name] = vals[fused_into[name]]
                    continue
                xs = [vals[i] for i in node.inputs]
                if name in qplans:
                    vals[name] = _run_quantized(
                        qplans[name], xs[0], packed=packed.get(name),
                        w_q=weights[name])
                    continue
                if node.op == "fused" and base_op(node) != "attention":
                    vals[name] = _run_fused_f32(node, xs, params)
                    continue
                if node.op == "reshape":
                    # per-sample [S, ...] targets lose the position axis
                    # at decode: one token, same trailing dims
                    vals[name] = xs[0].reshape(
                        (xs[0].shape[0],) + tuple(node.out_shape[1:]))
                    continue
                if base_op(node) == "attention":
                    vals[name], upd = _decode_attend(
                        xs, slot_ids, pos, pos_w, caches[name])
                    new[name] = upd
                    continue
                if base_op(node) == "ssd":
                    state = caches[name]["state"][slot_ids]
                    y, state = _decode_ssd(xs, params[name]["A"], state)
                    new[name] = {"state": caches[name]["state"].at[
                        slot_ids].set(state)}
                    vals[name] = y
                    continue
                vals[name] = BATCHED_OP_IMPLS[node.op](
                    xs, params.get(name, {}), node.attrs, None)
            new["pos"] = caches["pos"].at[slot_ids].add(1)
            tok = jnp.argmax(vals[self.logits_node], axis=-1)
            return tok.astype(jnp.int32), vals[self.hidden_node], new

        return step


def _decode_attend(xs, slot_ids, pos, pos_w, cache
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token attend over the int8 slot cache: append the new
    K/V at ``pos_w``, then masked-softmax over positions ``<= pos``."""
    q, k_new, v_new = (t.astype(jnp.float32) for t in xs)
    kc, ks = lm_quant.quantize_kv(k_new)            # [R,Hkv,hd] / [R,Hkv]
    vc, vs = lm_quant.quantize_kv(v_new)
    upd = {
        "k_codes": cache["k_codes"].at[slot_ids, pos_w].set(kc),
        "k_scale": cache["k_scale"].at[slot_ids, pos_w].set(
            ks.astype(jnp.float16)),
        "v_codes": cache["v_codes"].at[slot_ids, pos_w].set(vc),
        "v_scale": cache["v_scale"].at[slot_ids, pos_w].set(
            vs.astype(jnp.float16))}
    k_all = lm_quant.dequantize_kv(
        upd["k_codes"][slot_ids], upd["k_scale"][slot_ids], jnp.float32)
    v_all = lm_quant.dequantize_kv(
        upd["v_codes"][slot_ids], upd["v_scale"][slot_ids], jnp.float32)
    cap, hq, hd = k_all.shape[1], q.shape[1], q.shape[2]
    group = hq // k_all.shape[2]                    # GQA repeat factor
    k_r = jnp.repeat(k_all, group, axis=2)          # [R,cap,Hq,hd]
    v_r = jnp.repeat(v_all, group, axis=2)
    scores = jnp.einsum("rhd,rchd->rhc", q, k_r) * (hd ** -0.5)
    live = jnp.arange(cap)[None, :] <= pos[:, None]
    scores = jnp.where(live[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("rhc,rchd->rhd", probs, v_r), upd


def _decode_ssd(xs, a, state) -> Tuple[jax.Array, jax.Array]:
    """One SSD recurrence step on the cached state (kernels/ref.py
    decode math): ``xs`` = (x [R,H,P], B [R,N], C [R,N], dt [R,H])."""
    xh, b_, c_, dt = (t.astype(jnp.float32) for t in xs)
    decay = jnp.exp(dt * a)                         # [R,H]
    state = (state * decay[..., None, None]
             + jnp.einsum("rh,rn,rhp->rhpn", dt, b_, xh))
    return jnp.einsum("rn,rhpn->rhp", c_, state), state
