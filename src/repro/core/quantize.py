"""INT8 post-training quantization (+ QAT fake-quant) — the Vitis-AI
quantizer analog.

PTQ: per-output-channel symmetric weight scales (absmax/127), per-tensor
activation scales collected by running the calibration set through the
fp32 graph and recording absmax at every node output (the standard
Vitis-AI PTQ recipe). QAT: straight-through-estimator fake-quant usable
inside a jax.grad training loop — the paper notes PTQ caused "noticeable
degradation that QAT could mitigate"; both are provided and the
degradation is measured in benchmarks/table3_performance.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import Graph
from repro.kernels import ops as kops


@dataclasses.dataclass
class QuantizedLayer:
    w_q: jax.Array                  # int8 [K, N] (dense) / [KH*KW*Cin, Cout]
    w_scale: jax.Array              # f32 [N] per-output-channel
    bias: Optional[jax.Array]       # f32 [N]


def act_scale(absmax: float) -> float:
    """THE static per-tensor activation scale: calibration absmax / 127
    (+eps against zero tensors). One definition on purpose — the requant
    fusion's bit-exactness guarantee (DESIGN.md §10) requires the fused
    producer's requantize scale and the unfused consumer's quantize scale
    to be the same float."""
    return float(absmax) / 127.0 + 1e-12


def quantize_weights(graph: Graph,
                     params: Dict[str, Dict[str, jax.Array]]
                     ) -> Dict[str, QuantizedLayer]:
    """Per-output-channel INT8 for every conv2d/dense node."""
    out: Dict[str, QuantizedLayer] = {}
    for name in graph.order:
        node = graph.nodes[name]
        if node.op not in ("conv2d", "dense"):
            continue
        p = params[name]
        w = p["w"]
        if node.op == "conv2d":
            kh, kw, cin, cout = w.shape
            w2 = w.reshape(kh * kw * cin, cout)
        else:
            w2 = w
        w_q, w_scale = kops.quantize(w2, axis=0)
        out[name] = QuantizedLayer(w_q=w_q, w_scale=w_scale,
                                   bias=p.get("b"))
    return out


def calibrate_graph(engine, sample_inputs: List[Dict[str, np.ndarray]],
                    traces: Optional[List[Dict[str, jax.Array]]] = None
                    ) -> Dict[str, float]:
    """Per-node activation absmax over a calibration set (fp32 flex run).
    Pass precomputed ``traces`` to avoid re-running the forward pass."""
    absmax: Dict[str, float] = {}
    if traces is None:
        traces = [_trace(engine, s) for s in sample_inputs]
    for vals in traces:
        for name, v in vals.items():
            m = float(jnp.max(jnp.abs(v)))
            absmax[name] = max(absmax.get(name, 0.0), m)
    return absmax


def ptq_error_ratios(engine, sample_inputs: List[Dict[str, np.ndarray]],
                     quant: Dict[str, QuantizedLayer],
                     absmax: Dict[str, float],
                     traces: Optional[List[Dict[str, jax.Array]]] = None
                     ) -> Dict[str, float]:
    """Per-node PTQ fidelity: max over the calibration set of
    ``max|quantized_out - fp32_out| / absmax(fp32_out)`` for every
    conv2d/dense node, simulated in fp32 (int8 activations at the static
    calibration scale x per-output-channel int8 weights).

    The execution planner demotes nodes whose ratio exceeds the engine's
    threshold to the flex path — layers whose outputs sit below the
    quantization noise floor never reach the int8 kernels.
    """
    from repro.core.engine import OP_IMPLS
    g = engine.graph
    ratios: Dict[str, float] = {}
    if traces is None:
        traces = [_trace(engine, s) for s in sample_inputs]
    for name, q in quant.items():           # node-constant setup once
        node = g.nodes[name]
        inp = node.inputs[0]
        s = act_scale(absmax.get(inp, 0.0))
        w = engine.params[name]["w"]
        w_hat = (q.w_q.astype(jnp.float32)
                 * q.w_scale[None, :]).reshape(w.shape)
        p_hat = dict(engine.params[name], w=w_hat)
        worst = 0.0
        for vals in traces:
            x_hat = jnp.clip(jnp.round(vals[inp] / s), -127, 127) * s
            out_q = OP_IMPLS[node.op]([x_hat], p_hat, node.attrs, None)
            ref = vals[name]
            err = float(jnp.max(jnp.abs(out_q - ref)))
            scale = float(jnp.max(jnp.abs(ref))) + 1e-12
            worst = max(worst, err / scale)
        ratios[name] = worst
    return ratios


def _trace(engine, inputs) -> Dict[str, jax.Array]:
    from repro.core.engine import OP_IMPLS
    g = engine.graph
    vals: Dict[str, jax.Array] = {}
    rng = jax.random.PRNGKey(0)
    for name, shape in g.graph_inputs.items():
        vals[name] = jnp.asarray(inputs[name], jnp.float32)
    for name in g.order:
        node = g.nodes[name]
        if node.op == "input":
            continue
        if node.op == "const":
            # structural plan-time value (tracer-captured literal or a
            # folding product) — no impl to run, no RNG to consume
            vals[name] = jnp.asarray(node.attrs["value"])
            continue
        rng, sub = jax.random.split(rng)
        vals[name] = OP_IMPLS[node.op]([vals[i] for i in node.inputs],
                                       engine.params.get(name, {}),
                                       node.attrs, sub)
    return vals


# ---------------------------------------------------------------------------
# QAT (straight-through estimator)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def fake_quant(x: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale


def _fq_fwd(x, scale):
    return fake_quant(x, scale), (x, scale)


def _fq_bwd(res, g):
    x, scale = res
    # STE: pass gradients through inside the clip range, zero outside
    inside = (jnp.abs(x) <= 127.0 * scale).astype(g.dtype)
    return g * inside, jnp.zeros_like(scale)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def qat_quantize_params(params: Dict[str, Dict[str, jax.Array]],
                        graph: Graph) -> Dict[str, Dict[str, jax.Array]]:
    """Fake-quantize all conv/dense weights (QAT forward); biases stay fp32."""
    out = {}
    for name, p in params.items():
        node = graph.nodes.get(name)
        if node is not None and node.op in ("conv2d", "dense") and "w" in p:
            w = p["w"]
            w2 = w.reshape(-1, w.shape[-1])
            scale = jnp.max(jnp.abs(w2), axis=0) / 127.0 + 1e-12
            wq = fake_quant(w2, scale[None, :]).reshape(w.shape)
            out[name] = dict(p, w=wq)
        else:
            out[name] = p
    return out
