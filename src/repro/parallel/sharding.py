"""Logical-axis sharding: model code names axes, the mesh maps them.

Model code never mentions mesh axes directly. Every tensor dimension gets a
*logical* name ('batch', 'seq', 'heads', 'ffn', ...); a rule table maps
logical names to mesh axes; and :func:`spec_for` resolves the mapping with
a divisibility fallback (a dim that cannot be evenly split over the mapped
mesh axes is replicated instead — this is what makes decode shapes with
seq=1 or batch=1 'just work' on the production mesh).

The active (mesh, rules) pair is installed with :func:`use_mesh`, a context
manager set up by the launcher / dry-run; when no context is active,
:func:`constrain` is a no-op, so unit tests on one CPU device run the same
model code unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """jax.shard_map across jax versions: new API takes ``check_vma``,
    older ones (top-level or experimental) call the same knob
    ``check_rep``."""
    try:
        from jax import shard_map as _sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
    try:
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=check_vma)
    except TypeError:
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Logical axis -> tuple of mesh axes (tried in order, greedily).
# 'data' doubles as the FSDP axis for weights; 'model' is the TP axis;
# 'pod' is the cross-pod DP axis.
SINGLE_POD_RULES = {
    # activations
    "batch": ("data",),
    "seq": ("model",),            # sequence parallelism between blocks
    "embed": (),                  # residual feature dim stays unsharded
    # attention
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    # mlp / experts
    "ffn": ("model",),
    "expert": ("model",),
    "expert_ffn": ("data",),      # second-level expert sharding (256-way EP)
    "expert_cap": ("data",),      # dispatch-buffer capacity dim
    # embeddings / head
    "vocab": ("model",),
    "fsdp": ("data",),            # ZeRO-style weight/optimizer sharding
    # ssm
    "ssm_heads": ("model",),
    "ssm_state": (),
    "conv_dim": ("model",),
}

MULTI_POD_RULES = dict(SINGLE_POD_RULES)
MULTI_POD_RULES.update({
    "batch": ("pod", "data"),
    "fsdp": ("data",),            # keep FSDP intra-pod; pods replicate weights
})


def rules_for(mesh: Mesh) -> dict:
    return MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES


def serving_rules(mesh: Mesh) -> dict:
    """Inference sharding: ZeRO/FSDP weight sharding is wrong for decode —
    it re-all-gathers every weight every step (measured 9 GB/device/step on
    yi-34b decode_32k; same pathology on the experts' second-level
    'expert_ffn' axis for llama4-scout). Serving replicates weights over
    the data axis and keeps TP/EP over 'model' (§Perf B1'). NB: only when
    the replicated weights fit HBM — llama4-maverick's 403B routed experts
    do not; its decode cell keeps the sharded layout (EXPERIMENTS.md
    §Perf fleet notes)."""
    rules = dict(rules_for(mesh))
    rules["fsdp"] = ()
    rules["expert_ffn"] = ()
    return rules


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, names: Sequence[str]) -> int:
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size


def spec_for(
    shape: Sequence[int],
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[dict] = None,
) -> P:
    """Resolve logical names to a PartitionSpec with divisibility fallback.

    For each dim, the mapped mesh-axis tuple is trimmed from the right until
    the dim size divides the product of the remaining axes (so 'batch' ->
    ('pod','data') falls back to ('pod',) and then to replication). Mesh
    axes already consumed by an earlier dim are skipped — PartitionSpec
    forbids reuse.
    """
    rules = rules or rules_for(mesh)
    if len(shape) != len(logical):
        raise ValueError(f"shape {shape} vs logical {logical} rank mismatch")
    used: set = set()
    out = []
    for dim, name in zip(shape, logical):
        if name is None:
            out.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ()) if a not in used)
        while axes and (dim % _axis_size(mesh, axes) != 0):
            axes = axes[:-1]
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
            used.add(axes[0])
        else:
            out.append(axes)
            used.update(axes)
    return P(*out)


def sharding_for(shape, logical, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, mesh, rules))


# ---------------------------------------------------------------------------
# Context
# ---------------------------------------------------------------------------

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> Optional[dict]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[dict] = None):
    """Install (mesh, rules) so :func:`constrain` becomes active."""
    prev = (current_mesh(), current_rules())
    _state.mesh = mesh
    _state.rules = rules or rules_for(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh, _state.rules = prev


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical, mesh, current_rules())
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Pytree helpers (params <-> shardings)
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# Explicit sequence-parallel collectives (§Perf iteration A3)
#
# Relying on the SPMD partitioner for the SP<->TP transitions leaves two
# costs on the table (measured on llama4-scout prefill_32k):
#   * the partitioner's all-reduce/all-gather get promoted/elided to f32
#     (2x wire bytes vs the bf16 values), and
#   * TP output projections stay all-reduce (+dynamic-slice) instead of
#     reduce-scatter (another 2x on the wire).
# These helpers pin both: bf16 all_gather on the way in, einsum +
# psum_scatter fused in one shard_map on the way out — Megatron-SP,
# explicitly. They fall back to plain constraints whenever the mesh/shape
# cannot support them (decode s=1, unit tests without a mesh, tp=1).
# ---------------------------------------------------------------------------


def _sp_ready(mesh, seq: int, *dims_mod_model: int) -> bool:
    if mesh is None or "model" not in mesh.axis_names:
        return False
    tp = mesh.shape["model"]
    if tp == 1 or seq % tp:
        return False
    return all(d % tp == 0 for d in dims_mod_model)


def sp_gather_seq(x: jax.Array, batch_logical: str = "batch") -> jax.Array:
    """[B, s/tp, D] seq-sharded -> [B, S, D] gathered, explicit bf16 wire."""
    mesh = current_mesh()
    if not _sp_ready(mesh, x.shape[1]):
        return constrain(x, batch_logical, None, None) if mesh is not None else x
    rules = current_rules()
    in_spec = spec_for(x.shape, (batch_logical, "seq", None), mesh, rules)
    out_spec = spec_for(x.shape, (batch_logical, None, None), mesh, rules)
    if "model" not in jax.tree.leaves(tuple(in_spec)):
        return constrain(x, batch_logical, None, None)

    def f(xb):
        # bitcast bf16 -> u16 around the gather pins the wire dtype: the
        # CPU backend otherwise upcasts bf16 math to f32 and hoists the
        # convert across the collective, doubling the *reported* (and, on
        # CPU, actual) wire bytes. On TPU this is a free bitcast.
        if xb.dtype == jnp.bfloat16:
            g = jax.lax.all_gather(
                jax.lax.bitcast_convert_type(xb, jnp.uint16),
                "model", axis=1, tiled=True)
            return jax.lax.bitcast_convert_type(g, jnp.bfloat16)
        return jax.lax.all_gather(xb, "model", axis=1, tiled=True)

    return shard_map(f, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec,
                     check_vma=False)(x)


def tp_proj_scatter(inp: jax.Array, w: jax.Array, subscripts: str,
                    inp_logical: Tuple, w_sharded_dim: int = 0) -> jax.Array:
    """``einsum(subscripts, inp, w)`` whose contraction runs over the
    model-sharded dim of ``w``; the partial result is psum_scatter'd onto
    the seq dim (axis 1) in ONE shard_map — reduce-scatter on the wire.

    inp: [B, S, ...] with the contracted dim model-sharded; w's
    ``w_sharded_dim`` is viewed P('model') (other dims replicated — jit
    gathers them, cheap for weight matrices)."""
    mesh = current_mesh()
    contracted = inp.shape[-1] if inp.ndim == 3 else inp.shape[2]
    if not _sp_ready(mesh, inp.shape[1], contracted):
        y = jnp.einsum(subscripts, inp, w)
        return constrain(y, "batch", "seq", None) if mesh is not None else y
    rules = current_rules()
    in_spec = spec_for(inp.shape, inp_logical, mesh, rules)
    w_spec = P(*[("model" if i == w_sharded_dim else None)
                 for i in range(w.ndim)])
    out_shape = jax.eval_shape(lambda a, b: jnp.einsum(subscripts, a, b),
                               inp, w).shape
    y_spec = spec_for(out_shape, ("batch", "seq", None), mesh, rules)

    def f(i_blk, w_blk):
        y = jnp.einsum(subscripts, i_blk, w_blk)
        return jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                    tiled=True)

    return shard_map(f, mesh=mesh, in_specs=(in_spec, w_spec),
                     out_specs=y_spec, check_vma=False)(inp, w)


def is_logical_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def tree_shardings(tree_shapes, tree_logical, mesh, rules=None):
    """Map matching pytrees of shapes (or ShapeDtypeStructs) and logical-axis
    tuples to a pytree of NamedShardings.

    Traverses the *logical* tree (whose leaves are axis-name tuples) so the
    shape tree's array/ShapeDtypeStruct leaves line up 1:1.
    """
    rules = rules or rules_for(mesh)

    def one(names, shape_like):
        shape = getattr(shape_like, "shape", shape_like)
        return sharding_for(shape, names, mesh, rules)

    return jax.tree.map(one, tree_logical, tree_shapes, is_leaf=is_logical_leaf)


def pad_to_multiple(n: int, m: int) -> int:
    return int(np.ceil(n / m) * m)
