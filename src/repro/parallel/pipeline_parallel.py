"""Pipeline parallelism: GPipe-style microbatched schedule over a 'stage'
mesh axis, expressed with shard_map + ppermute.

Completes the parallelism matrix (DP/TP/EP/SP/FSDP elsewhere in
parallel/): at 1000+-node scale the model axis saturates one pod's ICI,
and depth must shard across pods — each stage holds a contiguous slice of
the layer stack, activations flow stage-to-stage over collective-permute
(the only inter-pod traffic: one [mb, S, D] tensor per microbatch per
boundary, vs TP's per-layer collectives).

Schedule: the classic GPipe fill-drain loop — T = n_micro + n_stages - 1
ticks; at tick t, stage s computes microbatch (t - s) when
0 <= t - s < n_micro, else it computes on garbage and the result is
masked (the bubble). Efficiency = n_micro / T, reported by
:func:`bubble_fraction`.

The layer slice per stage is the SAME stacked-params layout the model
uses (params sharded over the stage axis on the layer dim), so a dense
model's ``groups`` pytree drops in unchanged.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from repro.parallel.sharding import shard_map
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_forward(
    stacked_params: Any,          # pytree, leaves [L, ...] — L % n_stages == 0
    x: jax.Array,                 # [n_micro, mb, S, D] microbatched input
    block_fn: Callable,           # (layer_params, x) -> x  (one layer)
    mesh,
    *,
    stage_axis: str = "stage",
    extra_specs: P = P(),         # sharding of non-stage dims of x (e.g. data)
) -> jax.Array:
    """Run the layer stack as a pipeline; returns [n_micro, mb, S, D].

    ``stacked_params`` leaves are sharded over ``stage_axis`` on dim 0 by
    the in_specs below — each stage sees its [L/n_stages, ...] slice and
    scans it locally per tick.
    """
    n_stages = mesh.shape[stage_axis]
    n_micro = x.shape[0]
    n_ticks = n_micro + n_stages - 1
    fwd_ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    p_specs = jax.tree.map(lambda _: P(stage_axis), stacked_params)
    x_spec = P(None, *extra_specs)   # microbatch dim replicated per stage

    def staged(params_blk, x_all):
        stage = jax.lax.axis_index(stage_axis)

        def local_stack(h):
            def body(carry, lp):
                return block_fn(lp, carry), None
            out, _ = jax.lax.scan(body, h, params_blk)
            return out

        mb_shape = x_all.shape[1:]
        outputs = jnp.zeros_like(x_all)

        def tick(t, carry):
            cur, outputs = carry
            # stage 0 injects microbatch t; others take the permuted input
            inject = x_all[jnp.clip(t, 0, n_micro - 1)]
            h_in = jnp.where(stage == 0, inject, cur)
            h_out = local_stack(h_in)
            # emit: the LAST stage finished microbatch (t - n_stages + 1)
            mb_idx = t - (n_stages - 1)
            is_valid = jnp.logical_and(stage == n_stages - 1, mb_idx >= 0)
            outputs = jax.lax.cond(
                is_valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.maximum(mb_idx, 0), 0),
                lambda o: o,
                outputs)
            # pass activations down the ring for the next tick
            nxt = jax.lax.ppermute(h_out, stage_axis, fwd_ring)
            return nxt, outputs

        cur = jnp.zeros(mb_shape, x_all.dtype)
        _, outputs = jax.lax.fori_loop(0, n_ticks, tick, (cur, outputs))
        # only the last stage holds non-zero outputs; psum replicates them
        # so the out_spec (no stage axis) is well-defined on every shard
        return jax.lax.psum(outputs, stage_axis)

    out = shard_map(
        staged, mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=x_spec,
        check_vma=False,
    )(stacked_params, x)
    return out
