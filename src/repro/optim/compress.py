"""Gradient compression for cross-pod data parallelism.

The paper's energy lever is quantization (INT8 weights on the DPU); the
distributed-training analog is quantizing the *gradient* traffic that
crosses the slow pod-to-pod links. Two composable schemes:

* :func:`int8_compress` / :func:`int8_decompress` — per-tensor symmetric
  INT8 with an fp32 scale (4x reduction of DP all-reduce bytes).
* :class:`ErrorFeedback` — residual accumulation so the quantization error
  is re-injected next step (keeps convergence; standard EF-SGD result).

These wrap the gradient pytree *before* the pjit-inserted all-reduce: the
compressed dtype flows through the collective, which is what shrinks the
collective-term in the roofline for multi-pod training.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads) -> Any:
    return jax.tree.map(lambda g: int8_compress(g), grads,
                        is_leaf=lambda x: isinstance(x, jax.Array))


def decompress_tree(comp, dtype=jnp.float32) -> Any:
    return jax.tree.map(
        lambda qs: int8_decompress(qs[0], qs[1], dtype), comp,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2,
    )


class ErrorFeedback(NamedTuple):
    residual: Any

    @staticmethod
    def init(params) -> "ErrorFeedback":
        return ErrorFeedback(jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress(grads, ef: ErrorFeedback):
    """Quantize (grad + residual); stash the new residual."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    comp, resid = [], []
    for g, r in zip(flat_g, flat_r):
        target = g.astype(jnp.float32) + r
        q, s = int8_compress(target)
        comp.append((q, s))
        resid.append(target - int8_decompress(q, s))
    return (jax.tree.unflatten(treedef, comp),
            ErrorFeedback(jax.tree.unflatten(treedef, resid)))
