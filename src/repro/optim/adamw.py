"""AdamW with decoupled weight decay, global-norm clipping, and fp32 master
state over bf16 params (optax-free — this container only has jax+numpy).

State layout is a plain pytree so it checkpoints and shards like params:
``m`` / ``v`` / master weights inherit each param's logical axes, which under
the FSDP rules means optimizer state is fully sharded over (data x model) —
the ZeRO-style trick that lets 34B-param training fit v5e HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array           # scalar int32
    m: Any                    # fp32 pytree
    v: Any                    # fp32 pytree
    master: Any               # fp32 master weights


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(f32, params),
            v=jax.tree.map(f32, params),
            master=jax.tree.map(lambda p: p.astype(jnp.float32), params),
        )

    def abstract_init(self, abstract_params) -> AdamWState:
        f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(f32, abstract_params),
            v=jax.tree.map(f32, abstract_params),
            master=jax.tree.map(f32, abstract_params),
        )

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, grad_norm)."""
        gnorm = global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, w):
            g = g.astype(jnp.float32)
            m = self.b1 * m + (1.0 - self.b1) * g
            v = self.b2 * v + (1.0 - self.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            w = w - lr * (mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * w)
            return m, v, w

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        flat_w = treedef.flatten_up_to(state.master)
        out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
        new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
        new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
        new_w = jax.tree.unflatten(treedef, [o[2] for o in out])

        old_flat = treedef.flatten_up_to(params)
        new_params = jax.tree.unflatten(
            treedef, [w.astype(p.dtype) for w, p in zip([o[2] for o in out], old_flat)]
        )
        return new_params, AdamWState(step, new_m, new_v, new_w), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(peak: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr
