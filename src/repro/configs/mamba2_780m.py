"""mamba2-780m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    arch_id="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4),
    subquadratic=True,
    tie_embeddings=True,
    notes="pure Mamba2 stack; runs the long_500k cell (O(1)-state decode)",
))
