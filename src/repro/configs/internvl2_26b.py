"""internvl2-26b — InternViT + InternLM2 VLM backbone [arXiv:2404.16821; hf].

Per the assignment, only the transformer BACKBONE (InternLM2-20B-style
decoder) is modeled; the InternViT vision frontend is a STUB —
``input_specs`` provides precomputed patch embeddings.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="internvl2-26b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="embed",
    notes="VLM backbone; patch-embedding stub frontend; vocab padded to 92560 "
          "for TP-16 divisibility of the LM head",
))
