"""llama4-maverick-400b-a17b — MoE 128 experts top-1 [hf; unverified].

Maverick interleaves dense and MoE FFN layers (period=2): 24 MoE layers x
128 experts x ~126M params/expert ≈ 386B routed + dense trunk ≈ 400B total,
~17B active — matching the published parameter split. (With period=1 the
total would be ~790B, contradicting the 400B name; noted in DESIGN.md.)
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=128, top_k=1, num_shared_experts=1, layer_period=2),
    notes="alternating dense/MoE; 128-expert layers need 256-way expert sharding",
))
