from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ArchConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    all_archs,
    get_arch,
    reduced,
    shapes_for,
)
