"""llama4-scout-17b-a16e — MoE 16 experts top-1, early fusion [hf; unverified].

Every layer is MoE (period=1) with one shared expert — this reproduces the
~109B-total / ~17B-active parameter split of the published model.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    arch_id="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=1, num_shared_experts=1, layer_period=1),
    notes="MoE every layer; experts sharded over (data, model) = 256-way EP",
))
