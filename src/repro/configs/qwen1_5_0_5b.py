"""qwen1.5-0.5b — small dense with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    notes="QKV bias; tied embeddings (vocab dominates params)",
))
