"""zamba2-1.2b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242; hf].

Mamba2 trunk with ONE weight-tied attention+MLP block applied every
``hybrid_attn_period`` layers (zamba2's shared-block design: the same
attention weights are reused at each application point).
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    arch_id="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
    hybrid_attn_period=6,
    subquadratic=True,
    tie_embeddings=True,
    notes="shared attn every 6 layers (6 applications over 38 layers); "
          "runs long_500k (attention is O(S) per decode step, SSM is O(1))",
))
