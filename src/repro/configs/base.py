"""Configuration system for repro.

Two config families live here:

* :class:`ArchConfig` — the ten assigned LM-family architectures (dense /
  MoE / SSM / hybrid / VLM / audio backbones), selectable via ``--arch``.
* :class:`ShapeSpec` — the per-arch input-shape cells (train_4k,
  prefill_32k, decode_32k, long_500k).

Configs are plain frozen dataclasses so they hash, print, and diff well;
the registry maps ``arch_id -> ArchConfig`` and is populated by the
``repro.configs.<arch>`` modules at import time.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (llama4-style top-1 routing)."""

    num_experts: int
    top_k: int = 1
    num_shared_experts: int = 1
    # Every `period`-th layer is MoE (1 = every layer, 2 = alternating).
    layer_period: int = 1
    capacity_factor: float = 1.25
    # Expert-parallel dispatch implementation:
    #   'scatter' — sharded capacity-buffer scatter (XLA SPMD resolves the
    #               cross-shard writes; baseline — measured collective-bound)
    #   'a2a'     — shard_map + explicit all_to_all over the 'model' axis
    #               (§Perf iteration A1; tokens move, not buffers)
    ep_impl: str = "scatter"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) settings."""

    state_dim: int = 128          # N — SSM state size per head
    head_dim: int = 64            # P — channels per SSD head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4           # short causal conv kernel
    chunk_size: int = 256         # SSD block size for the chunked scan


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One assigned architecture.

    ``family`` selects the block stack:
      dense   — pre-norm GQA transformer (llama-style)
      moe     — dense attention + routed expert FFN
      ssm     — attention-free Mamba2 (SSD) stack
      hybrid  — Mamba2 stack with a *shared* (weight-tied) attention block
                applied every ``hybrid_attn_period`` layers (zamba2-style)

    ``frontend`` selects what ``input_specs`` feeds the backbone:
      text    — int32 token ids, embedding table lookup
      embed   — precomputed frame/patch embeddings (the modality frontend
                is a STUB per the assignment; vlm + audio archs)
    """

    arch_id: str
    family: str                       # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int                    # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    frontend: str = "text"            # text | embed
    norm_eps: float = 1e-5
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_period: int = 6       # hybrid: shared attn every N layers
    tie_embeddings: bool = False
    # Sub-quadratic sequence mixing? Gates the long_500k cell.
    subquadratic: bool = False
    # INT8 KV cache (codes + per-token-head scales) — §Perf B2/C2; the
    # paper's PTQ residency idea applied to the decode-dominating bytes.
    kv_quant: bool = False
    notes: str = ""

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived sizes ------------------------------------------------------

    @property
    def attends(self) -> bool:
        return self.family in ("dense", "moe") or (
            self.family == "hybrid" and self.hybrid_attn_period > 0
        )

    def num_attn_layers(self) -> int:
        if self.family in ("dense", "moe"):
            return self.num_layers
        if self.family == "hybrid":
            return self.num_layers // self.hybrid_attn_period
        return 0

    def num_moe_layers(self) -> int:
        if self.moe is None:
            return 0
        return self.num_layers // self.moe.layer_period

    def param_count(self) -> int:
        """Analytic parameter count (excludes padding; used for 6ND)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n = 0
        # embeddings (+ output head unless tied)
        n += v * d
        if not self.tie_embeddings:
            n += v * d
        for layer in range(self.num_layers):
            if self.family in ("dense", "moe"):
                n += self._attn_params(d, hd)
                n += 2 * d  # two RMSNorm scales
                if self.moe is not None and layer % self.moe.layer_period == 0:
                    n += self.moe.num_experts * 3 * d * f
                    n += self.moe.num_shared_experts * 3 * d * f
                    n += d * self.moe.num_experts  # router
                else:
                    n += 3 * d * f  # SwiGLU
            elif self.family in ("ssm", "hybrid"):
                n += self._ssm_params(d)
                n += d  # norm
        if self.family == "hybrid":
            # one weight-tied attention block (norm + attn + mlp)
            n += self._attn_params(d, hd) + 3 * d * f + 2 * d
        n += d  # final norm
        return n

    def _attn_params(self, d: int, hd: int) -> int:
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        b = (self.num_heads + 2 * self.num_kv_heads) * hd if self.qkv_bias else 0
        return q + kv + o + b

    def _ssm_params(self, d: int) -> int:
        s = self.ssm
        di = s.expand * d
        nheads = di // s.head_dim
        in_proj = d * (2 * di + 2 * s.state_dim + nheads)
        conv = (di + 2 * s.state_dim) * s.conv_width
        out = di * d + di  # out proj + gate norm
        extra = 2 * nheads  # A_log, dt_bias
        return in_proj + conv + out + extra

    def active_param_count(self) -> int:
        """Active params per token (MoE discounts non-routed experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        inactive_experts = m.num_experts - m.top_k
        per_layer_inactive = inactive_experts * 3 * self.d_model * self.d_ff
        return self.param_count() - self.num_moe_layers() * per_layer_inactive


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape × step-kind) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

ALL_SHAPES: Tuple[ShapeSpec, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ArchConfig) -> Sequence[ShapeSpec]:
    """The shape cells that apply to an arch.

    ``long_500k`` needs sub-quadratic sequence mixing: it runs only for the
    SSM / hybrid archs; full-attention archs skip it (recorded in
    DESIGN.md / EXPERIMENTS.md, not silently).
    """
    out = []
    for s in ALL_SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            continue
        out.append(s)
    return tuple(out)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch_id {cfg.arch_id!r}")
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_arch(arch_id: str) -> ArchConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        ) from None


def all_archs() -> Sequence[str]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


def _ensure_loaded() -> None:
    # Import the per-arch modules lazily so `import repro.configs.base`
    # never pulls jax.
    if _REGISTRY:
        return
    from repro.configs import arch_defs  # noqa: F401  (registers everything)


def reduced(cfg: ArchConfig, *, layers: int = 2, width: int = 128) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests.

    Scales every dimension down while preserving family structure
    (GQA grouping ratio, MoE routing, SSM state, hybrid sharing).
    """
    heads = max(2, min(4, cfg.num_heads)) if cfg.num_heads else 0
    kv = 0
    if heads:
        kv = max(1, min(heads, cfg.num_kv_heads * heads // max(cfg.num_heads, 1)))
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(4, cfg.moe.num_experts), layer_period=cfg.moe.layer_period
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=16, chunk_size=32)
    return dataclasses.replace(
        cfg,
        arch_id=cfg.arch_id + "-smoke",
        num_layers=layers if cfg.family != "hybrid" else max(layers, cfg.hybrid_attn_period),
        d_model=width,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=width // heads if heads else 0,
        d_ff=width * 2,
        vocab_size=256,
        moe=moe,
        ssm=ssm,
        hybrid_attn_period=2 if cfg.family == "hybrid" else cfg.hybrid_attn_period,
    )
