"""Imports every per-arch config module, populating the registry."""
from repro.configs import (  # noqa: F401
    yi_34b,
    codeqwen1_5_7b,
    qwen1_5_0_5b,
    tinyllama_1_1b,
    internvl2_26b,
    mamba2_780m,
    musicgen_large,
    llama4_scout_17b_a16e,
    llama4_maverick_400b_a17b,
    zamba2_1_2b,
)
