"""yi-34b — llama-arch dense GQA [arXiv:2403.04652; hf]."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    notes="llama-arch GQA; 56 q heads pad to 64 for TP=16 (see parallel/sharding.py)",
))
