"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only: the EnCodec tokenizer / codebook-interleaving frontend is a
STUB; ``input_specs`` provides precomputed frame embeddings. The LM head
predicts the 2048-entry codebook.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    arch_id="musicgen-large",
    family="dense",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="embed",
    notes="audio decoder backbone over EnCodec frames (stub frontend)",
))
