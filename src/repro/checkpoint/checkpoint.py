"""Fault-tolerant checkpointing: atomic commit, async writes, auto-resume.

Layout (one directory per step)::

    ckpt_dir/
      step_000120/
        arrays.npz          # flattened pytree leaves (addressable shards)
        treedef.json        # structure + leaf names
        COMMITTED           # sentinel written LAST -> atomic commit

A checkpoint is valid iff COMMITTED exists; partially-written directories
(host died mid-save) are ignored by :func:`latest_step` and garbage-collected
by :func:`cleanup`. The async writer runs in a daemon thread so the train
loop never blocks on disk; ``wait()`` joins before the next save or exit.

On multi-host deployments each process saves its addressable shards into
``arrays.<process>.npz`` — restore re-assembles per-host. (Single-process
here, but the naming/commit protocol is the production one.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

COMMITTED = "COMMITTED"


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:09d}")


def _flatten_with_names(tree) -> Tuple[list, list]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves


def save(ckpt_dir: str, step: int, tree: Any, *, process: int = 0) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    d = _step_dir(ckpt_dir, step)
    os.makedirs(d, exist_ok=True)
    names, leaves = _flatten_with_names(tree)
    arrays = {}
    dtypes = {}
    for name, leaf in zip(names, leaves):
        x = np.asarray(jax.device_get(leaf))
        dtypes[name] = str(x.dtype)
        if x.dtype.name == "bfloat16":  # npz has no bf16 — store raw bits
            x = x.view(np.uint16)
        arrays[name] = x
    np.savez(os.path.join(d, f"arrays.{process}.npz"), **arrays)
    treedef = {"names": names, "step": step, "dtypes": dtypes}
    with open(os.path.join(d, "treedef.json"), "w") as f:
        json.dump(treedef, f)
    # commit LAST — readers only trust committed checkpoints
    with open(os.path.join(d, COMMITTED), "w") as f:
        f.write("ok")
    return d


def restore(ckpt_dir: str, step: int, like: Any, *, process: int = 0) -> Any:
    """Restore into the structure (and dtypes) of ``like``."""
    d = _step_dir(ckpt_dir, step)
    if not os.path.exists(os.path.join(d, COMMITTED)):
        raise FileNotFoundError(f"checkpoint at step {step} not committed: {d}")
    data = np.load(os.path.join(d, f"arrays.{process}.npz"))
    with open(os.path.join(d, "treedef.json")) as f:
        meta = json.load(f)
    saved_dtypes = meta.get("dtypes", {})
    names, leaves = _flatten_with_names(like)
    treedef = jax.tree.structure(like)
    out = []
    for name, leaf in zip(names, leaves):
        arr = data[name]
        if saved_dtypes.get(name) == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        dtype = getattr(leaf, "dtype", arr.dtype)
        out.append(jax.numpy.asarray(arr, dtype=dtype))
    return jax.tree.unflatten(treedef, out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, COMMITTED)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def cleanup(ckpt_dir: str, keep: int = 3) -> None:
    """Drop uncommitted wreckage and all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(ckpt_dir):
        return
    committed, junk = [], []
    for name in sorted(os.listdir(ckpt_dir)):
        if not name.startswith("step_"):
            continue
        path = os.path.join(ckpt_dir, name)
        (committed if os.path.exists(os.path.join(path, COMMITTED)) else junk
         ).append(path)
    for path in junk + committed[:-keep if keep else None]:
        shutil.rmtree(path, ignore_errors=True)


class AsyncCheckpointer:
    """Non-blocking saver: snapshot to host memory synchronously, write to
    disk in a daemon thread. One in-flight save at a time (back-pressure)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.saved_steps: list = []

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        # device->host copy happens NOW so training can mutate the arrays
        names, leaves = _flatten_with_names(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snapshot = jax.tree.unflatten(jax.tree.structure(tree), host)

        def work():
            save(self.ckpt_dir, step, snapshot)
            cleanup(self.ckpt_dir, self.keep)
            self.saved_steps.append(step)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
