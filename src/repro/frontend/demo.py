"""Front-end demo: a depthwise-separable cloud-mask CNN that exists
ONLY as a JAX function — no hand-built graph anywhere in models/ — going
trace -> inspect -> PTQ -> autotune -> scheduler serve end-to-end.

The model is a CloudScout-style cloud screening net (the classic
on-board selective-downlink use case: discard cloudy tiles before they
reach the radio): multispectral 48x48x4 tiles through a strided stem
conv and two depthwise-separable blocks, ending in a cloud probability
plus a thresholded discard flag. Depthwise convs exercise the grouped-
conv path the hand-built nets never touch: the inspector routes them to
flex (no int8 grouped kernel) while the pointwise 1x1 and dense layers
quantize onto the accel path — a partial-offload split the tracer has
to get right for the serve to work at all.
"""
from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.frontend.trace import TracedModel, trace

INPUT_SHAPE = (48, 48, 4)          # 4-band multispectral tile
CHANNELS = (16, 32, 64)            # stem, block1 pointwise, block2 pointwise
DENSE = 32
CLOUD_THRESHOLD = 0.5


def init_params(key: jax.Array) -> Dict[str, Dict[str, jax.Array]]:
    """He-init weights keyed by the *function's* layer names — the
    tracer rebinds them under traced node names."""
    shapes = {
        "stem": (3, 3, INPUT_SHAPE[-1], CHANNELS[0]),
        "dw1": (3, 3, 1, CHANNELS[0]),
        "pw1": (1, 1, CHANNELS[0], CHANNELS[1]),
        "dw2": (3, 3, 1, CHANNELS[1]),
        "pw2": (1, 1, CHANNELS[1], CHANNELS[2]),
    }
    params: Dict[str, Dict[str, jax.Array]] = {}
    for name, s in shapes.items():
        key, k1 = jax.random.split(key)
        fan_in = s[0] * s[1] * s[2]
        params[name] = {
            "w": jax.random.normal(k1, s, jnp.float32)
            * (2.0 / fan_in) ** 0.5,
            "b": jnp.zeros((s[-1],), jnp.float32)}
    fin = (INPUT_SHAPE[0] // 8) * (INPUT_SHAPE[1] // 8) * CHANNELS[2]
    for name, (i, o) in {"fc1": (fin, DENSE), "score": (DENSE, 1)}.items():
        key, k1 = jax.random.split(key)
        params[name] = {
            "w": jax.random.normal(k1, (i, o), jnp.float32)
            * (1.0 / i) ** 0.5,
            "b": jnp.zeros((o,), jnp.float32)}
    return params


def _conv(x, p, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups) + p["b"]


def jax_forward(params: Dict[str, Dict[str, jax.Array]],
                batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    x = jax.nn.relu(_conv(batch["bands"], params["stem"], stride=2))
    for i, blk in enumerate((("dw1", "pw1"), ("dw2", "pw2"))):
        dw, pw = blk
        x = jax.nn.relu(_conv(x, params[dw], groups=CHANNELS[i]))
        x = jax.nn.relu(_conv(x, params[pw]))
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    score = x @ params["score"]["w"] + params["score"]["b"]
    prob = jax.nn.sigmoid(score)
    return {"cloud_prob": prob,
            "cloud_flag": (prob > CLOUD_THRESHOLD).astype(jnp.float32)}


def build_traced(seed: int = 42) -> TracedModel:
    params = init_params(jax.random.PRNGKey(seed))
    return trace(functools.partial(jax_forward, params),
                 {"bands": INPUT_SHAPE}, name="cloud_mask_cnn")


def synthetic_input(key: jax.Array) -> Dict[str, jax.Array]:
    """A synthetic tile: cumulus-like bright blobs over a dark surface,
    correlated across the four bands."""
    k1, k2, k3 = jax.random.split(key, 3)
    h, w, _ = INPUT_SHAPE
    yy, xx = jnp.mgrid[0:h, 0:w]
    cy = jax.random.uniform(k1, (3, 1, 1), minval=8.0, maxval=h - 8.0)
    cx = jax.random.uniform(k2, (3, 1, 1), minval=8.0, maxval=w - 8.0)
    blobs = jnp.sum(jnp.exp(-(((yy - cy) / 6.0) ** 2
                              + ((xx - cx) / 7.0) ** 2)), axis=0)
    base = 0.1 + 0.05 * jax.random.normal(k3, (h, w))
    gains = jnp.asarray([1.0, 0.9, 0.8, 1.2])
    tile = base[..., None] + blobs[..., None] * gains
    return {"bands": tile.astype(jnp.float32)}


def synthetic_requests(n: int, seed: int = 0
                       ) -> List[Dict[str, np.ndarray]]:
    key = jax.random.PRNGKey(seed)
    out = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        out.append({k: np.asarray(v)
                    for k, v in synthetic_input(sub).items()})
    return out


def keep_predicate(out: Dict[str, np.ndarray]) -> bool:
    """Selective downlink: cloudy tiles are discarded on board."""
    return float(np.max(out["cloud_flag"])) < 0.5


def run_demo(n_requests: int = 32, rate_hz: float = 256.0,
             batch_top: int = 8, autotune: bool = True,
             backends=("accel", "flex"), verbose: bool = True) -> Dict:
    """The full front-end pipeline on the never-hand-built model:
    trace -> inspect -> PTQ calibrate -> autotune -> serve a Poisson
    trace through the continuous-batching scheduler. Returns the facts
    the demo/benchmark gates assert on."""
    from repro.core import inspector
    from repro.core.engine import Engine
    from repro.core.scheduler import (ContinuousBatchingScheduler,
                                      capped_ladder, poisson_arrivals)
    tm = build_traced()
    report = inspector.inspect(tm.graph)
    if verbose:
        print(tm.graph.summary())
        print(report.summary())
    engine = Engine(tm.graph, tm.params, autotune=autotune)
    reqs = synthetic_requests(n_requests, seed=11)
    if "accel" in backends:
        engine.calibrate(reqs[:4])
    sched = ContinuousBatchingScheduler(clock="modeled")
    sched.register("cloud_mask_cnn", engine, backend=backends,
                   ladder=capped_ladder(batch_top),
                   keep_predicate=keep_predicate, warmup_sample=reqs[0])
    arrivals = poisson_arrivals(rate_hz, n_requests, seed=5)
    sched.serve_trace([(t, "cloud_mask_cnn", r)
                       for t, r in zip(arrivals, reqs)])
    if verbose:
        print(sched.summary())
    kept = sum(1 for c in sched.completions if c.kept)
    return {
        "graph_nodes": len(tm.graph.order),
        "mac_coverage": report.mac_coverage,
        "n_segments": len(report.segments),
        "fully_supported": report.fully_supported,
        "n_requests": n_requests,
        "n_completed": len(sched.completions),
        "n_kept": kept,
        "outputs": sorted(tm.graph.outputs),
    }
