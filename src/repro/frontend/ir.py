"""Trace-time value and node representations shared by the jaxpr walker
(trace.py) and the per-primitive translators (translators.py).

Two value kinds flow through the walk:

* ``Ref`` — a tensor produced by an emitted graph node; ``sid`` indexes
  the ``NodeSpec`` list. Ref avals are *batched* (trace batch leading).
* ``ConstVal`` — a trace-time constant (jaxpr constvar or literal),
  stored **unbatched**. A const that passed through ``broadcast_in_dim``
  keeps its original value plus the broadcast target
  (``bdims``/``bshape``) so the bias-fold peephole can still see the
  per-channel vector instead of a materialized full-size array.

``NodeSpec`` is the mutable staging form of a graph node: peepholes
(bias fold, sum-pool -> avgpool) rewrite specs in place; the final
``Graph`` is only built once the whole jaxpr has been walked.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple


class UnsupportedPrimitiveError(NotImplementedError):
    """A jaxpr primitive (or a parameterization of one) has no graph
    translation. The message always names the offending eqn."""


@dataclasses.dataclass(frozen=True)
class Ref:
    sid: int


@dataclasses.dataclass
class ConstVal:
    value: Any                                   # np/jnp array, unbatched
    bdims: Optional[Tuple[int, ...]] = None      # broadcast_dimensions
    bshape: Optional[Tuple[int, ...]] = None     # broadcast target (batched)


@dataclasses.dataclass
class NodeSpec:
    sid: int
    op: str
    inputs: List[int]                            # producer sids
    attrs: Dict[str, Any]
    batched_shape: Tuple[int, ...]               # traced aval, batch leading
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    hint: Optional[str] = None                   # naming hint (best-effort)
    bias_folded: bool = False
