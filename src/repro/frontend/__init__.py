"""Jaxpr front-end: trace jax.jit-able models into the op graph.

Public surface:

* ``trace(fn, example_inputs, name=...)`` -> ``TracedModel`` whose
  ``.graph``/``.params`` flow through ``Engine.compile()`` unchanged.
* ``sample_normal(mu, logvar)`` — the reparameterization primitive for
  use inside traced functions (maps to the graph's RNG-threaded op).
* ``register(primitive_name)`` — extend the translator registry.
* ``UnsupportedPrimitiveError`` — raised, naming the eqn, for anything
  the graph can't express.
"""
from repro.frontend.ir import UnsupportedPrimitiveError
from repro.frontend.ops import sample_normal
from repro.frontend.trace import TRACE_BATCH, TracedModel, trace
from repro.frontend.translators import TRANSLATORS, register

__all__ = ["trace", "TracedModel", "TRACE_BATCH", "sample_normal",
           "register", "TRANSLATORS", "UnsupportedPrimitiveError"]
