"""Graph-level custom primitives usable inside traced JAX functions.

The op graph has one op with no lax equivalent: ``sample_normal`` — the
VAE reparameterization ``z = mu + exp(0.5*logvar) * eps`` whose *eps*
comes from the execution plan's per-sample RNG stream (RANDOM_OPS in
core/opgraph.py), not from anything the user function can close over.
A plain JAX implementation would need a PRNG key argument, which has no
place in the traced graph.

So the front-end exposes ``sample_normal(mu, logvar)`` as its own JAX
primitive: inside a trace it appears as a single ``sample_normal`` eqn
the translator registry maps 1:1 onto the graph op; outside a trace it
still *runs* (eager/jit) with a fixed PRNGKey(0) so users can sanity-
check their function before tracing — documented as NOT matching the
plan's RNG stream, which is owned by the scheduler/engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.extend import core as jex_core
from jax.interpreters import mlir

sample_normal_p = jex_core.Primitive("sample_normal")


def sample_normal(mu: jax.Array, logvar: jax.Array) -> jax.Array:
    """Reparameterized gaussian sample — traces to the graph's
    ``sample_normal`` op (plan-threaded RNG); eager execution uses a
    fixed PRNGKey(0) for smoke-testing only."""
    return sample_normal_p.bind(mu, logvar)


@sample_normal_p.def_abstract_eval
def _sample_normal_abstract(mu, logvar):
    if mu.shape != logvar.shape:
        raise ValueError(
            f"sample_normal: mu shape {mu.shape} != logvar shape "
            f"{logvar.shape}")
    return mu


def _sample_normal_eager(mu, logvar):
    # fixed key: deterministic smoke-test semantics outside the engine
    eps = jax.random.normal(jax.random.PRNGKey(0), jnp.shape(mu))
    return mu + jnp.exp(0.5 * logvar) * eps


sample_normal_p.def_impl(_sample_normal_eager)
mlir.register_lowering(
    sample_normal_p,
    mlir.lower_fun(_sample_normal_eager, multiple_results=False))
