"""Per-primitive jaxpr -> op-graph translator registry.

Each translator maps one jaxpr eqn onto zero or more ``NodeSpec``s via
the ``TraceState`` passed in, and returns the output values (``Ref`` or
``ConstVal``) for the eqn's outvars. Registering a new primitive is one
decorated function (DESIGN.md §14):

    @register("my_primitive")
    def _my_primitive(state, eqn, invals):
        (x,) = invals
        return [state.emit("my_op", [x], {}, eqn.outvars[0].aval.shape)]

Translators enforce the *exact* parameterizations the graph ops model —
anything else raises ``UnsupportedPrimitiveError`` naming the eqn, never
a bare KeyError. Three peepholes keep traced graphs structurally
identical to hand-built ones (the bit-exactness contract,
tests/test_frontend.py):

* ``conv/dense + add(broadcast(const))`` folds into the node's bias
  (sole-consumer guarded) — biases are node params, not add nodes.
* ``reduce_window_sum`` stages a pending ``_sum_poolNd`` spec that the
  following ``div`` by ``k**nd`` rewrites to ``avgpoolNd`` — the same
  sum-then-divide the batched impl executes, so the fold is bit-exact.
* ``gt`` + ``convert_element_type[f32]`` collapses onto the ``greater``
  node, whose impl already emits f32.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.frontend.ir import ConstVal, NodeSpec, Ref, \
    UnsupportedPrimitiveError

TRANSLATORS: Dict[str, Callable] = {}

# call-like primitives the walker inlines instead of translating
INLINE_PRIMS = ("pjit", "custom_jvp_call", "custom_vjp_call",
                "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr",
                "closed_call", "core_call", "xla_call")

# primitives whose translator must run even on all-constant inputs:
# eagerly materializing a broadcast bakes in target dims and loses the
# original per-channel vector the bias-fold peephole matches on
CONST_LAZY = ("broadcast_in_dim",)


def register(name: str):
    def deco(fn):
        TRANSLATORS[name] = fn
        return fn
    return deco


def _fail(eqn, why: str) -> None:
    raise UnsupportedPrimitiveError(
        f"cannot translate eqn `{eqn}`: {why}")


def _the_ref(eqn, val, what: str) -> Ref:
    if not isinstance(val, Ref):
        _fail(eqn, f"{what} must be a traced tensor, got a trace-time "
                   "constant")
    return val


def _const_scalar(val) -> float:
    """Extract a python scalar from a size-1 ConstVal, else None."""
    if not isinstance(val, ConstVal) or val.bdims is not None:
        return None
    v = np.asarray(val.value)
    if v.size != 1:
        return None
    return float(v.reshape(()))


def _out_shape(eqn) -> tuple:
    return tuple(eqn.outvars[0].aval.shape)


# ---------------------------------------------------------------------------
# conv / dense
# ---------------------------------------------------------------------------

# channel-last dimension_numbers for 2-D (NHWC/HWIO/NHWC) and 3-D
# (NDHWC/DHWIO/NDHWC) convs — the only layouts the graph models
_CONV_SPECS = {
    2: ((0, 3, 1, 2), (3, 2, 0, 1), (0, 3, 1, 2), "conv2d"),
    3: ((0, 4, 1, 2, 3), (4, 3, 0, 1, 2), (0, 4, 1, 2, 3), "conv3d"),
}


def _same_pads(size: int, k: int, s: int) -> tuple:
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    return (total // 2, total - total // 2)


@register("conv_general_dilated")
def _conv(state, eqn, invals):
    x, w = invals
    x = _the_ref(eqn, x, "conv input")
    if not isinstance(w, ConstVal) or w.bdims is not None:
        _fail(eqn, "conv weights must be a trace-time constant")
    p = eqn.params
    dn = p["dimension_numbers"]
    nd = len(dn.lhs_spec) - 2
    spec = _CONV_SPECS.get(nd)
    if spec is None or (dn.lhs_spec, dn.rhs_spec, dn.out_spec) != spec[:3]:
        _fail(eqn, f"only channel-last layouts are supported, got "
                   f"dimension_numbers={dn}")
    op = spec[3]
    if any(d != 1 for d in p["lhs_dilation"] + p["rhs_dilation"]):
        _fail(eqn, "dilated convolutions are not supported")
    if p.get("batch_group_count", 1) != 1:
        _fail(eqn, "batch_group_count != 1 is not supported")
    groups = p.get("feature_group_count", 1)
    if op == "conv3d" and groups != 1:
        _fail(eqn, "grouped conv3d is not supported")
    strides = tuple(p["window_strides"])
    if len(set(strides)) != 1:
        _fail(eqn, f"anisotropic strides {strides} are not supported")
    stride = strides[0]
    wv = np.asarray(w.value)
    kernel = tuple(wv.shape[:nd])
    features = int(wv.shape[-1])
    spatial = state.spec(x).batched_shape[1:1 + nd]
    pads = tuple(tuple(pr) for pr in p["padding"])
    if all(pr == (0, 0) for pr in pads):
        padding = "VALID"
    elif pads == tuple(_same_pads(s, k, stride)
                       for s, k in zip(spatial, kernel)):
        padding = "SAME"
    else:
        _fail(eqn, f"explicit padding {pads} is neither SAME nor VALID "
                   f"for input {spatial}, kernel {kernel}, "
                   f"stride {stride}")
    attrs = {"kernel": kernel, "features": features, "stride": stride,
             "padding": padding}
    if groups != 1:
        attrs["groups"] = groups
    ref = state.emit(op, [x], attrs, _out_shape(eqn),
                     params={"w": w.value,
                             "b": np.zeros((features,), np.float32)})
    return [ref]


@register("dot_general")
def _dot_general(state, eqn, invals):
    x, w = invals
    x = _the_ref(eqn, x, "dot_general lhs")
    if not isinstance(w, ConstVal) or w.bdims is not None:
        _fail(eqn, "dot_general rhs (weights) must be a trace-time "
                   "constant")
    dn = eqn.params["dimension_numbers"]
    contract, batch = dn
    if (tuple(contract[0]), tuple(contract[1])) != ((1,), (0,)) or \
            any(tuple(b) for b in batch):
        _fail(eqn, f"only [batch, k] @ [k, n] matmuls are supported, got "
                   f"dimension_numbers={dn}")
    if len(state.spec(x).batched_shape) != 2:
        _fail(eqn, "dot_general lhs must be rank-2 (flatten first)")
    wv = np.asarray(w.value)
    if wv.ndim != 2:
        _fail(eqn, f"dense weights must be rank-2, got {wv.shape}")
    ref = state.emit("dense", [x],
                     {"features": int(wv.shape[1]), "bias": False},
                     _out_shape(eqn), params={"w": w.value})
    return [ref]


# ---------------------------------------------------------------------------
# elementwise binary (+ bias folding)
# ---------------------------------------------------------------------------


def _try_bias_fold(state, eqn, ref: Ref, cv: ConstVal):
    """Fold `conv/dense(x) + broadcast(b)` into the producer's bias.
    Guarded: the pre-bias tensor must have exactly one reader (this add)
    and the producer must not already carry a folded bias."""
    spec = state.spec(ref)
    if spec.op not in ("conv2d", "conv3d", "dense") or spec.bias_folded:
        return False
    if state.reads_of(eqn, ref) != 1:
        return False
    v = np.asarray(cv.value)
    if v.ndim != 1 or v.shape[0] != spec.attrs["features"]:
        return False
    rank = len(spec.batched_shape)
    if cv.bdims is not None:
        # the broadcast must place the vector on the channel (last)
        # axis; jnp ranks biases up to (1, .., 1, c), so accept any
        # target whose dims are 1 or match the producer's aval
        if tuple(cv.bdims) != (rank - 1,) or len(cv.bshape) != rank or \
                any(d not in (1, s) for d, s in
                    zip(cv.bshape, spec.batched_shape)):
            return False
    elif rank != 2:        # unbroadcast (n,) only matches a [batch, n] lhs
        return False
    spec.params["b"] = v.astype(np.float32)
    if spec.op == "dense":
        spec.attrs["bias"] = True
    spec.bias_folded = True
    return True


def _binary(graph_op: str, commutative: bool):
    def t(state, eqn, invals):
        a, b = invals
        if graph_op == "add":
            for ref, cv in ((a, b), (b, a)):
                if isinstance(ref, Ref) and isinstance(cv, ConstVal) \
                        and _try_bias_fold(state, eqn, ref, cv):
                    return [ref]
        if commutative and isinstance(b, Ref) and not isinstance(a, Ref):
            a, b = b, a
        a = _the_ref(
            eqn, a, f"{graph_op} lhs (constant-first `{graph_op}` has no "
                    "graph form)")
        out = _out_shape(eqn)
        if out != state.spec(a).batched_shape:
            _fail(eqn, f"broadcasting {graph_op} changes the lhs shape "
                       f"{state.spec(a).batched_shape} -> {out}; the "
                       f"graph `{graph_op}` op is shape-preserving")
        bref = state.as_ref(eqn, b, per_sample_rank=len(out) - 1)
        return [state.emit(graph_op, [a, bref], {}, out)]
    return t


register("add")(_binary("add", commutative=True))
register("mul")(_binary("mul", commutative=True))
register("sub")(_binary("sub", commutative=False))


@register("div")
def _div(state, eqn, invals):
    x, d = invals
    x = _the_ref(eqn, x, "div lhs")
    scalar = _const_scalar(d)
    spec = state.spec(x)
    # the avgpool peephole: reduce_window_sum staged a pending spec;
    # dividing its sole reader by k**nd is exactly the batched avgpool
    # impl (sum-then-divide), so rewrite in place
    if spec.op.startswith("_sum_pool") and scalar is not None:
        nd = int(spec.op[len("_sum_pool")])
        if scalar == float(spec.attrs["kernel"] ** nd) and \
                state.reads_of(eqn, x) == 1:
            spec.op = f"avgpool{nd}d"
            return [x]
    _fail(eqn, "div is only supported as the normalizer of a "
               "sum-window average pool (reduce_window_sum / k**nd)")


@register("max")
def _max(state, eqn, invals):
    a, b = invals
    if isinstance(b, Ref) and not isinstance(a, Ref):
        a, b = b, a
    scalar = _const_scalar(b)
    if not isinstance(a, Ref) or scalar != 0.0:
        _fail(eqn, "only max(x, 0) — ReLU — is supported")
    return [state.emit("relu", [a], {}, _out_shape(eqn))]


@register("gt")
def _gt(state, eqn, invals):
    x, t = invals
    x = _the_ref(eqn, x, "gt lhs")
    scalar = _const_scalar(t)
    if scalar is None:
        _fail(eqn, "gt threshold must be a scalar trace-time constant")
    return [state.emit("greater", [x], {"threshold": scalar},
                       _out_shape(eqn))]


# ---------------------------------------------------------------------------
# elementwise unary
# ---------------------------------------------------------------------------


def _unary(graph_op: str):
    def t(state, eqn, invals):
        x = _the_ref(eqn, invals[0], f"{graph_op} input")
        return [state.emit(graph_op, [x], {}, _out_shape(eqn))]
    return t


register("logistic")(_unary("sigmoid"))
register("tanh")(_unary("tanh"))
register("exp")(_unary("exp"))


@register("convert_element_type")
def _convert(state, eqn, invals):
    # dtype is an execution-plan concern (impls cast; `greater` already
    # emits f32) — a convert on a traced tensor is a graph no-op
    return [_the_ref(eqn, invals[0], "convert_element_type input")]


# ---------------------------------------------------------------------------
# shape ops
# ---------------------------------------------------------------------------


@register("reshape")
def _reshape(state, eqn, invals):
    x = _the_ref(eqn, invals[0], "reshape input")
    spec = state.spec(x)
    new = tuple(eqn.params["new_sizes"])
    if eqn.params.get("dimensions") is not None:
        _fail(eqn, "reshape with dimension permutation is not supported")
    if new == spec.batched_shape:
        return [x]
    per_sample = spec.batched_shape[1:]
    if new == (spec.batched_shape[0], int(np.prod(per_sample))):
        return [state.emit("flatten", [x], {}, new)]
    _fail(eqn, f"only batch-preserving flatten reshapes are supported "
               f"({spec.batched_shape} -> {new})")


@register("broadcast_in_dim")
def _broadcast(state, eqn, invals):
    (v,) = invals
    shape = tuple(eqn.params["shape"])
    bdims = tuple(eqn.params["broadcast_dimensions"])
    if isinstance(v, ConstVal):
        if v.bdims is not None:
            _fail(eqn, "chained broadcasts of one constant are not "
                       "supported")
        return [ConstVal(v.value, bdims=bdims, bshape=shape)]
    if shape == state.spec(v).batched_shape:
        return [v]
    _fail(eqn, "broadcasting a traced tensor to a new shape has no "
               "graph form")


@register("concatenate")
def _concat(state, eqn, invals):
    dim = int(eqn.params["dimension"])
    if dim == 0:
        _fail(eqn, "concatenating along the batch dimension has no "
                   "graph form")
    refs = []
    rank = None
    for v in invals:
        if isinstance(v, Ref):
            rank = len(state.spec(v).batched_shape)
            break
    if rank is None:
        _fail(eqn, "concatenate needs at least one traced operand")
    for v in invals:
        refs.append(state.as_ref(eqn, v, per_sample_rank=rank - 1))
    return [state.emit("concat", refs, {"axis": dim - 1},
                       _out_shape(eqn))]


# ---------------------------------------------------------------------------
# pooling / reductions
# ---------------------------------------------------------------------------


def _window_pool(state, eqn, invals, kind: str):
    x = _the_ref(eqn, invals[0], "pool input")
    p = eqn.params
    rank = len(state.spec(x).batched_shape)
    nd = rank - 2
    if nd not in (2, 3):
        _fail(eqn, f"only 2-D/3-D channel-last pooling is supported "
                   f"(input rank {rank})")
    window = tuple(p["window_dimensions"])
    strides = tuple(p["window_strides"])
    if window[0] != 1 or window[-1] != 1 or strides[0] != 1 or \
            strides[-1] != 1:
        _fail(eqn, f"pool window {window} / strides {strides} must not "
                   "span batch or channel dims")
    ks, ss = set(window[1:-1]), set(strides[1:-1])
    if len(ks) != 1 or len(ss) != 1:
        _fail(eqn, f"anisotropic pool window {window} / strides "
                   f"{strides} are not supported")
    if any(tuple(pr) != (0, 0) for pr in p["padding"]):
        _fail(eqn, "padded pooling is not supported (graph pools are "
                   "VALID)")
    if any(d != 1 for d in p.get("base_dilation", (1,) * rank)
           + p.get("window_dilation", (1,) * rank)):
        _fail(eqn, "dilated pooling is not supported")
    k, s = ks.pop(), ss.pop()
    attrs = {"kernel": int(k)}
    if s != k:
        attrs["stride"] = int(s)
    op = f"maxpool{nd}d" if kind == "max" else f"_sum_pool{nd}d"
    return [state.emit(op, [x], attrs, _out_shape(eqn))]


@register("reduce_window_max")
def _reduce_window_max(state, eqn, invals):
    return _window_pool(state, eqn, invals, "max")


@register("reduce_window_sum")
def _reduce_window_sum(state, eqn, invals):
    # staged: only valid once the following div rewrites it to avgpool
    # (trace.finalize rejects any leftover _sum_pool spec)
    return _window_pool(state, eqn, invals, "sum")


@register("reduce_max")
def _reduce_max(state, eqn, invals):
    x = _the_ref(eqn, invals[0], "reduce_max input")
    shape = state.spec(x).batched_shape
    axes = tuple(eqn.params["axes"])
    if len(shape) != 4 or axes != (1, 2):
        _fail(eqn, "only global spatial reduce_max over a [batch, h, w, "
                   "c] tensor is supported")
    h, w = shape[1], shape[2]
    if h != w:
        _fail(eqn, f"global reduce_max needs square spatial dims, got "
                   f"{(h, w)}")
    pooled = state.emit("maxpool2d", [x], {"kernel": int(h)},
                        (shape[0], 1, 1, shape[3]))
    return [state.emit("flatten", [pooled], {}, _out_shape(eqn))]


@register("argmax")
def _argmax(state, eqn, invals):
    x = _the_ref(eqn, invals[0], "argmax input")
    shape = state.spec(x).batched_shape
    if len(shape) != 2 or tuple(eqn.params["axes"]) != (1,):
        _fail(eqn, "only argmax over the feature axis of a [batch, n] "
                   "tensor is supported")
    return [state.emit("argmax", [x], {}, _out_shape(eqn))]


# ---------------------------------------------------------------------------
# custom front-end primitives
# ---------------------------------------------------------------------------


@register("sample_normal")
def _sample_normal(state, eqn, invals):
    mu = _the_ref(eqn, invals[0], "sample_normal mu")
    logvar = _the_ref(eqn, invals[1], "sample_normal logvar")
    return [state.emit("sample_normal", [mu, logvar], {},
                       _out_shape(eqn))]
