"""Jaxpr front-end: trace any ``jax.jit``-able callable into the op
graph (DESIGN.md §14).

    traced = trace(fn, {"image": (128, 256, 3)}, name="my_net")
    engine = Engine(traced.graph, traced.params)

``fn`` takes one dict of **batched** arrays and returns a dict of
batched arrays; the returned keys become the graph's output node names
(the golden-digest contract keys results by output name, so the user —
not the tracer — owns those names). Tracing happens at a fixed batch of
2, which disambiguates the batch dim from size-1 tensor dims; per-sample
graph shapes are the traced avals minus the leading dim.

The walk is a straightforward abstract interpretation of the
``ClosedJaxpr``: constvars/literals become ``ConstVal``s, call-like
primitives (pjit, custom_jvp/vjp) are inlined, eqns whose inputs are all
constants are eagerly evaluated, and everything else dispatches through
the translator registry (translators.py). Node specs are staged so
peepholes can rewrite them (bias folding, sum-pool -> avgpool); the
``Graph`` is built at the end, where every node's inferred shape is
cross-checked against the traced aval — a translation bug dies here,
named, instead of surfacing as wrong numerics downstream.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import tree_util
from jax.extend import core as jex_core

from repro.core.opgraph import Graph
from repro.frontend.ir import ConstVal, NodeSpec, Ref, \
    UnsupportedPrimitiveError
from repro.frontend.translators import CONST_LAZY, INLINE_PRIMS, \
    TRANSLATORS

# fixed trace batch: >1 so the batch dim can't be mistaken for a size-1
# tensor dim when reshapes are classified
TRACE_BATCH = 2


@dataclasses.dataclass
class TracedModel:
    graph: Graph
    params: Dict[str, Dict[str, jax.Array]]
    out_names: Tuple[str, ...]


class TraceState:
    """Mutable walk state: staged node specs + var-use counts (the
    sole-consumer guard peepholes need) + the current naming hint."""

    def __init__(self, batch: int):
        self.batch = batch
        self.specs: List[NodeSpec] = []
        self.hint: Optional[str] = None
        self._uses: Dict[Any, int] = {}
        self._cur_invals: List[Any] = []

    # -- used by translators ------------------------------------------------

    def emit(self, op: str, inputs: List[Ref], attrs: Dict[str, Any],
             batched_shape: Tuple[int, ...],
             params: Optional[Dict[str, Any]] = None,
             hint: Optional[str] = None) -> Ref:
        spec = NodeSpec(len(self.specs), op, [r.sid for r in inputs],
                        dict(attrs), tuple(batched_shape),
                        params=dict(params or {}),
                        hint=hint or self.hint)
        self.specs.append(spec)
        return Ref(spec.sid)

    def spec(self, ref: Ref) -> NodeSpec:
        return self.specs[ref.sid]

    def reads_of(self, eqn, ref: Ref) -> int:
        """How many times the jaxpr reads the var that produced ``ref``
        (eqn operands + jaxpr outputs). Unknown -> 2, so peepholes that
        require a sole consumer conservatively refuse to fire."""
        for atom, val in zip(eqn.invars, self._cur_invals):
            if val is ref and isinstance(atom, jex_core.Var):
                return self._uses.get(atom, 2)
        return 2

    def as_ref(self, eqn, val, per_sample_rank: int) -> Ref:
        """A Ref for any value: Refs pass through; ConstVals become
        ``const`` nodes whose value is reshaped to ``per_sample_rank``
        (size-1 leading dims) so the batched impls broadcast them
        against the other operand."""
        if isinstance(val, Ref):
            return val
        v = np.asarray(val.value, np.float32)
        if val.bdims is not None:
            if any(d == 0 for d in val.bdims):
                raise UnsupportedPrimitiveError(
                    f"eqn `{eqn}`: constant broadcast into the batch "
                    "dimension has no graph form")
            shape = [1] * per_sample_rank
            for vd, d in enumerate(val.bdims):
                shape[d - 1] = v.shape[vd]
            v = v.reshape(shape)
        else:
            if v.ndim > per_sample_rank:
                raise UnsupportedPrimitiveError(
                    f"eqn `{eqn}`: rank-{v.ndim} constant does not fit "
                    f"a rank-{per_sample_rank} per-sample operand")
            v = v.reshape((1,) * (per_sample_rank - v.ndim) + v.shape)
        return self.emit("const", [], {"value": v},
                         (self.batch,) + v.shape)

    # -- used by the walker -------------------------------------------------

    def count_uses(self, jaxpr) -> None:
        for eqn in jaxpr.eqns:
            for a in eqn.invars:
                if isinstance(a, jex_core.Var):
                    self._uses[a] = self._uses.get(a, 0) + 1
        for a in jaxpr.outvars:
            if isinstance(a, jex_core.Var):
                self._uses[a] = self._uses.get(a, 0) + 1


def _sub_jaxpr(eqn):
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(key)
        if sub is None:
            continue
        if hasattr(sub, "jaxpr"):            # ClosedJaxpr
            return sub.jaxpr, sub.consts
        return sub, []
    raise UnsupportedPrimitiveError(
        f"call-like primitive '{eqn.primitive.name}' carries no "
        f"inlineable jaxpr (eqn `{eqn}`)")


def _walk(state: TraceState, jaxpr, consts, invals,
          extra_uses: Optional[List[int]] = None) -> List[Any]:
    env: Dict[Any, Any] = {}
    for v, c in zip(jaxpr.constvars, consts):
        env[v] = ConstVal(c)
    for v, val in zip(jaxpr.invars, invals):
        env[v] = val
    state.count_uses(jaxpr)
    if extra_uses:
        # readers outside this (inlined) jaxpr still count against the
        # sole-consumer peephole guards
        for v, e in zip(jaxpr.invars, extra_uses):
            if e:
                state._uses[v] = state._uses.get(v, 0) + e

    def read(atom):
        if isinstance(atom, jex_core.Literal):
            return ConstVal(np.asarray(atom.val))
        return env[atom]

    for eqn in jaxpr.eqns:
        vals = [read(a) for a in eqn.invars]
        pname = eqn.primitive.name
        if pname in INLINE_PRIMS:
            sub, sub_consts = _sub_jaxpr(eqn)
            extra = [max(state._uses.get(a, 1) - 1, 0)
                     if isinstance(a, jex_core.Var) else 0
                     for a in eqn.invars]
            prev_hint, hint = state.hint, eqn.params.get("name")
            if isinstance(hint, str) and hint:
                state.hint = hint
            outs = _walk(state, sub, sub_consts, vals, extra)
            state.hint = prev_hint
        elif pname in CONST_LAZY and pname in TRANSLATORS:
            state._cur_invals = vals
            outs = TRANSLATORS[pname](state, eqn, vals)
        elif all(isinstance(v, ConstVal) for v in vals):
            # pure trace-time computation: evaluate eagerly
            if any(v.bdims is not None for v in vals):
                raise UnsupportedPrimitiveError(
                    f"eqn `{eqn}`: constant math on a pending broadcast "
                    "is not supported")
            res = eqn.primitive.bind(
                *[jnp.asarray(v.value) for v in vals], **eqn.params)
            if not eqn.primitive.multiple_results:
                res = [res]
            outs = [ConstVal(np.asarray(r)) for r in res]
        elif pname in TRANSLATORS:
            state._cur_invals = vals
            outs = TRANSLATORS[pname](state, eqn, vals)
        else:
            raise UnsupportedPrimitiveError(
                f"no translator registered for primitive '{pname}' "
                f"(eqn `{eqn}`); add one with "
                "repro.frontend.translators.register")
        for var, val in zip(eqn.outvars, outs):
            env[var] = val
    return [read(v) for v in jaxpr.outvars]


def _finalize(state: TraceState, name: str, input_names: List[str],
              shapes: Dict[str, Tuple[int, ...]], outvals: List[Any],
              out_names: List[str]) -> TracedModel:
    out_by_sid: Dict[int, str] = {}
    for val, oname in zip(outvals, out_names):
        if not isinstance(val, Ref):
            raise UnsupportedPrimitiveError(
                f"output {oname!r} is a trace-time constant, not a "
                "traced tensor")
        if val.sid in out_by_sid:
            raise ValueError(
                f"outputs {out_by_sid[val.sid]!r} and {oname!r} are the "
                "same traced tensor; each output needs its own node")
        if state.specs[val.sid].op == "input" and \
                state.specs[val.sid].hint != oname:
            raise ValueError(
                f"output {oname!r} is the untouched input "
                f"{state.specs[val.sid].hint!r}")
        out_by_sid[val.sid] = oname

    g = Graph(name)
    params: Dict[str, Dict[str, jax.Array]] = {}
    used = set(input_names) | set(out_names)
    for k in input_names:
        g.input(k, shapes[k])
    for spec in state.specs:
        if spec.op == "input":
            spec.name = spec.hint
            continue
        if spec.op.startswith("_sum_pool"):
            raise UnsupportedPrimitiveError(
                "reduce_window_sum without a trailing div-by-window-size "
                "has no graph form (expected an average pool)")
        node_name = out_by_sid.get(spec.sid)
        if node_name is None:
            base = spec.hint or spec.op
            i = len(g.order)
            node_name = f"{base}_{i}"
            while node_name in used or node_name in g.nodes:
                i += 1
                node_name = f"{base}_{i}"
        used.add(node_name)
        in_names = [state.specs[s].name for s in spec.inputs]
        g.add(spec.op, in_names, name=node_name, **spec.attrs)
        spec.name = node_name
        expect = tuple(spec.batched_shape[1:])
        if g.nodes[node_name].out_shape != expect:
            raise AssertionError(
                f"tracer bug at node {node_name!r} ({spec.op}): graph "
                f"inferred {g.nodes[node_name].out_shape} but the jaxpr "
                f"traced per-sample {expect}")
        if spec.params:
            params[node_name] = {k: jnp.asarray(v, jnp.float32)
                                 for k, v in spec.params.items()}
    g.mark_output(*out_names)
    return TracedModel(g, params, tuple(out_names))


def trace(fn: Callable, example_inputs: Dict[str, Any], *,
          name: str = "traced") -> TracedModel:
    """Trace ``fn`` (dict of batched arrays -> dict of batched arrays)
    into a ``TracedModel``. ``example_inputs`` maps input names to
    per-sample shapes (tuples) or per-sample example arrays."""
    shapes: Dict[str, Tuple[int, ...]] = {}
    for k, v in example_inputs.items():
        if isinstance(v, (tuple, list)) and \
                all(isinstance(d, (int, np.integer)) for d in v):
            shapes[k] = tuple(int(d) for d in v)
        else:
            shapes[k] = tuple(np.shape(v))
    batched = {k: jax.ShapeDtypeStruct((TRACE_BATCH,) + s, jnp.float32)
               for k, s in shapes.items()}
    closed, out_struct = jax.make_jaxpr(fn, return_shape=True)(batched)

    leaves = tree_util.tree_flatten_with_path(out_struct)[0]
    out_names: List[str] = []
    for path, _leaf in leaves:
        if len(path) != 1 or not isinstance(path[0], tree_util.DictKey):
            raise TypeError(
                "traced function must return a flat dict of named "
                f"output arrays, got {out_struct!r}")
        out_names.append(str(path[0].key))

    state = TraceState(TRACE_BATCH)
    input_names = sorted(shapes)       # dict flatten order == invars order
    invals = []
    for k in input_names:
        spec = NodeSpec(len(state.specs), "input", [], {},
                        (TRACE_BATCH,) + shapes[k], hint=k)
        state.specs.append(spec)
        invals.append(Ref(spec.sid))
    outvals = _walk(state, closed.jaxpr, closed.consts, invals)
    return _finalize(state, name, input_names, shapes, outvals, out_names)
