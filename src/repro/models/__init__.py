"""The paper's six space-use-case networks, as op graphs + params.

Registry keys match the paper's Table I rows. ``synthetic_batch`` yields
``[n, ...]`` stacked inputs for the engine's batched execution plans.
"""
from __future__ import annotations

from typing import Callable, Dict, List, NamedTuple

import jax
import numpy as np

from repro.models import cnet_plus_scalar, esperta, mms, vae_encoder


class SpaceModel(NamedTuple):
    name: str
    build_graph: Callable
    init_params: Callable
    synthetic_input: Callable
    synthetic_batch: Callable
    paper_params: int         # Table I
    paper_ops: int            # Table I
    paper_toolchain: str      # which path the paper used
    # the same network as a plain batched JAX function
    # ``(params, batch) -> {output_name: array}`` — the jaxpr front-end
    # target (repro.frontend.trace; bit-exact vs build_graph by contract)
    jax_forward: Callable = None


SPACE_MODELS: Dict[str, SpaceModel] = {
    "vae_encoder": SpaceModel(
        "vae_encoder", vae_encoder.build_graph, vae_encoder.init_params,
        vae_encoder.synthetic_input, vae_encoder.synthetic_batch,
        395_692, 83_417_100, "vitis_ai", vae_encoder.jax_forward),
    "cnet_plus_scalar": SpaceModel(
        "cnet_plus_scalar", cnet_plus_scalar.build_graph,
        cnet_plus_scalar.init_params, cnet_plus_scalar.synthetic_input,
        cnet_plus_scalar.synthetic_batch,
        3_061_966, 918_241_400, "vitis_ai", cnet_plus_scalar.jax_forward),
    "multi_esperta": SpaceModel(
        "multi_esperta", esperta.build_graph,
        lambda key=None: esperta.init_params(key), esperta.synthetic_input,
        esperta.synthetic_batch, 24, 60, "hls", esperta.jax_forward),
    "logistic_net": SpaceModel(
        "logistic_net", mms.build_logistic_graph,
        lambda key: mms.init_params("logistic_net", key),
        mms.synthetic_input, mms.synthetic_batch, 8_196, 30_720, "hls",
        mms.jax_forward_logistic),
    "reduced_net": SpaceModel(
        "reduced_net", mms.build_reduced_graph,
        lambda key: mms.init_params("reduced_net", key),
        mms.synthetic_input, mms.synthetic_batch, 44_624, 502_961, "hls",
        mms.jax_forward_reduced),
    "baseline_net": SpaceModel(
        "baseline_net", mms.build_baseline_graph,
        lambda key: mms.init_params("baseline_net", key),
        mms.synthetic_input, mms.synthetic_batch,
        915_492, 110_541_696, "hls", mms.jax_forward_baseline),
}


def synthetic_requests(model: SpaceModel, n: int, seed: int = 0
                       ) -> List[Dict[str, np.ndarray]]:
    """``n`` independent synthetic request dicts as host numpy arrays —
    the request-staging convention every serving driver and test shares
    (one PRNG split chain from ``seed``, one dict per request)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        out.append({k: np.asarray(v)
                    for k, v in model.synthetic_input(sub).items()})
    return out
