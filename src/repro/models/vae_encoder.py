"""VAE Encoder — probabilistic conv encoder for SHARP magnetogram tiles.

128x256 RGB tiles -> 6-element latent (1:16,384 compression). Five
stride-2 conv+ReLU stages, then mu / logvar heads; the sampling + exp tail
is kept in the graph but is *flex-path only* (the paper executes exactly
these two ops on the CPU because they don't map to the DPU).

Channel widths are calibrated to the paper's Table I:
396,940 params (paper: 395,692; +0.32%), ~85.6 MOP (paper: 83.4 MOP).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.opgraph import Graph

INPUT_SHAPE = (128, 256, 3)
LATENT = 6
CHANNELS = (8, 32, 96, 144, 144)


def build_graph() -> Graph:
    g = Graph("vae_encoder")
    x = g.input("image", INPUT_SHAPE)
    for i, c in enumerate(CHANNELS):
        x = g.add("conv2d", [x], name=f"conv{i}", kernel=(3, 3), features=c,
                  stride=2, padding="SAME")
        x = g.add("relu", [x], name=f"relu{i}")
    x = g.add("flatten", [x], name="flatten")
    mu = g.add("dense", [x], name="mu", features=LATENT)
    logvar = g.add("dense", [x], name="logvar", features=LATENT)
    z = g.add("sample_normal", [mu, logvar], name="sample")
    g.mark_output(mu, logvar, z)
    return g


def jax_forward(params: Dict[str, Dict[str, jax.Array]],
                batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """The encoder as a plain batched JAX function — same math as the
    graph, traceable by the jaxpr front-end (DESIGN.md §14). Output keys
    are the graph's output node names."""
    from repro.frontend.ops import sample_normal
    x = batch["image"]
    for i in range(len(CHANNELS)):
        p = params[f"conv{i}"]
        x = jax.lax.conv_general_dilated(
            x, p["w"], (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
        x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    mu = x @ params["mu"]["w"] + params["mu"]["b"]
    logvar = x @ params["logvar"]["w"] + params["logvar"]["b"]
    return {"mu": mu, "logvar": logvar,
            "sample": sample_normal(mu, logvar)}


def init_params(key: jax.Array) -> Dict[str, Dict[str, jax.Array]]:
    from repro.models.common import init_graph_params
    return init_graph_params(build_graph(), key)


def synthetic_input(key: jax.Array) -> Dict[str, jax.Array]:
    """A synthetic active-region tile: bipolar gaussian blobs (sunspot pair)
    on a noisy background — matches Fig 1's structure."""
    k1, k2 = jax.random.split(key)
    h, w, _ = INPUT_SHAPE
    yy, xx = jnp.mgrid[0:h, 0:w]
    cy, cx = h // 2, w // 2
    pos = jnp.exp(-(((yy - cy) / 12.0) ** 2 + ((xx - cx + 30) / 18.0) ** 2))
    neg = -jnp.exp(-(((yy - cy) / 15.0) ** 2 + ((xx - cx - 30) / 20.0) ** 2))
    field = pos + neg + 0.05 * jax.random.normal(k1, (h, w))
    img = jnp.stack([field, jnp.abs(field), 0.5 * field], axis=-1)
    return {"image": img.astype(jnp.float32)}


def synthetic_batch(key: jax.Array, n: int) -> Dict[str, jax.Array]:
    from repro.models.common import batch_synthetic
    return batch_synthetic(synthetic_input, key, n)
