"""ESPERTA / multi-ESPERTA — Solar Energetic Particle event prediction
(Laurenza et al. 2009; Alberti et al. 2017).

Each ESPERTA model is a 3-input logistic threshold unit over (flare
heliolongitude, time-integrated soft X-ray flux, time-integrated ~1 MHz
radio flux): p = sigmoid(w.x + b); warn = p > threshold. The paper's
multi-ESPERTA packs SIX such models with different weights/thresholds in
parallel behind a shared input — 24 params, ~60 ops, and the op mix
(sigmoid + greater) is precisely what the DPU cannot run, forcing the
flexible path.

Weights/thresholds follow the published technique's regime split
(six (w, b, thr) sets, one per heliolongitude/flux regime).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import Graph

N_MODELS = 6

# per Laurenza et al.: logistic coefficients per regime (w_lon, w_sxr,
# w_radio, bias) and decision threshold. Values set per the published
# 10-minute-warning operating point.
WEIGHTS = np.array([
    [0.012, 1.10, 0.85, -2.10],
    [0.010, 1.25, 0.70, -1.95],
    [0.015, 0.95, 0.95, -2.30],
    [0.008, 1.40, 0.60, -1.80],
    [0.013, 1.05, 0.80, -2.05],
    [0.011, 1.15, 0.75, -2.00],
], np.float32)
THRESHOLDS = np.array([0.50, 0.45, 0.55, 0.40, 0.50, 0.48], np.float32)


def build_graph(n_models: int = N_MODELS) -> Graph:
    g = Graph("multi_esperta")
    x = g.input("features", (3,))
    for m in range(n_models):
        z = g.add("dense", [x], name=f"logit{m}", features=1)
        p = g.add("sigmoid", [z], name=f"prob{m}")
        w = g.add("greater", [p], name=f"warn{m}",
                  threshold=float(THRESHOLDS[m]))
        g.mark_output(p, w)
    return g


def build_single_graph(m: int = 0) -> Graph:
    """One ESPERTA model (the paper's sequential original)."""
    g = Graph(f"esperta_{m}")
    x = g.input("features", (3,))
    z = g.add("dense", [x], name="logit", features=1)
    p = g.add("sigmoid", [z], name="prob")
    w = g.add("greater", [p], name="warn", threshold=float(THRESHOLDS[m]))
    g.mark_output(p, w)
    return g


def jax_forward(params: Dict[str, Dict[str, jax.Array]],
                batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Multi-ESPERTA as a plain batched JAX function (jaxpr front-end
    target, DESIGN.md §14) — sigmoid + thresholded warning per model."""
    x = batch["features"]
    out: Dict[str, jax.Array] = {}
    for m in range(N_MODELS):
        p = params[f"logit{m}"]
        prob = jax.nn.sigmoid(x @ p["w"] + p["b"])
        out[f"prob{m}"] = prob
        out[f"warn{m}"] = (prob > float(THRESHOLDS[m])).astype(jnp.float32)
    return out


def init_params(key: jax.Array = None) -> Dict[str, Dict[str, jax.Array]]:
    del key  # fixed published weights, not trained
    return {
        f"logit{m}": {"w": jnp.asarray(WEIGHTS[m, :3][:, None]),
                      "b": jnp.asarray(WEIGHTS[m, 3:4])}
        for m in range(N_MODELS)
    }


def sequential_reference(inputs: Dict[str, jax.Array]) -> Dict[str, np.ndarray]:
    """The paper's ORIGINAL formulation: six ESPERTA models invoked one
    after another — the oracle multi-ESPERTA must match exactly."""
    x = np.asarray(inputs["features"], np.float32)
    out: Dict[str, np.ndarray] = {}
    for m in range(N_MODELS):
        z = float(x @ WEIGHTS[m, :3] + WEIGHTS[m, 3])
        p = 1.0 / (1.0 + np.exp(-z))
        out[f"prob{m}"] = np.asarray([p], np.float32)
        out[f"warn{m}"] = np.asarray([p > THRESHOLDS[m]], np.float32)
    return out


def synthetic_input(key: jax.Array) -> Dict[str, jax.Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    lon = jax.random.uniform(k1, (), minval=-90.0, maxval=90.0)
    sxr = jax.random.uniform(k2, (), minval=0.5, maxval=3.0)   # log-integr.
    radio = jax.random.uniform(k3, (), minval=0.3, maxval=2.5)
    return {"features": jnp.stack([lon, sxr, radio]).astype(jnp.float32)}


def synthetic_batch(key: jax.Array, n: int) -> Dict[str, jax.Array]:
    from repro.models.common import batch_synthetic
    return batch_synthetic(synthetic_input, key, n)
