"""LM decoder block — the on-board telemetry-summarisation language model.

One hybrid transformer/SSM decoder block over a fixed telemetry window:
token-wise (``per_position``) dense projections feed a causal GQA
attention head group and a Mamba-2 SSD scan, with residual adds and a
vocab head. The block is built from first-class op-graph nodes so it
compiles through the same Planned -> Lowered -> Compiled chain as the
CNNs: the inspector partitions it into accel QKV/MLP projections around
flex ``attention``/``ssd`` segments (DESIGN.md §15).

Shapes are deliberately small (interpret-mode Pallas on the dev host);
the structure — not the scale — is what the serving path exercises.

Graph contract the LM engine (``core/lm.py``) relies on:

* ``emb``'s only consumers are the q/k/v projections, so the requant
  pass can chain int8 straight through the QKV block;
* ``k_heads`` / ``v_heads`` / ``ssm_heads`` / ``b_proj`` / ``dt`` are
  marked as graph outputs — the prefill KV/state capture points;
* ``resid2`` (the pre-head hidden state) is an output: decode feeds it
  back as the next token's input features (continuous feedback — the
  telemetry LM has no discrete token embedding table);
* prompts are full fixed-length windows (``seq_len``): the SSD prefill
  state is the scan's final state, valid only when the prompt fills the
  window.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.opgraph import Graph
from repro.models.common import batch_synthetic, init_graph_params


class LMConfig(NamedTuple):
    seq_len: int = 32           # fixed prefill window (telemetry frame)
    d_model: int = 32
    n_q_heads: int = 4          # GQA: 2 query heads per KV head
    n_kv_heads: int = 2
    n_ssm_heads: int = 4
    head_p: int = 8             # SSD per-head state rows (H*P = d_model)
    d_state: int = 8            # SSD state cols N
    vocab: int = 16


DEFAULT_CONFIG = LMConfig()

# prefill capture points + serving outputs, in graph-output order
CAPTURE_OUTPUTS = ("k_heads", "v_heads", "ssm_heads", "b_proj", "dt")
SERVE_OUTPUTS = ("head", "resid2")


def build_graph(cfg: LMConfig = DEFAULT_CONFIG) -> Graph:
    s, d = cfg.seq_len, cfg.d_model
    hd = d // cfg.n_q_heads
    dkv = cfg.n_kv_heads * hd
    dssm = cfg.n_ssm_heads * cfg.head_p
    g = Graph("lm_decoder")
    x = g.input("x", (s, d))
    # token embedding stand-in: consumers are q/k/v ONLY (requant chain)
    emb = g.add("dense", [x], name="emb", features=d, per_position=True)
    q = g.add("dense", [emb], name="q_proj", features=d, per_position=True)
    k = g.add("dense", [emb], name="k_proj", features=dkv,
              per_position=True)
    v = g.add("dense", [emb], name="v_proj", features=dkv,
              per_position=True)
    qh = g.add("reshape", [q], name="q_heads",
               shape=(s, cfg.n_q_heads, hd))
    kh = g.add("reshape", [k], name="k_heads",
               shape=(s, cfg.n_kv_heads, hd))
    vh = g.add("reshape", [v], name="v_heads",
               shape=(s, cfg.n_kv_heads, hd))
    att = g.add("attention", [qh, kh, vh], name="attn", causal=True)
    af = g.add("reshape", [att], name="attn_flat", shape=(s, d))
    op = g.add("dense", [af], name="out_proj", features=d,
               per_position=True)
    ao = g.add("relu", [op], name="attn_out")     # fuses into out_proj
    r1 = g.add("add", [ao, x], name="resid1")
    # SSM branch (Mamba-2 SSD): x/B/C/dt projections off the residual
    xb = g.add("dense", [r1], name="ssm_in", features=dssm,
               per_position=True)
    xh = g.add("reshape", [xb], name="ssm_heads",
               shape=(s, cfg.n_ssm_heads, cfg.head_p))
    bp = g.add("dense", [r1], name="b_proj", features=cfg.d_state,
               per_position=True)
    cp = g.add("dense", [r1], name="c_proj", features=cfg.d_state,
               per_position=True)
    dtd = g.add("dense", [r1], name="dt_proj", features=cfg.n_ssm_heads,
                per_position=True)
    dts = g.add("sigmoid", [dtd], name="dt")      # fuses into dt_proj
    ssm = g.add("ssd", [xh, bp, cp, dts], name="ssm")
    sf = g.add("reshape", [ssm], name="ssm_flat", shape=(s, dssm))
    dn = g.add("dense", [sf], name="down_proj", features=d,
               per_position=True)
    r2 = g.add("add", [dn, r1], name="resid2")
    g.add("dense", [r2], name="head", features=cfg.vocab,
          per_position=True)
    g.mark_output(*SERVE_OUTPUTS, *CAPTURE_OUTPUTS)
    return g


def init_params(key: jax.Array, cfg: LMConfig = DEFAULT_CONFIG
                ) -> Dict[str, Dict[str, jax.Array]]:
    return init_graph_params(build_graph(cfg), key)


def synthetic_input(key: jax.Array, cfg: LMConfig = DEFAULT_CONFIG
                    ) -> Dict[str, jax.Array]:
    """One telemetry window: [S, D] continuous features."""
    return {"x": 0.5 * jax.random.normal(
        key, (cfg.seq_len, cfg.d_model), jnp.float32)}


def synthetic_batch(key: jax.Array, n: int,
                    cfg: LMConfig = DEFAULT_CONFIG
                    ) -> Dict[str, jax.Array]:
    return batch_synthetic(lambda k: synthetic_input(k, cfg), key, n)
