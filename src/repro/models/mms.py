"""MMS Neural Networks — dayside plasma-region classifiers (Ekelund et al.
2024; BaselineNet originally Olshevsky et al. 2021).

Input: 32x16x32 3-D ion energy distribution from the FPI instrument;
output: 4 classes (SW / IF / MSH / MSP). Three topologies:

* BaselineNet — 3-D convs + FC (calibrated: 918,625 params vs paper
  915,492; +0.34%, ~102 MOP vs 110.5 MOP).
* ReducedNet  — pool-first + slim 3-D conv + FC (44,363 vs 44,624; -0.6%).
* LogisticNet — pool + flatten + linear (8,196 — exact).

The paper drops the final sigmoid (argmax-only classification) — so do we;
3-D conv/pool is exactly the op class the DPU lacks, routing these to the
flexible path (the paper's HLS).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.opgraph import Graph
from repro.models.common import init_graph_params

INPUT_SHAPE = (32, 16, 32, 1)
N_CLASSES = 4


def build_logistic_graph() -> Graph:
    g = Graph("logistic_net")
    x = g.input("dist", INPUT_SHAPE)
    x = g.add("maxpool3d", [x], name="pool", kernel=2)
    x = g.add("flatten", [x], name="flatten")
    y = g.add("dense", [x], name="head", features=N_CLASSES)
    c = g.add("argmax", [y], name="region")
    g.mark_output(y, c)
    return g


def build_reduced_graph() -> Graph:
    g = Graph("reduced_net")
    x = g.input("dist", INPUT_SHAPE)
    x = g.add("maxpool3d", [x], name="pool0", kernel=2)
    x = g.add("conv3d", [x], name="conv0", kernel=(3, 3, 3), features=4,
              padding="SAME")
    x = g.add("relu", [x], name="act0")
    x = g.add("maxpool3d", [x], name="pool1", kernel=2)
    x = g.add("flatten", [x], name="flatten")
    x = g.add("dense", [x], name="fc1", features=43)
    x = g.add("relu", [x], name="fc1_act")
    y = g.add("dense", [x], name="head", features=N_CLASSES)
    c = g.add("argmax", [y], name="region")
    g.mark_output(y, c)
    return g


def build_baseline_graph() -> Graph:
    g = Graph("baseline_net")
    x = g.input("dist", INPUT_SHAPE)
    x = g.add("conv3d", [x], name="conv0", kernel=(3, 3, 3), features=16,
              padding="SAME")
    x = g.add("relu", [x], name="act0")
    x = g.add("maxpool3d", [x], name="pool0", kernel=2)
    x = g.add("conv3d", [x], name="conv1", kernel=(3, 3, 3), features=48,
              padding="SAME")
    x = g.add("relu", [x], name="act1")
    x = g.add("maxpool3d", [x], name="pool1", kernel=2)
    x = g.add("flatten", [x], name="flatten")
    x = g.add("dense", [x], name="fc1", features=73)
    x = g.add("relu", [x], name="fc1_act")
    y = g.add("dense", [x], name="head", features=N_CLASSES)
    c = g.add("argmax", [y], name="region")
    g.mark_output(y, c)
    return g


GRAPH_BUILDERS = {
    "logistic_net": build_logistic_graph,
    "reduced_net": build_reduced_graph,
    "baseline_net": build_baseline_graph,
}


def init_params(name: str, key: jax.Array) -> Dict[str, Dict[str, jax.Array]]:
    return init_graph_params(GRAPH_BUILDERS[name](), key)


def synthetic_input(key: jax.Array) -> Dict[str, jax.Array]:
    """A synthetic FPI distribution: anisotropic beam (solar-wind-like)
    plus thermal background."""
    k1, k2 = jax.random.split(key)
    e, t, p = jnp.mgrid[0:32, 0:16, 0:32]
    beam = jnp.exp(-((e - 10.0) ** 2 / 8.0 + (t - 8.0) ** 2 / 6.0
                     + (p - 16.0) ** 2 / 10.0))
    background = 0.05 * jax.random.uniform(k1, (32, 16, 32))
    dist = (beam + background)[..., None]
    return {"dist": dist.astype(jnp.float32)}


def synthetic_batch(key: jax.Array, n: int) -> Dict[str, jax.Array]:
    from repro.models.common import batch_synthetic
    return batch_synthetic(synthetic_input, key, n)
