"""MMS Neural Networks — dayside plasma-region classifiers (Ekelund et al.
2024; BaselineNet originally Olshevsky et al. 2021).

Input: 32x16x32 3-D ion energy distribution from the FPI instrument;
output: 4 classes (SW / IF / MSH / MSP). Three topologies:

* BaselineNet — 3-D convs + FC (calibrated: 918,625 params vs paper
  915,492; +0.34%, ~102 MOP vs 110.5 MOP).
* ReducedNet  — pool-first + slim 3-D conv + FC (44,363 vs 44,624; -0.6%).
* LogisticNet — pool + flatten + linear (8,196 — exact).

The paper drops the final sigmoid (argmax-only classification) — so do we;
3-D conv/pool is exactly the op class the DPU lacks, routing these to the
flexible path (the paper's HLS).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.opgraph import Graph
from repro.models.common import init_graph_params

INPUT_SHAPE = (32, 16, 32, 1)
N_CLASSES = 4


def build_logistic_graph() -> Graph:
    g = Graph("logistic_net")
    x = g.input("dist", INPUT_SHAPE)
    x = g.add("maxpool3d", [x], name="pool", kernel=2)
    x = g.add("flatten", [x], name="flatten")
    y = g.add("dense", [x], name="head", features=N_CLASSES)
    c = g.add("argmax", [y], name="region")
    g.mark_output(y, c)
    return g


def build_reduced_graph() -> Graph:
    g = Graph("reduced_net")
    x = g.input("dist", INPUT_SHAPE)
    x = g.add("maxpool3d", [x], name="pool0", kernel=2)
    x = g.add("conv3d", [x], name="conv0", kernel=(3, 3, 3), features=4,
              padding="SAME")
    x = g.add("relu", [x], name="act0")
    x = g.add("maxpool3d", [x], name="pool1", kernel=2)
    x = g.add("flatten", [x], name="flatten")
    x = g.add("dense", [x], name="fc1", features=43)
    x = g.add("relu", [x], name="fc1_act")
    y = g.add("dense", [x], name="head", features=N_CLASSES)
    c = g.add("argmax", [y], name="region")
    g.mark_output(y, c)
    return g


def build_baseline_graph() -> Graph:
    g = Graph("baseline_net")
    x = g.input("dist", INPUT_SHAPE)
    x = g.add("conv3d", [x], name="conv0", kernel=(3, 3, 3), features=16,
              padding="SAME")
    x = g.add("relu", [x], name="act0")
    x = g.add("maxpool3d", [x], name="pool0", kernel=2)
    x = g.add("conv3d", [x], name="conv1", kernel=(3, 3, 3), features=48,
              padding="SAME")
    x = g.add("relu", [x], name="act1")
    x = g.add("maxpool3d", [x], name="pool1", kernel=2)
    x = g.add("flatten", [x], name="flatten")
    x = g.add("dense", [x], name="fc1", features=73)
    x = g.add("relu", [x], name="fc1_act")
    y = g.add("dense", [x], name="head", features=N_CLASSES)
    c = g.add("argmax", [y], name="region")
    g.mark_output(y, c)
    return g


GRAPH_BUILDERS = {
    "logistic_net": build_logistic_graph,
    "reduced_net": build_reduced_graph,
    "baseline_net": build_baseline_graph,
}


# -- plain batched JAX forwards (jaxpr front-end targets, DESIGN.md §14) ----


def _maxpool3(x: jax.Array, k: int = 2) -> jax.Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, k, 1), (1, k, k, k, 1), "VALID")


def _conv3(x: jax.Array, p: Dict[str, jax.Array]) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, p["w"], (1, 1, 1), "SAME",
        dimension_numbers=("NDHWC", "DHWIO", "NDHWC")) + p["b"]


def _head(x: jax.Array, params, name: str = "head"):
    y = x.reshape(x.shape[0], -1) @ params[name]["w"] + params[name]["b"]
    return {"head": y, "region": jnp.argmax(y, axis=1).astype(jnp.int32)}


def jax_forward_logistic(params, batch):
    return _head(_maxpool3(batch["dist"]), params)


def jax_forward_reduced(params, batch):
    x = _maxpool3(batch["dist"])
    x = jax.nn.relu(_conv3(x, params["conv0"]))
    x = _maxpool3(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return _head(x, params)


def jax_forward_baseline(params, batch):
    x = batch["dist"]
    for i in range(2):
        x = _maxpool3(jax.nn.relu(_conv3(x, params[f"conv{i}"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return _head(x, params)


JAX_FORWARDS = {
    "logistic_net": jax_forward_logistic,
    "reduced_net": jax_forward_reduced,
    "baseline_net": jax_forward_baseline,
}


def init_params(name: str, key: jax.Array) -> Dict[str, Dict[str, jax.Array]]:
    return init_graph_params(GRAPH_BUILDERS[name](), key)


def synthetic_input(key: jax.Array) -> Dict[str, jax.Array]:
    """A synthetic FPI distribution: anisotropic beam (solar-wind-like)
    plus thermal background."""
    k1, k2 = jax.random.split(key)
    e, t, p = jnp.mgrid[0:32, 0:16, 0:32]
    beam = jnp.exp(-((e - 10.0) ** 2 / 8.0 + (t - 8.0) ** 2 / 6.0
                     + (p - 16.0) ** 2 / 10.0))
    background = 0.05 * jax.random.uniform(k1, (32, 16, 32))
    dist = (beam + background)[..., None]
    return {"dist": dist.astype(jnp.float32)}


def synthetic_batch(key: jax.Array, n: int) -> Dict[str, jax.Array]:
    from repro.models.common import batch_synthetic
    return batch_synthetic(synthetic_input, key, n)
