"""Shared param init for op-graph models (He/LeCun init per op type) and
batched synthetic-input stacking."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import Graph


def batch_synthetic(synthetic_input: Callable, key: jax.Array, n: int
                    ) -> Dict[str, jax.Array]:
    """Stack ``n`` independent synthetic samples into ``[n, ...]`` inputs
    (the layout the engine's batched execution plans consume)."""
    keys = jax.random.split(key, n)
    samples = [synthetic_input(k) for k in keys]
    return {name: jnp.stack([s[name] for s in samples])
            for name in samples[0]}


def init_graph_params(g: Graph, key: jax.Array
                      ) -> Dict[str, Dict[str, jax.Array]]:
    params: Dict[str, Dict[str, jax.Array]] = {}
    for name in g.order:
        node = g.nodes[name]
        if node.op == "conv2d":
            kh, kw = node.attrs["kernel"]
            cin = g.nodes[node.inputs[0]].out_shape[-1]
            cin_g = cin // node.attrs.get("groups", 1)
            cout = node.attrs["features"]
            key, k1 = jax.random.split(key)
            fan_in = kh * kw * cin_g
            params[name] = {
                "w": jax.random.normal(k1, (kh, kw, cin_g, cout),
                                       jnp.float32)
                * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((cout,), jnp.float32)}
        elif node.op == "conv3d":
            kd, kh, kw = node.attrs["kernel"]
            cin = g.nodes[node.inputs[0]].out_shape[-1]
            cout = node.attrs["features"]
            key, k1 = jax.random.split(key)
            fan_in = kd * kh * kw * cin
            params[name] = {
                "w": jax.random.normal(k1, (kd, kh, kw, cin, cout),
                                       jnp.float32) * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((cout,), jnp.float32)}
        elif node.op == "dense":
            in_shape = g.nodes[node.inputs[0]].out_shape
            # per_position dense projects the LAST axis only (the LM
            # token-wise QKV/MLP shape) — fan-in is the feature dim, not
            # the flattened sample
            fin = (int(in_shape[-1]) if node.attrs.get("per_position")
                   else int(np.prod(in_shape)))
            fout = node.attrs["features"]
            key, k1 = jax.random.split(key)
            p = {"w": jax.random.normal(k1, (fin, fout), jnp.float32)
                 * (1.0 / fin) ** 0.5}
            if node.attrs.get("bias", True):
                p["b"] = jnp.zeros((fout,), jnp.float32)
            params[name] = p
        elif node.op == "ssd":
            # per-head decay rate A [H], negative so exp(dt*A) < 1 for
            # dt > 0 (bounded state) — the Mamba-2 initialization range
            h = int(g.nodes[node.inputs[0]].out_shape[-2])
            key, k1 = jax.random.split(key)
            params[name] = {"A": -jax.random.uniform(
                k1, (h,), jnp.float32, minval=0.5, maxval=1.5)}
    return params
