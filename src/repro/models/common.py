"""Shared param init for op-graph models (He/LeCun init per op type) and
batched synthetic-input stacking."""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.opgraph import Graph


def batch_synthetic(synthetic_input: Callable, key: jax.Array, n: int
                    ) -> Dict[str, jax.Array]:
    """Stack ``n`` independent synthetic samples into ``[n, ...]`` inputs
    (the layout the engine's batched execution plans consume)."""
    keys = jax.random.split(key, n)
    samples = [synthetic_input(k) for k in keys]
    return {name: jnp.stack([s[name] for s in samples])
            for name in samples[0]}


def init_graph_params(g: Graph, key: jax.Array
                      ) -> Dict[str, Dict[str, jax.Array]]:
    params: Dict[str, Dict[str, jax.Array]] = {}
    for name in g.order:
        node = g.nodes[name]
        if node.op == "conv2d":
            kh, kw = node.attrs["kernel"]
            cin = g.nodes[node.inputs[0]].out_shape[-1]
            cin_g = cin // node.attrs.get("groups", 1)
            cout = node.attrs["features"]
            key, k1 = jax.random.split(key)
            fan_in = kh * kw * cin_g
            params[name] = {
                "w": jax.random.normal(k1, (kh, kw, cin_g, cout),
                                       jnp.float32)
                * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((cout,), jnp.float32)}
        elif node.op == "conv3d":
            kd, kh, kw = node.attrs["kernel"]
            cin = g.nodes[node.inputs[0]].out_shape[-1]
            cout = node.attrs["features"]
            key, k1 = jax.random.split(key)
            fan_in = kd * kh * kw * cin
            params[name] = {
                "w": jax.random.normal(k1, (kd, kh, kw, cin, cout),
                                       jnp.float32) * (2.0 / fan_in) ** 0.5,
                "b": jnp.zeros((cout,), jnp.float32)}
        elif node.op == "dense":
            fin = int(np.prod(g.nodes[node.inputs[0]].out_shape))
            fout = node.attrs["features"]
            key, k1 = jax.random.split(key)
            p = {"w": jax.random.normal(k1, (fin, fout), jnp.float32)
                 * (1.0 / fin) ** 0.5}
            if node.attrs.get("bias", True):
                p["b"] = jnp.zeros((fout,), jnp.float32)
            params[name] = p
    return params
