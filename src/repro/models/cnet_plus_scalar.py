"""CNetPlusScalar — CNN + scalar-context X-ray flux regressor (Miloshevich
et al., PyNets).

Multi-modal input: 256x256 2-channel solar imagery (HMI magnetogram +
AIA 193 Å, limb-brightening-corrected upstream) plus the preceding 30-min
background flux scalar, concatenated into the first FC layer — exactly the
paper's description. Leaky-ReLU is replaced by ReLU as the paper did for
DPU compatibility (the original is kept selectable for the fidelity test).

Calibrated to Table I: 3,050,485 params (paper: 3,061,966; -0.38%),
~0.92 GOP (paper: 0.918 GOP).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.opgraph import Graph
from repro.models.common import init_graph_params

INPUT_SHAPE = (256, 256, 2)
CHANNELS = (48, 48, 32)
DENSE = 92


def build_graph(dpu_compatible: bool = True) -> Graph:
    """``dpu_compatible=False`` keeps the original leaky_relu activations."""
    act = "relu" if dpu_compatible else "leaky_relu"
    g = Graph("cnet_plus_scalar")
    x = g.input("image", INPUT_SHAPE)
    s = g.input("background_flux", (1,))
    for i, c in enumerate(CHANNELS):
        x = g.add("conv2d", [x], name=f"conv{i}", kernel=(3, 3), features=c,
                  stride=1, padding="SAME")
        x = g.add(act, [x], name=f"act{i}")
        x = g.add("maxpool2d", [x], name=f"pool{i}", kernel=2)
    x = g.add("flatten", [x], name="flatten")
    x = g.add("concat", [x, s], name="concat_scalar", axis=0)
    x = g.add("dense", [x], name="fc1", features=DENSE)
    x = g.add("relu", [x], name="fc1_act")
    y = g.add("dense", [x], name="head", features=1)
    g.mark_output(y)
    return g


def jax_forward(params: Dict[str, Dict[str, jax.Array]],
                batch: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """CNetPlusScalar (DPU-compatible ReLU variant) as a plain batched
    JAX function — jaxpr front-end target (DESIGN.md §14)."""
    x = batch["image"]
    for i in range(len(CHANNELS)):
        p = params[f"conv{i}"]
        x = jax.lax.conv_general_dilated(
            x, p["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    x = jnp.concatenate([x, batch["background_flux"]], axis=1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return {"head": x @ params["head"]["w"] + params["head"]["b"]}


def init_params(key: jax.Array) -> Dict[str, Dict[str, jax.Array]]:
    return init_graph_params(build_graph(), key)


def synthetic_input(key: jax.Array) -> Dict[str, jax.Array]:
    k1, k2 = jax.random.split(key)
    h, w, _ = INPUT_SHAPE
    yy, xx = jnp.mgrid[0:h, 0:w]
    r2 = ((yy - h / 2) / (h / 2)) ** 2 + ((xx - w / 2) / (w / 2)) ** 2
    disk = (r2 < 0.9).astype(jnp.float32)
    hmi = disk * jax.random.normal(k1, (h, w)) * 0.3
    aia = disk * jnp.exp(-3.0 * r2) + 0.02 * jax.random.normal(k2, (h, w))
    return {
        "image": jnp.stack([hmi, aia], axis=-1).astype(jnp.float32),
        "background_flux": jnp.array([1e-6 * 3.0], jnp.float32) * 1e6,
    }


def synthetic_batch(key: jax.Array, n: int) -> Dict[str, jax.Array]:
    from repro.models.common import batch_synthetic
    return batch_synthetic(synthetic_input, key, n)
