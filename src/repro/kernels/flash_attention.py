"""Pallas TPU kernel: blockwise online-softmax (flash) causal attention.

The beyond-paper kernel (DESIGN.md §6): the 32k-prefill cells would need a
materialized [S,S] score matrix (17 GB/device for yi-34b) without it.

Grid (B, Hq, Sq/bq, Sk/bk); the KV dim is innermost/'arbitrary' so the
running max m, normalizer l, and output accumulator persist in VMEM across
KV steps. GQA is handled in the BlockSpec index maps — KV blocks are
indexed by ``h // group`` so grouped query heads share the same KV stream
without materializing repeated K/V (which is exactly what a DMA engine
should never copy twice).

Causality is exploited two ways: fully-masked KV blocks short-circuit via
``pl.when`` (no MXU work), and the diagonal block applies the triangle mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bq: int, bk: int, n_k: int, causal: bool,
            kv_len: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(2)
    q_start = i * bq
    k_start = j * bk
    padded_kv = kv_len < n_k * bk           # static: ragged Sk was padded

    # skip blocks strictly above the causal diagonal — and blocks that
    # lie entirely in the ragged-length KV padding
    run = (not causal) or (k_start <= q_start + bq - 1)
    if padded_kv:
        run = jnp.logical_and(run, k_start < kv_len)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal or padded_kv:
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if padded_kv:
            s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,                   # [B, Sq, Hq, hd]
    k: jax.Array,                   # [B, Sk, Hkv, hd]
    v: jax.Array,                   # [B, Sk, Hkv, hd]
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    bq, bk = min(bq, sq), min(bk, sk)
    # ragged sequence lengths: pad up to the block grid. Padded KV columns
    # are masked to NEG_INF inside the kernel (so they never contribute);
    # padded query rows compute garbage that is sliced off below.
    pad_q, pad_k = (-sq) % bq, (-sk) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    n_k = sk_p // bk
    scale = hd ** -0.5

    qt = q.transpose(0, 2, 1, 3)                         # [B, Hq, Sq, hd]
    kt = k.transpose(0, 2, 1, 3)                         # [B, Hkv, Sk, hd]
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bk=bk, n_k=n_k,
                          causal=causal, kv_len=sk),
        grid=(b, hq, sq_p // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :sq] if pad_q else out
