"""Pallas TPU kernel: blockwise online-softmax (flash) causal attention.

The beyond-paper kernel (DESIGN.md §6): the 32k-prefill cells would need a
materialized [S,S] score matrix (17 GB/device for yi-34b) without it.

Grid (B, Hq, Sq/bq, Sk/bk); the KV dim is innermost/'arbitrary' so the
running max m, normalizer l, and output accumulator persist in VMEM across
KV steps. GQA is handled in the BlockSpec index maps — KV blocks are
indexed by ``h // group`` so grouped query heads share the same KV stream
without materializing repeated K/V (which is exactly what a DMA engine
should never copy twice).

Causality is exploited two ways: fully-masked KV blocks short-circuit via
``pl.when`` (no MXU work), and the diagonal block applies the triangle mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, bq: int, bk: int, n_k: int, causal: bool):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    i = pl.program_id(2)
    q_start = i * bq
    k_start = j * bk

    # skip blocks strictly above the causal diagonal
    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)              # [bk, hd]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,                   # [B, Sq, Hq, hd]
    k: jax.Array,                   # [B, Sk, Hkv, hd]
    v: jax.Array,                   # [B, Sk, Hkv, hd]
    *,
    causal: bool = True,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, sq, hq, hd = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    bq, bk = min(bq, sq), min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    n_k = sk // bk
    scale = hd ** -0.5

    qt = q.transpose(0, 2, 1, 3)                         # [B, Hq, Sq, hd]
    kt = k.transpose(0, 2, 1, 3)                         # [B, Hkv, Sk, hd]
    vt = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bq=bq, bk=bk, n_k=n_k,
                          causal=causal),
        grid=(b, hq, sq // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
