"""Version shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels import the name from here so they run on both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams",
                         getattr(pltpu, "TPUCompilerParams", None))
if CompilerParams is None:   # fail at import, with the actual reason
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
        "TPUCompilerParams; this jax version is unsupported")
