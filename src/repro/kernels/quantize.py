"""Pallas TPU kernel: fused symmetric INT8 quantization (PTQ inner loop).

Two-phase: scales come from an XLA reduction (absmax is bandwidth-bound and
XLA already emits an optimal reduce); the Pallas kernel fuses
scale-broadcast + round + clip + cast in one pass so the fp32 tensor is
read exactly once and only int8 is written back — the 4x HBM-write saving
is the point (cf. the paper's PTQ step, where quantization cost is amortized
offline but on-line requantization of activations is per-inference).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _divisor_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target."""
    for b in range(min(target, n), 0, -1):
        if n % b == 0:
            return b
    return n


def _kernel(x_ref, s_ref, q_ref):
    x = x_ref[...].astype(jnp.float32)
    inv = 1.0 / s_ref[...]                       # [bn] per-channel
    q = jnp.round(x * inv[None, :])
    q_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def quantize_apply(
    x: jax.Array,                   # [M, N] float
    scale: jax.Array,               # [N] f32 per-channel (axis=0 reduced)
    *,
    bm: int = 256,
    bn: int = 256,
    interpret: bool = True,
) -> jax.Array:
    m, n = x.shape
    bm, bn = _divisor_block(m, bm), _divisor_block(n, bn)
    return pl.pallas_call(
        _kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int8),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, scale)


def quantize(x: jax.Array, axis: Optional[int] = 0,
             interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-channel (or per-tensor) INT8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    if axis is None:
        scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return q, scale
    assert x.ndim == 2 and axis == 0, "kernel path: 2-D, per-column scales"
    scale = jnp.max(jnp.abs(xf), axis=0) / 127.0 + 1e-12
    return quantize_apply(xf, scale, interpret=interpret), scale
