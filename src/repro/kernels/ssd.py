"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

The SSM mixer is this framework's "flexible-path" op (DESIGN.md §4 — the
analog of the paper's MMS 3-D convs that the DPU cannot run), but its
inner chunk math is pure MXU work. This kernel keeps the running state
[P, N] resident in VMEM across the chunk dimension — the HBM-traffic
profile the pure-XLA version cannot achieve (it round-trips chunk states
and materializes the [Q, Q] decay masks in HBM).

Grid (B, H, S/Q), chunk index innermost with 'arbitrary' semantics:
    per step (all f32 in VMEM):
      a   = dt * A[h]                cum = cumsum(a)
      L   = tril(exp(cum_i - cum_j))             [Q, Q]
      M   = (C @ B^T) * L * dt_j                 [Q, Q]
      y   = M @ x  +  exp(cum)_i * (C @ state^T) [Q, P]
      state = exp(cum_Q) * state + ((suffix*dt) . x)^T B
the state scratch carries across chunk steps; the final state is emitted
on the last step (prefill hands it to the decode recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams


def _kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, init_ref,
            y_ref, final_ref, state_ref, *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = init_ref[0, 0].astype(jnp.float32)      # [P, N]

    q = x_ref.shape[1]
    x = x_ref[0, :, 0, :].astype(jnp.float32)                    # [Q, P]
    B = b_ref[0].astype(jnp.float32)                             # [Q, N]
    C = c_ref[0].astype(jnp.float32)                             # [Q, N]
    dt = dt_ref[0, :, 0].astype(jnp.float32)                     # [Q]
    A = a_ref[0]                                                 # scalar

    a = dt * A
    cum = jnp.cumsum(a)                                          # [Q]

    # intra-chunk: decay-masked "attention" over the chunk
    li = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(iota_i >= iota_j, jnp.exp(li), 0.0)            # [Q, Q]
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    M = scores * L * dt[None, :]                                 # [Q, Q]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [Q, P]

    # inter-chunk: contribution of the state entering this chunk
    state = state_ref[...]                                       # [P, N]
    y_in = jax.lax.dot_general(C, state, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [Q, P]
    y = y + jnp.exp(cum)[:, None] * y_in
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: decay past the chunk + this chunk's outer products
    suffix = jnp.exp(cum[q - 1] - cum) * dt                      # [Q]
    s_new = jax.lax.dot_general(x * suffix[:, None], B,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [P, N]
    state_ref[...] = state * jnp.exp(cum[q - 1]) + s_new

    @pl.when(ci == n_chunks - 1)
    def _emit():
        final_ref[0, 0] = state_ref[...].astype(final_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd(x, B_, C_, dt, A, init_state=None, *, chunk: int = 256,
        interpret: bool = False):
    """Chunked SSD scan. x [B,S,H,P], B_/C_ [B,S,N], dt [B,S,H] (already
    softplus'd, f32), A [H] (negative, f32), init_state [B,H,P,N] or None.
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    if s % chunk:
        chunk = next(c for c in range(min(chunk, s), 0, -1) if s % c == 0)
    nc = s // chunk
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    grid = (b, h, nc)
    out_shapes = (
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, B_, C_, dt, A.astype(jnp.float32), init_state)
