"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematically-plain implementation the kernels are
tested against with ``assert_allclose`` over shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def int8_matmul_ref(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                    w_scale: jax.Array, bias: Optional[jax.Array] = None,
                    relu: bool = False, out_dtype=jnp.float32) -> jax.Array:
    """x_q [M,K] int8, w_q [K,N] int8, x_scale [M] f32 (per-row),
    w_scale [N] f32 (per-output-channel)."""
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    out = acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]
    if bias is not None:
        out = out + bias[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(out_dtype)


def conv2d_ref(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None,
               stride: int = 1, padding: str = "SAME",
               relu: bool = False) -> jax.Array:
    """NHWC conv. x [B,H,W,Cin], w [KH,KW,Cin,Cout]."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        out = out + bias
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q [B,Sq,Hq,hd], k/v [B,Sk,Hkv,hd]; GQA by head grouping."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    if causal:
        sk = k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None, None], scores, -2.0e38)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def quantize_ref(x: jax.Array, axis: Optional[int] = 0):
    """Symmetric int8 PTQ. axis=None -> per-tensor; else per-channel over
    the remaining axis. Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    if axis is None:
        scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-12
    else:
        scale = jnp.max(jnp.abs(xf), axis=axis, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axis) if axis is not None else scale


def dequantize_ref(q: jax.Array, scale: jax.Array, axis: Optional[int] = 0,
                   dtype=jnp.float32) -> jax.Array:
    if axis is None:
        return (q.astype(jnp.float32) * scale).astype(dtype)
    return (q.astype(jnp.float32) * jnp.expand_dims(scale, axis)).astype(dtype)


def ssd_ref(x, B_, C_, dt, A, init_state=None):
    """Naive SSD recurrence (O(S) scan — the correctness contract for the
    chunked Pallas kernel). Shapes as kernels/ssd.py."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    state0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def step(state, t):
        decay = jnp.exp(dt[:, t] * A)                            # [B, H]
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], B_[:, t].astype(jnp.float32),
            x[:, t].astype(jnp.float32))
        y_t = jnp.einsum("bn,bhpn->bhp", C_[:, t].astype(jnp.float32), state)
        return state, y_t

    final, ys = jax.lax.scan(step, state0, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final
