"""Pallas TPU kernel: NHWC conv2d as shift-and-matmul (the DPU conv engine).

TPU adaptation of the DPU's convolution array: instead of a systolic
line-buffer (FPGA idiom), each grid step loads the KH input rows feeding
one output row into VMEM and accumulates KH*KW shifted [W_out, Cin] x
[Cin, Cout] matmuls on the MXU — im2col without ever materializing the
patch matrix in HBM. Bias + ReLU fuse into the epilogue.

Space-use-case shapes (<=128x256 imgs, <=64 channels) keep the whole row
set comfortably inside VMEM; the grid parallelizes over (batch, out-row).
Supports stride 1/2 and 'SAME'/'VALID' padding (host-side pre-pad).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.epilogue import (apply_epilogue, normalize_act,
                                    out_dtype_for)


def _conv_geometry(x: jax.Array, kh: int, kw: int, stride: int,
                   padding: str, rows_per_block: int = 1):
    """Shared SAME/VALID geometry for the fp32 and int8 kernels: returns
    ``(x_padded, h_out, w_out, rows, n_row_blocks)`` with the image
    extended so every row window the grid touches — including rows padded
    out to a whole number of ``rows_per_block`` blocks — is in range.
    Zero padding is exact for both fp32 and int8 accumulation."""
    _, h, wd, _ = x.shape
    if padding == "SAME":
        h_out = -(-h // stride)
        w_out = -(-wd // stride)
        pad_h = max((h_out - 1) * stride + kh - h, 0)
        pad_w = max((w_out - 1) * stride + kw - wd, 0)
        x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    elif padding == "VALID":
        h_out = (h - kh) // stride + 1
        w_out = (wd - kw) // stride + 1
    else:
        raise ValueError(padding)
    rows = min(rows_per_block, h_out)
    n_row_blocks = -(-h_out // rows)
    need_h = (n_row_blocks * rows - 1) * stride + kh
    need_w = (w_out - 1) * stride + kw
    h_pad, w_pad = x.shape[1], x.shape[2]
    if need_h > h_pad or need_w > w_pad:
        x = jnp.pad(x, ((0, 0), (0, max(need_h - h_pad, 0)),
                        (0, max(need_w - w_pad, 0)), (0, 0)))
    return x, h_out, w_out, rows, n_row_blocks


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int, w_out: int,
            stride: int, relu: bool, has_bias: bool):
    # x_ref block: [1, H_pad, W_pad, Cin] (whole image resident in VMEM —
    # space-use-case feature maps are small); we slice the KH rows feeding
    # this output row dynamically.
    cout = o_ref.shape[-1]
    row_start = pl.program_id(1) * stride
    rows = x_ref[0, pl.dslice(row_start, kh)]            # [KH, W_pad, Cin]
    acc = jnp.zeros((w_out, cout), jnp.float32)
    for r in range(kh):
        row = rows[r].astype(jnp.float32)                # [W_pad, Cin]
        for c in range(kw):
            # static strided slice: w_out taps starting at column c
            taps = jax.lax.slice(row, (c, 0),
                                 (c + (w_out - 1) * stride + 1, row.shape[1]),
                                 (stride, 1))            # [w_out, Cin]
            acc += jax.lax.dot_general(
                taps, w_ref[r, c].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[0, 0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "relu",
                                             "interpret"))
def conv2d(
    x: jax.Array,                   # [B, H, W, Cin]
    w: jax.Array,                   # [KH, KW, Cin, Cout]
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    interpret: bool = True,
) -> jax.Array:
    b, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    x, h_out, w_out, _, _ = _conv_geometry(x, kh, kw, stride, padding)
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((cout,), jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, w_out=w_out, stride=stride,
                          relu=relu, has_bias=has_bias),
        grid=(b, h_out),
        in_specs=[
            pl.BlockSpec((1, x.shape[1], x.shape[2], cin),
                         lambda bi, hi: (bi, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda bi, hi: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda bi, hi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, cout),
                               lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, cout), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, bias)
    return out


# ---------------------------------------------------------------------------
# INT8 variant — the DPU conv engine proper: int8 taps x int8 weights
# accumulated in int32 on the MXU, dequant + bias + ReLU fused into the
# epilogue. Same shift-and-matmul structure (no im2col patch matrix ever
# touches HBM); the grid blocks ``rows_per_block`` output rows per step so
# small feature maps don't drown in grid overhead.
# ---------------------------------------------------------------------------


def _kernel_int8(x_ref, w_ref, ws_ref, b_ref, o_ref, *, kh: int, kw: int,
                 w_out: int, stride: int, rows: int, x_scale: float,
                 act, requant_scale, has_bias: bool):
    # x_ref block: [1, H_pad, W_pad, Cin] int8 (whole image in VMEM);
    # o_ref block: [1, rows, W_out, Cout] f32 — or int8 when the fused
    # epilogue re-quantizes for the next layer (requant_scale set).
    cout = o_ref.shape[-1]
    cin = x_ref.shape[-1]
    base = pl.program_id(1) * rows * stride
    dequant = ws_ref[...] * jnp.float32(x_scale)         # [Cout]
    for rr in range(rows):
        row_start = base + rr * stride
        taps_rows = x_ref[0, pl.dslice(row_start, kh)]   # [KH, W_pad, Cin] i8
        acc = jnp.zeros((w_out, cout), jnp.int32)
        for r in range(kh):
            row = taps_rows[r]                           # [W_pad, Cin] int8
            for c in range(kw):
                taps = jax.lax.slice(
                    row, (c, 0), (c + (w_out - 1) * stride + 1, cin),
                    (stride, 1))                         # [w_out, Cin] int8
                acc += jax.lax.dot_general(
                    taps, w_ref[r, c],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
        out = acc.astype(jnp.float32) * dequant[None, :]
        if has_bias:
            out = out + b_ref[...][None, :]
        out = apply_epilogue(out, act, requant_scale)
        o_ref[0, rr] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "x_scale", "stride", "padding", "relu", "act", "requant_scale",
    "rows_per_block", "interpret"))
def conv2d_int8(
    x_q: jax.Array,                 # [B, H, W, Cin] int8
    w_q: jax.Array,                 # [KH, KW, Cin, Cout] int8
    w_scale: jax.Array,             # [Cout] f32 per-output-channel
    bias: Optional[jax.Array] = None,   # [Cout] f32
    *,
    x_scale: float = 1.0,           # static per-tensor activation scale
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    act: Optional[str] = None,      # 'relu' | 'sigmoid' epilogue
    requant_scale: Optional[float] = None,  # int8 output at this scale
    rows_per_block: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """Quantized conv: ``deq(conv_int32(x_q, w_q))`` with fused epilogue.

    ``x_scale`` is folded at plan time (PTQ calibration absmax / 127), so
    the whole layer is one kernel launch — no per-sample HBM im2col and no
    dynamic scale reduction on the critical path. With ``requant_scale``
    the epilogue re-quantizes the result to int8 for the next quantized
    layer (the graph compiler's producer->consumer fusion): the fp32
    activation never leaves the kernel.
    """
    act = normalize_act(relu, act)
    b, _, _, cin = x_q.shape
    kh, kw, _, cout = w_q.shape
    x_q, h_out, w_out, rows, n_row_blocks = _conv_geometry(
        x_q, kh, kw, stride, padding, rows_per_block)
    h_out_pad = n_row_blocks * rows
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((cout,), jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel_int8, kh=kh, kw=kw, w_out=w_out,
                          stride=stride, rows=rows, x_scale=float(x_scale),
                          act=act, requant_scale=requant_scale,
                          has_bias=has_bias),
        grid=(b, n_row_blocks),
        in_specs=[
            pl.BlockSpec((1, x_q.shape[1], x_q.shape[2], cin),
                         lambda bi, ri: (bi, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda bi, ri: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda bi, ri: (0,)),
            pl.BlockSpec((cout,), lambda bi, ri: (0,)),
        ],
        out_specs=pl.BlockSpec((1, rows, w_out, cout),
                               lambda bi, ri: (bi, ri, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out_pad, w_out, cout),
                                       out_dtype_for(requant_scale)),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, w_scale, bias)
    if h_out_pad != h_out:
        out = out[:, :h_out]
    return out
