"""Pallas TPU kernel: NHWC conv2d as shift-and-matmul (the DPU conv engine).

TPU adaptation of the DPU's convolution array: instead of a systolic
line-buffer (FPGA idiom), each grid step loads the KH input rows feeding
one output row into VMEM and accumulates KH*KW shifted [W_out, Cin] x
[Cin, Cout] matmuls on the MXU — im2col without ever materializing the
patch matrix in HBM. Bias + ReLU fuse into the epilogue.

Space-use-case shapes (<=128x256 imgs, <=64 channels) keep the whole row
set comfortably inside VMEM; the grid parallelizes over (batch, out-row).
Supports stride 1/2 and 'SAME'/'VALID' padding (host-side pre-pad).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int, w_out: int,
            stride: int, relu: bool, has_bias: bool):
    # x_ref block: [1, H_pad, W_pad, Cin] (whole image resident in VMEM —
    # space-use-case feature maps are small); we slice the KH rows feeding
    # this output row dynamically.
    cout = o_ref.shape[-1]
    row_start = pl.program_id(1) * stride
    rows = x_ref[0, pl.dslice(row_start, kh)]            # [KH, W_pad, Cin]
    acc = jnp.zeros((w_out, cout), jnp.float32)
    for r in range(kh):
        row = rows[r].astype(jnp.float32)                # [W_pad, Cin]
        for c in range(kw):
            # static strided slice: w_out taps starting at column c
            taps = jax.lax.slice(row, (c, 0),
                                 (c + (w_out - 1) * stride + 1, row.shape[1]),
                                 (stride, 1))            # [w_out, Cin]
            acc += jax.lax.dot_general(
                taps, w_ref[r, c].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[0, 0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "relu",
                                             "interpret"))
def conv2d(
    x: jax.Array,                   # [B, H, W, Cin]
    w: jax.Array,                   # [KH, KW, Cin, Cout]
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    interpret: bool = True,
) -> jax.Array:
    b, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    if padding == "SAME":
        h_out = -(-h // stride)
        w_out = -(-wd // stride)
        pad_h = max((h_out - 1) * stride + kh - h, 0)
        pad_w = max((w_out - 1) * stride + kw - wd, 0)
        x = jnp.pad(x, ((0, 0), (pad_h // 2, pad_h - pad_h // 2),
                        (pad_w // 2, pad_w - pad_w // 2), (0, 0)))
    elif padding == "VALID":
        h_out = (h - kh) // stride + 1
        w_out = (wd - kw) // stride + 1
    else:
        raise ValueError(padding)
    h_pad, w_pad = x.shape[1], x.shape[2]
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((cout,), jnp.float32)

    # make sure every block fits: extend the padded image so the last
    # block's row window is in range
    need_h = (h_out - 1) * stride + kh
    if need_h > h_pad:
        x = jnp.pad(x, ((0, 0), (0, need_h - h_pad), (0, 0), (0, 0)))
    need_w = (w_out - 1) * stride + kw
    if need_w > w_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, need_w - w_pad), (0, 0)))
        w_pad = need_w

    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, w_out=w_out, stride=stride,
                          relu=relu, has_bias=has_bias),
        grid=(b, h_out),
        in_specs=[
            pl.BlockSpec((1, x.shape[1], w_pad, cin),
                         lambda bi, hi: (bi, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda bi, hi: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda bi, hi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, cout),
                               lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, cout), jnp.float32),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, bias)
    return out
