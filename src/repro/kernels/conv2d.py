"""Pallas TPU kernel: NHWC conv2d as shift-and-matmul (the DPU conv engine).

TPU adaptation of the DPU's convolution array: instead of a systolic
line-buffer (FPGA idiom), each grid step loads the KH input rows feeding
one output row into VMEM and accumulates KH*KW shifted [W_out, Cin] x
[Cin, Cout] matmuls on the MXU — im2col without ever materializing the
patch matrix in HBM. Bias + ReLU fuse into the epilogue.

Space-use-case shapes (<=128x256 imgs, <=64 channels) keep the whole row
set comfortably inside VMEM; the grid parallelizes over (batch, out-row).
Supports stride 1/2 and 'SAME'/'VALID' padding (host-side pre-pad).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.epilogue import (apply_epilogue, normalize_act,
                                    out_dtype_for, pad_channel_params)


class ConvGeom(NamedTuple):
    """Plan-time conv pad geometry: everything the SAME/VALID staging and
    the grid blocking need, derived purely from static shapes — so a
    lowering computes it ONCE (cached) and steady-state serving never
    re-derives pad amounts for identically-shaped batches."""
    h_out: int
    w_out: int
    rows: int                       # output rows per grid step
    n_row_blocks: int
    pad_top: int
    pad_bottom: int                 # includes row-block coverage padding
    pad_left: int
    pad_right: int
    h_pad: int                      # padded input dims the kernel expects
    w_pad: int


@functools.lru_cache(maxsize=None)
def conv_geometry(h: int, wd: int, kh: int, kw: int, stride: int,
                  padding: str, rows_per_block: int = 1) -> ConvGeom:
    """Shared SAME/VALID geometry for the fp32 and int8 kernels: the
    image is extended so every row window the grid touches — including
    rows padded out to a whole number of ``rows_per_block`` blocks — is
    in range. Zero padding is exact for both fp32 and int8 accumulation.
    Pure function of static shapes, memoized."""
    if padding == "SAME":
        h_out = -(-h // stride)
        w_out = -(-wd // stride)
        pad_h = max((h_out - 1) * stride + kh - h, 0)
        pad_w = max((w_out - 1) * stride + kw - wd, 0)
        top, left = pad_h // 2, pad_w // 2
        bottom, right = pad_h - top, pad_w - left
    elif padding == "VALID":
        h_out = (h - kh) // stride + 1
        w_out = (wd - kw) // stride + 1
        top = bottom = left = right = 0
    else:
        raise ValueError(padding)
    rows = min(rows_per_block, h_out)
    n_row_blocks = -(-h_out // rows)
    need_h = (n_row_blocks * rows - 1) * stride + kh
    need_w = (w_out - 1) * stride + kw
    bottom += max(need_h - (h + top + bottom), 0)
    right += max(need_w - (wd + left + right), 0)
    return ConvGeom(h_out, w_out, rows, n_row_blocks, top, bottom, left,
                    right, h + top + bottom, wd + left + right)


def pad_input(x: jax.Array, g: ConvGeom) -> jax.Array:
    """Apply a plan-time :class:`ConvGeom` to one [B, H, W, C] batch —
    the single input-staging pad both the kernels and the prepacked
    plan path share."""
    if (g.pad_top, g.pad_bottom, g.pad_left, g.pad_right) == (0, 0, 0, 0):
        return x
    return jnp.pad(x, ((0, 0), (g.pad_top, g.pad_bottom),
                       (g.pad_left, g.pad_right), (0, 0)))


def _conv_geometry(x: jax.Array, kh: int, kw: int, stride: int,
                   padding: str, rows_per_block: int = 1):
    """Back-compat wrapper: ``(x_padded, h_out, w_out, rows,
    n_row_blocks)`` over the cached :func:`conv_geometry`."""
    g = conv_geometry(x.shape[1], x.shape[2], kh, kw, stride, padding,
                      rows_per_block)
    return pad_input(x, g), g.h_out, g.w_out, g.rows, g.n_row_blocks


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int, w_out: int,
            stride: int, relu: bool, has_bias: bool):
    # x_ref block: [1, H_pad, W_pad, Cin] (whole image resident in VMEM —
    # space-use-case feature maps are small); we slice the KH rows feeding
    # this output row dynamically.
    cout = o_ref.shape[-1]
    row_start = pl.program_id(1) * stride
    rows = x_ref[0, pl.dslice(row_start, kh)]            # [KH, W_pad, Cin]
    acc = jnp.zeros((w_out, cout), jnp.float32)
    for r in range(kh):
        row = rows[r].astype(jnp.float32)                # [W_pad, Cin]
        for c in range(kw):
            # static strided slice: w_out taps starting at column c
            taps = jax.lax.slice(row, (c, 0),
                                 (c + (w_out - 1) * stride + 1, row.shape[1]),
                                 (stride, 1))            # [w_out, Cin]
            acc += jax.lax.dot_general(
                taps, w_ref[r, c].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[0, 0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "relu",
                                             "interpret"))
def conv2d(
    x: jax.Array,                   # [B, H, W, Cin]
    w: jax.Array,                   # [KH, KW, Cin, Cout]
    bias: Optional[jax.Array] = None,
    *,
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    interpret: bool = True,
) -> jax.Array:
    b, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    x, h_out, w_out, _, _ = _conv_geometry(x, kh, kw, stride, padding)
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((cout,), jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, w_out=w_out, stride=stride,
                          relu=relu, has_bias=has_bias),
        grid=(b, h_out),
        in_specs=[
            pl.BlockSpec((1, x.shape[1], x.shape[2], cin),
                         lambda bi, hi: (bi, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, cout), lambda bi, hi: (0, 0, 0, 0)),
            pl.BlockSpec((cout,), lambda bi, hi: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, w_out, cout),
                               lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, cout), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, bias)
    return out


# ---------------------------------------------------------------------------
# INT8 variant — the DPU conv engine proper: int8 taps x int8 weights
# accumulated in int32 on the MXU, dequant + bias + ReLU fused into the
# epilogue. Same shift-and-matmul structure (no im2col patch matrix ever
# touches HBM); the grid blocks ``rows_per_block`` output rows per step so
# small feature maps don't drown in grid overhead.
# ---------------------------------------------------------------------------


def _kernel_int8(x_ref, w_ref, ws_ref, b_ref, o_ref, *, kh: int, kw: int,
                 w_out: int, stride: int, rows: int, x_scale: float,
                 act, requant_scale, has_bias: bool):
    # x_ref block: [1, H_pad, W_pad, Cin] int8 (whole image in VMEM);
    # o_ref block: [1, rows, W_out, Cout] f32 — or int8 when the fused
    # epilogue re-quantizes for the next layer (requant_scale set).
    cout = o_ref.shape[-1]
    cin = x_ref.shape[-1]
    base = pl.program_id(1) * rows * stride
    dequant = ws_ref[...] * jnp.float32(x_scale)         # [Cout]
    for rr in range(rows):
        row_start = base + rr * stride
        taps_rows = x_ref[0, pl.dslice(row_start, kh)]   # [KH, W_pad, Cin] i8
        acc = jnp.zeros((w_out, cout), jnp.int32)
        for r in range(kh):
            row = taps_rows[r]                           # [W_pad, Cin] int8
            for c in range(kw):
                taps = jax.lax.slice(
                    row, (c, 0), (c + (w_out - 1) * stride + 1, cin),
                    (stride, 1))                         # [w_out, Cin] int8
                acc += jax.lax.dot_general(
                    taps, w_ref[r, c],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
        out = acc.astype(jnp.float32) * dequant[None, :]
        if has_bias:
            out = out + b_ref[...][None, :]
        out = apply_epilogue(out, act, requant_scale)
        o_ref[0, rr] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "x_scale", "stride", "padding", "relu", "act", "requant_scale",
    "rows_per_block", "cout_per_block", "cout", "pre_padded", "in_hw",
    "interpret"))
def conv2d_int8(
    x_q: jax.Array,                 # [B, H, W, Cin] int8
    w_q: jax.Array,                 # [KH, KW, Cin, Cout(_pad)] int8
    w_scale: jax.Array,             # [Cout(_pad)] f32 per-output-channel
    bias: Optional[jax.Array] = None,   # [Cout(_pad)] f32
    *,
    x_scale: float = 1.0,           # static per-tensor activation scale
    stride: int = 1,
    padding: str = "SAME",
    relu: bool = False,
    act: Optional[str] = None,      # 'relu' | 'sigmoid' epilogue
    requant_scale: Optional[float] = None,  # int8 output at this scale
    rows_per_block: int = 8,
    cout_per_block: int = 0,        # 0 = no channel tiling (whole Cout)
    cout: Optional[int] = None,     # logical Cout when w arrives padded
    pre_padded: bool = False,       # x already staged per conv_geometry
    in_hw: Optional[Tuple[int, int]] = None,  # logical (H, W), pre_padded
    interpret: bool = True,
) -> jax.Array:
    """Quantized conv: ``deq(conv_int32(x_q, w_q))`` with fused epilogue.

    ``x_scale`` is folded at plan time (PTQ calibration absmax / 127), so
    the whole layer is one kernel launch — no per-sample HBM im2col and no
    dynamic scale reduction on the critical path. With ``requant_scale``
    the epilogue re-quantizes the result to int8 for the next quantized
    layer (the graph compiler's producer->consumer fusion): the fp32
    activation never leaves the kernel.

    Tiling/prepack hooks (DESIGN.md §11 — all bit-exact vs the default
    path, since channel blocks are independent and zero pad rows/channels
    are sliced off):

    * ``cout_per_block`` tiles the output-channel dim: the grid gains a
      channel-block axis and each step holds only a [KH, KW, Cin, bc]
      weight slice in VMEM. Cout is zero-padded up to whole blocks
      (neutral scale/bias on pad channels; prepacked callers arrive
      aligned, with the logical ``cout`` passed separately).
    * ``pre_padded`` skips the kernel's own input staging: the caller
      already applied :func:`conv_geometry`/:func:`pad_input` at plan
      time (the prepacked plans' staging step) and passes the logical
      ``in_hw`` so the geometry can be re-derived from the cache.
    """
    act = normalize_act(relu, act)
    b, _, _, cin = x_q.shape
    kh, kw, _, cout_pad = w_q.shape
    cout = cout_pad if cout is None else cout
    if pre_padded:
        if in_hw is None:
            raise ValueError("pre_padded=True needs in_hw=(H, W)")
        g = conv_geometry(in_hw[0], in_hw[1], kh, kw, stride, padding,
                          rows_per_block)
        if x_q.shape[1:3] != (g.h_pad, g.w_pad):
            raise ValueError(
                f"pre-padded input {x_q.shape} does not match geometry "
                f"({g.h_pad}, {g.w_pad})")
    else:
        g = conv_geometry(x_q.shape[1], x_q.shape[2], kh, kw, stride,
                          padding, rows_per_block)
        x_q = pad_input(x_q, g)
    h_out, w_out = g.h_out, g.w_out
    rows, n_row_blocks = g.rows, g.n_row_blocks
    h_out_pad = n_row_blocks * rows
    bc = cout_per_block or cout_pad
    if cout_pad % bc:
        pad_c = -(-cout_pad // bc) * bc - cout_pad
        w_q = jnp.pad(w_q, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
        w_scale, bias = pad_channel_params(w_scale, bias, pad_c)
        cout_pad += pad_c
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((cout_pad,), jnp.float32)

    kernel = functools.partial(
        _kernel_int8, kh=kh, kw=kw, w_out=w_out, stride=stride, rows=rows,
        x_scale=float(x_scale), act=act, requant_scale=requant_scale,
        has_bias=has_bias)
    out_sd = jax.ShapeDtypeStruct((b, h_out_pad, w_out, cout_pad),
                                  out_dtype_for(requant_scale))
    if bc == cout_pad:
        out = pl.pallas_call(
            kernel,
            grid=(b, n_row_blocks),
            in_specs=[
                pl.BlockSpec((1, x_q.shape[1], x_q.shape[2], cin),
                             lambda bi, ri: (bi, 0, 0, 0)),
                pl.BlockSpec((kh, kw, cin, cout_pad),
                             lambda bi, ri: (0, 0, 0, 0)),
                pl.BlockSpec((cout_pad,), lambda bi, ri: (0,)),
                pl.BlockSpec((cout_pad,), lambda bi, ri: (0,)),
            ],
            out_specs=pl.BlockSpec((1, rows, w_out, cout_pad),
                                   lambda bi, ri: (bi, ri, 0, 0)),
            out_shape=out_sd,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
            interpret=interpret,
        )(x_q, w_q, w_scale, bias)
    else:
        out = pl.pallas_call(
            kernel,
            grid=(b, n_row_blocks, cout_pad // bc),
            in_specs=[
                pl.BlockSpec((1, x_q.shape[1], x_q.shape[2], cin),
                             lambda bi, ri, ci: (bi, 0, 0, 0)),
                pl.BlockSpec((kh, kw, cin, bc),
                             lambda bi, ri, ci: (0, 0, 0, ci)),
                pl.BlockSpec((bc,), lambda bi, ri, ci: (ci,)),
                pl.BlockSpec((bc,), lambda bi, ri, ci: (ci,)),
            ],
            out_specs=pl.BlockSpec((1, rows, w_out, bc),
                                   lambda bi, ri, ci: (bi, ri, 0, ci)),
            out_shape=out_sd,
            compiler_params=CompilerParams(
                dimension_semantics=("parallel", "arbitrary", "arbitrary")),
            interpret=interpret,
        )(x_q, w_q, w_scale, bias)
    if h_out_pad != h_out or cout_pad != cout:
        out = out[:, :h_out, :, :cout]
    return out
