"""Pallas TPU kernel: INT8 x INT8 -> INT32 matmul with fused dequant epilogue.

This is the DPU analog (DESIGN.md §6): the AMD DPU's entire value
proposition is INT8 MACs with weights resident on-chip; on TPU the MXU
runs int8 x int8 -> int32 natively at 2x bf16 throughput, and "on-chip
residency" means the weight tile lives in VMEM across the K loop.

Tiling: grid (M/bm, N/bn, K/bk), K innermost ('arbitrary' semantics) so the
int32 VMEM accumulator carries across K steps; per-row activation scales
and per-output-channel weight scales + bias + ReLU fuse into the epilogue,
so quantized inference is ONE kernel per layer — the paper's observation
that accelerator speedup comes from avoiding per-layer round-trips
(cf. Fig 11: input staging dominating compute for small HLS models).

Block defaults are MXU-aligned (128x128); VMEM working set at defaults is
bm*bk + bk*bn (int8) + bm*bn (int32) = 16KB + 16KB + 64KB << 16MB VMEM.
Dims that don't divide the tile are zero-padded up to aligned tiles (exact
for integer matmul) rather than shrinking blocks to tiny divisors.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams
from repro.kernels.epilogue import (apply_epilogue, normalize_act,
                                    out_dtype_for)


def _kernel(x_ref, w_ref, xs_ref, ws_ref, b_ref, o_ref, acc_ref, *,
            n_k: int, act, requant_scale, has_bias: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        out = acc_ref[...].astype(jnp.float32)
        out = out * xs_ref[...][:, None] * ws_ref[...][None, :]
        if has_bias:
            out = out + b_ref[...][None, :]
        out = apply_epilogue(out, act, requant_scale)
        o_ref[...] = out.astype(o_ref.dtype)


def _aligned_block(dim: int, target: int) -> int:
    """MXU-aligned block size: full ``target`` tiles when the dim is big
    enough, otherwise the dim rounded up to a multiple of 8 sublanes.
    Never a tiny divisor — callers pad instead (zero padding is exact for
    integer matmul)."""
    if dim >= target:
        return target
    return -(-dim // 8) * 8


def heuristic_blocks(m: int, k: int, n: int,
                     bm: int = 128, bn: int = 128, bk: int = 128):
    """The default (pre-autotune) block choice for an [M, K] x [K, N]
    int8 matmul — THE definition the autotuner's default candidate and
    the kernel's untuned path share, so ``autotune=False`` reproduces
    these blocks bit-for-bit."""
    return (min(bm, _aligned_block(m, bm)),
            min(bn, _aligned_block(n, bn)),
            min(bk, _aligned_block(k, bk)))


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "relu", "act", "requant_scale",
                     "out_dtype", "prepacked", "n_out", "interpret"))
def int8_matmul(
    x_q: jax.Array,                 # [M, K] int8
    w_q: jax.Array,                 # [K, N] int8 (tile-aligned if prepacked)
    x_scale: jax.Array,             # [M] f32 per-row
    w_scale: jax.Array,             # [N] f32 per-output-channel
    bias: Optional[jax.Array] = None,   # [N] f32
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    relu: bool = False,
    act: Optional[str] = None,      # 'relu' | 'sigmoid' epilogue
    requant_scale: Optional[float] = None,  # int8 output at this scale
    out_dtype=jnp.float32,
    prepacked: bool = False,        # w/w_scale/bias arrive tile-aligned
    n_out: Optional[int] = None,    # logical N when prepacked
    interpret: bool = True,
) -> jax.Array:
    act = normalize_act(relu, act)
    out_dtype = out_dtype_for(requant_scale, out_dtype)
    m, k = x_q.shape
    k2, n = w_q.shape
    if prepacked:
        # the weight arena already padded w (zeros), w_scale (1.0) and
        # bias (0.0) out to whole (bk, bn) tiles at plan time — only the
        # per-call activation still needs staging. bk/bn are the packed
        # layout and must divide the packed dims exactly.
        kp, np_ = k2, n
        n = np_ if n_out is None else n_out
        assert kp % bk == 0 and np_ % bn == 0, (kp, np_, bk, bn)
        assert k <= kp, (k, kp)
        bm = min(bm, _aligned_block(m, bm))
        mp = -(-m // bm) * bm
        if (mp, kp) != (m, k):
            x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
            x_scale = jnp.pad(x_scale, (0, mp - m), constant_values=1.0)
    else:
        assert k == k2, (k, k2)
        bm, bn, bk = heuristic_blocks(m, k, n, bm, bn, bk)
        # pad every dim up to a whole number of aligned tiles; padded K
        # contributes exact zeros, padded M/N rows/cols are sliced below
        mp, kp, np_ = -(-m // bm) * bm, -(-k // bk) * bk, -(-n // bn) * bn
        if (mp, kp, np_) != (m, k, n):
            x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
            w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
            x_scale = jnp.pad(x_scale, (0, mp - m), constant_values=1.0)
            w_scale = jnp.pad(w_scale, (0, np_ - n), constant_values=1.0)
            if bias is not None:
                bias = jnp.pad(bias, (0, np_ - n))
    n_k = kp // bk
    has_bias = bias is not None
    if bias is None:
        bias = jnp.zeros((np_,), jnp.float32)

    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, act=act,
                          requant_scale=requant_scale, has_bias=has_bias),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, h: (i, h)),
            pl.BlockSpec((bk, bn), lambda i, j, h: (h, j)),
            pl.BlockSpec((bm,), lambda i, j, h: (i,)),
            pl.BlockSpec((bn,), lambda i, j, h: (j,)),
            pl.BlockSpec((bn,), lambda i, j, h: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, h: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale, bias)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out
