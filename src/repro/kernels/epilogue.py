"""Shared fused-epilogue math for the int8 kernels (DESIGN.md §10).

One definition of the post-accumulator tail both `int8_matmul` and
`conv2d_int8` apply inside their kernels, so the fused graph-compiler
path and the legacy per-node path can never drift numerically:

    int32 acc -> fp32 dequant -> (+bias) -> act -> (requantize to int8)

* ``act`` — 'relu' or 'sigmoid', computed on the fp32 dequantized value
  (the HLS idiom: the activation streams right after the MAC array).
* ``requant_scale`` — when set, the fp32 result is re-quantized to int8
  at this *static* scale in-register and the kernel's output dtype is
  int8: the next quantized layer consumes it directly, and the fp32
  intermediate never exists in HBM/DDR. The expression is bit-identical
  to the unfused consumer's ``clip(round(x / s))`` quantize step.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

ACTS = ("relu", "sigmoid")


def normalize_act(relu: bool, act: Optional[str]) -> Optional[str]:
    """Back-compat: the pre-pass kernels took ``relu: bool``; new call
    sites pass ``act``. Both set is an API misuse."""
    if act is not None:
        if relu:
            raise ValueError("pass either relu=True or act=..., not both")
        if act not in ACTS:
            raise ValueError(f"unsupported epilogue act {act!r}")
        return act
    return "relu" if relu else None


def out_dtype_for(requant_scale: Optional[float], default=jnp.float32):
    return jnp.int8 if requant_scale is not None else default


def pad_channel_params(w_scale: jax.Array, bias: Optional[jax.Array],
                       n_pad: int):
    """Extend per-output-channel dequant params to a tile-padded channel
    count: scale 1.0 and bias 0.0 on the padding channels. Neutral values
    keep the padded lanes' math finite and exact — their outputs are
    sliced off after the kernel. One definition shared by the prepacked
    weight arenas and the kernels' pad-on-the-fly channel tiling."""
    if n_pad == 0:
        return w_scale, bias
    w_scale = jnp.pad(w_scale, (0, n_pad), constant_values=1.0)
    if bias is not None:
        bias = jnp.pad(bias, (0, n_pad))
    return w_scale, bias


def apply_epilogue(out: jax.Array, act: Optional[str],
                   requant_scale: Optional[float]) -> jax.Array:
    """The fp32 tail after dequant+bias. ``out`` is fp32; returns fp32,
    or the int8-ranged fp32 values ready for an int8 cast when
    ``requant_scale`` is set (callers cast via their out ref dtype)."""
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "sigmoid":
        out = jax.nn.sigmoid(out)
    if requant_scale is not None:
        out = jnp.clip(jnp.round(out / jnp.float32(requant_scale)),
                       -127, 127)
    return out
