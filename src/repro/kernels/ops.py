"""Public jit'd kernel API.

``interpret`` defaults to True on CPU (this container) and False when a
real TPU backend is present, so the same call sites run emulated here and
compiled Mosaic on hardware.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import conv2d as _conv2d
from repro.kernels import flash_attention as _flash
from repro.kernels import int8_matmul as _int8mm
from repro.kernels import quantize as _quant
from repro.kernels import ssd as _ssd


@functools.lru_cache(None)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def int8_matmul(x_q, w_q, x_scale, w_scale, bias=None, *, relu=False,
                act=None, requant_scale=None, out_dtype=jnp.float32,
                prepacked=False, n_out=None, **tiles):
    return _int8mm.int8_matmul(x_q, w_q, x_scale, w_scale, bias, relu=relu,
                               act=act, requant_scale=requant_scale,
                               out_dtype=out_dtype, prepacked=prepacked,
                               n_out=n_out, interpret=_interp(),
                               **tiles)


def conv2d(x, w, bias=None, *, stride=1, padding="SAME", relu=False):
    return _conv2d.conv2d(x, w, bias, stride=stride, padding=padding,
                          relu=relu, interpret=_interp())


def conv2d_int8(x_q, w_q, w_scale, bias=None, *, x_scale=1.0, stride=1,
                padding="SAME", relu=False, act=None, requant_scale=None,
                rows_per_block=8, cout_per_block=0, cout=None,
                pre_padded=False, in_hw=None):
    return _conv2d.conv2d_int8(x_q, w_q, w_scale, bias, x_scale=x_scale,
                               stride=stride, padding=padding, relu=relu,
                               act=act, requant_scale=requant_scale,
                               rows_per_block=rows_per_block,
                               cout_per_block=cout_per_block, cout=cout,
                               pre_padded=pre_padded, in_hw=in_hw,
                               interpret=_interp())


def flash_attention(q, k, v, *, causal=True, bq=256, bk=256):
    return _flash.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                                  interpret=_interp())


def ssd(x, B_, C_, dt, A, init_state=None, *, chunk: int = 256):
    return _ssd.ssd(x, B_, C_, dt, A, init_state, chunk=chunk,
                    interpret=_interp())


def quantize(x, axis: Optional[int] = 0):
    return _quant.quantize(x, axis=axis, interpret=_interp())


def dequantize(q, scale, axis: Optional[int] = 0, dtype=jnp.float32):
    from repro.kernels.ref import dequantize_ref
    return dequantize_ref(q, scale, axis=axis, dtype=dtype)
