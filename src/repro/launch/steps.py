"""Canonical step functions: train_step / prefill_step / decode_step.

These are the exact callables the dry-run lowers and the launcher jits —
tests, benchmarks, and the 40-cell dry-run all exercise the same code.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import model as model_lib
from repro.nn.dims import Dims
from repro.nn.layers import cross_entropy
from repro.optim.adamw import AdamW, AdamWState
from repro.parallel.sharding import constrain


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


@dataclasses.dataclass(frozen=True)
class StepOptions:
    attn_impl: str = "chunked"
    remat: bool = True
    remat_policy: str = "nothing"      # 'nothing' | 'dots' (§Perf cell D)
    microbatch: Optional[int] = None   # accumulation chunks along batch


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ArchConfig, dims: Dims, opts: StepOptions):
    def loss_fn(params, batch: Dict[str, jax.Array]) -> jax.Array:
        inputs = batch["embeds"] if cfg.frontend == "embed" else batch["tokens"]
        logits = model_lib.forward(
            params, inputs, cfg, dims,
            mode="train", attn_impl=opts.attn_impl, remat=opts.remat,
            remat_policy=opts.remat_policy,
        )
        labels = batch["labels"]
        # padded vocab tail never receives probability mass from labels
        return cross_entropy(logits, labels, batch.get("valid"))
    return loss_fn


def make_train_step(cfg: ArchConfig, dims: Dims, optimizer: AdamW,
                    opts: StepOptions = StepOptions()):
    loss_fn = make_loss_fn(cfg, dims, opts)

    def grads_of(params, batch):
        if not opts.microbatch or opts.microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        n = opts.microbatch
        micro = jax.tree.map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)

        def acc(carry, mb):
            loss_a, g_a = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_a + loss / n,
                    jax.tree.map(lambda a, b: a + b / n, g_a, g)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss, grads), _ = jax.lax.scan(acc, zero, micro)
        return loss, grads

    def train_step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        params, opt, gnorm = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "step": opt.step.astype(jnp.float32)}
        return TrainState(params, opt), metrics

    return train_step


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ArchConfig, dims: Dims,
                      opts: StepOptions = StepOptions(),
                      s_max: Optional[int] = None):
    def prefill_step(params, batch):
        inputs = batch["embeds"] if cfg.frontend == "embed" else batch["tokens"]
        logits, cache = model_lib.forward(
            params, inputs, cfg, dims,
            mode="prefill", s_max=s_max, attn_impl=opts.attn_impl, remat=False,
        )
        # next-token logits only — callers sample from the last position
        return logits[:, -1, :], cache
    return prefill_step


def make_prefill_forward(cfg: ArchConfig, dims: Dims,
                         opts: StepOptions = StepOptions()):
    """Inference forward WITHOUT cache materialization — the prefill_32k
    dry-run cell (batch scoring / filtering workloads)."""
    def prefill_forward(params, batch):
        inputs = batch["embeds"] if cfg.frontend == "embed" else batch["tokens"]
        logits = model_lib.forward(
            params, inputs, cfg, dims,
            mode="train", attn_impl=opts.attn_impl, remat=False,
        )
        return logits[:, -1, :]
    return prefill_forward


def make_decode_step(cfg: ArchConfig, dims: Dims):
    def decode_step(params, cache, token_or_embed, pos):
        logits, cache = model_lib.decode(params, token_or_embed, cache, pos,
                                         cfg, dims)
        return logits[:, -1, :], cache
    return decode_step
