"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/executed before any other jax-touching import sets device
state — the first two lines force 512 host-platform devices so
``jax.make_mesh`` can build the production meshes.

Per cell we record:
  * memory_analysis (per-device bytes — proves it fits)
  * cost_analysis   (HLO FLOPs / bytes — feeds the roofline)
  * collective bytes parsed from the post-SPMD optimized HLO
into a JSON ledger (benchmarks/roofline.py and EXPERIMENTS.md read it).

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi     # 2-pod only
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402  (imports must follow the XLA_FLAGS lines)
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import all_archs, get_arch, shapes_for
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (abstract_train_state, input_specs,
                                shardings_for_cell)
from repro.launch.steps import (StepOptions, TrainState, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.nn import model as model_lib
from repro.nn.dims import compute_dims
from repro.optim.adamw import AdamW
from repro.parallel.sharding import use_mesh

LEDGER = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "benchmarks", "dryrun_ledger.json")

COLLECTIVE_RE = re.compile(
    r"=\s+([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)",
)

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# ring all-reduce moves ~2x the buffer; others ~1x
WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum wire bytes per collective kind from post-SPMD optimized HLO."""
    out: Dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        dtype, shape_s, kind = m.groups()
        if dtype not in DTYPE_BYTES:
            continue
        elems = 1
        if shape_s:
            for d in shape_s.split(","):
                elems *= int(d)
        b = elems * DTYPE_BYTES[dtype] * WIRE_FACTOR[kind]
        out[kind] = out.get(kind, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def apply_overrides(cfg, overrides: Dict[str, Any]):
    """Config surgery for perf iterations (e.g. {'moe_impl': 'a2a'})."""
    import dataclasses
    if not overrides:
        return cfg
    if "moe_impl" in overrides and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         ep_impl=overrides["moe_impl"]))
    if overrides.get("kv8") and cfg.attends:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    return cfg


def build_cell(arch_id: str, shape_name: str, mesh, opts: StepOptions,
               overrides: Dict[str, Any] = None):
    """Returns (fn, args_abstract, in_shardings, out_shardings)."""
    from repro.parallel.sharding import serving_rules
    cfg = apply_overrides(get_arch(arch_id), overrides or {})
    dims = compute_dims(cfg, tp=mesh.shape["model"])
    shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
    optimizer = AdamW(lr=1e-4)
    rules = serving_rules(mesh) if (overrides or {}).get("serving") else None
    sh = shardings_for_cell(cfg, dims, shape, mesh, optimizer, rules)
    scalar_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    if shape.kind == "train":
        fn = make_train_step(cfg, dims, optimizer, opts)
        params, opt = abstract_train_state(cfg, dims, optimizer)
        state = TrainState(params, opt)
        state_sh = TrainState(sh["params"], sh["opt"])
        args = (state, input_specs(cfg, dims, shape))
        in_sh = (state_sh, sh["inputs"])
        out_sh = (state_sh, None)
        donate = (0,)          # state buffers are reused in-place
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, dims, opts, s_max=shape.seq_len)
        params = model_lib.abstract_model_params(cfg, dims)
        args = (params, input_specs(cfg, dims, shape))
        cache_sh = shardings_for_cell(cfg, dims,
                                      _as_decode(shape), mesh, optimizer)["cache"]
        in_sh = (sh["params"], sh["inputs"])
        out_sh = (None, cache_sh)
        donate = ()
    else:  # decode
        base = make_decode_step(cfg, dims)
        params = model_lib.abstract_model_params(cfg, dims)
        cache = model_lib.abstract_cache(cfg, dims, shape.global_batch,
                                         shape.seq_len)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        p_sh = sh["params"]
        fn = base
        if (overrides or {}).get("quant") == "w8":
            # §Perf B1: int8 weight storage for the memory-bound decode
            from repro.core import lm_quant
            from repro.launch.specs import state_axes
            from repro.parallel.sharding import tree_shardings
            params = lm_quant.abstract_quantized(params)
            p_axes, _ = state_axes(cfg, dims)
            q_axes = lm_quant.quantized_axes(
                model_lib.abstract_model_params(cfg, dims), p_axes)
            p_sh = tree_shardings(params, q_axes, mesh, rules)

            def fn(qp, c, tok, pos):
                return base(lm_quant.dequantize_params(qp), c, tok, pos)
        args = (params, cache, input_specs(cfg, dims, shape)["token"],
                pos)
        in_sh = (p_sh, sh["cache"], sh["inputs"]["token"], scalar_sh)
        out_sh = (None, sh["cache"])
        donate = (1,)          # KV/SSM cache is updated in place
    return fn, args, in_sh, out_sh, donate


def _as_decode(shape):
    import dataclasses
    return dataclasses.replace(shape, kind="decode")


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             opts: StepOptions = StepOptions(),
             granularity: str = "full",
             overrides: Dict[str, Any] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: Dict[str, Any] = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "granularity": granularity,
    }
    if overrides:
        rec["overrides"] = dict(overrides)
    t0 = time.time()
    from repro.parallel.sharding import serving_rules
    rules = serving_rules(mesh) if (overrides or {}).get("serving") else None
    with use_mesh(mesh, rules):
        if granularity == "full":
            fn, args, in_sh, out_sh, donate = build_cell(
                arch_id, shape_name, mesh, opts, overrides)
        else:  # 'group' | 'tail' — scan-body probes for the roofline
            from repro.launch.group_probe import build_group_cell, build_tail_cell
            cfg = apply_overrides(get_arch(arch_id), overrides or {})
            dims = compute_dims(cfg, tp=mesh.shape["model"])
            shape = {s.name: s for s in shapes_for(cfg)}[shape_name]
            build = build_group_cell if granularity == "group" else build_tail_cell
            if granularity == "group":
                fn, args, in_sh, donate = build(
                    cfg, dims, shape, mesh,
                    attn_impl=opts.attn_impl, remat=opts.remat,
                    remat_policy=opts.remat_policy,
                    quant=(overrides or {}).get("quant"))
            else:
                fn, args, in_sh, donate = build(cfg, dims, shape, mesh)
            out_sh = None
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec.setdefault("memory", {})[k] = int(v)
        cost = compiled.cost_analysis()
        if cost:
            c = cost[0] if isinstance(cost, (list, tuple)) else cost
            rec["cost"] = {k: float(v) for k, v in c.items()
                           if isinstance(v, (int, float)) and
                           ("flops" in k or "bytes" in k or k == "utilization")}
        rec["collectives"] = collective_bytes(compiled.as_text())
    return rec


def load_ledger() -> Dict[str, Any]:
    path = os.path.abspath(LEDGER)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_ledger(ledger: Dict[str, Any]) -> None:
    path = os.path.abspath(LEDGER)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(ledger, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    ap.add_argument("--tag", default="baseline", help="ledger namespace")
    ap.add_argument("--granularity", default="full",
                    choices=["full", "group", "tail"],
                    help="'group'/'tail' lower ONE scan-body step — the "
                         "roofline's scan-correction probes")
    ap.add_argument("--moe-impl", default=None, choices=[None, "scatter", "a2a"],
                    help="override MoE dispatch (perf iteration A1)")
    ap.add_argument("--quant", default=None, choices=[None, "w8"],
                    help="int8 weight storage for decode cells (§Perf B1)")
    ap.add_argument("--serving", action="store_true",
                    help="serving sharding: replicate weights over data "
                         "(kills per-step FSDP gathers; §Perf B1')")
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache (§Perf B2/C2)")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"],
                    help="activation-checkpoint policy (§Perf cell D)")
    ap.add_argument("--microbatch", type=int, default=None,
                    help="gradient-accumulation chunks (§Perf cell E)")
    args = ap.parse_args()

    overrides = {}
    if args.moe_impl:
        overrides["moe_impl"] = args.moe_impl
    if args.quant:
        overrides["quant"] = args.quant
    if args.serving:
        overrides["serving"] = True
    if args.kv8:
        overrides["kv8"] = True

    ledger = load_ledger()
    failures = []
    archs = [args.arch] if args.arch else list(all_archs())
    gran = args.granularity
    tag = args.tag if gran == "full" else f"{args.tag}-{gran}"
    for arch_id in archs:
        cfg = get_arch(arch_id)
        if gran == "tail" and cfg.family != "hybrid":
            continue
        for shape in shapes_for(cfg):
            if args.shape and shape.name != args.shape:
                continue
            for mesh_kind in ("single", "multi"):
                if args.mesh and mesh_kind != args.mesh:
                    continue
                key = f"{tag}/{arch_id}/{shape.name}/{mesh_kind}"
                if key in ledger and not args.force \
                        and ledger[key].get("status") == "ok":
                    print(f"[skip] {key}")
                    continue
                print(f"[cell] {key} ...", flush=True)
                try:
                    rec = run_cell(arch_id, shape.name, mesh_kind,
                                   opts=StepOptions(
                                       remat_policy=args.remat_policy,
                                       microbatch=args.microbatch),
                                   granularity=gran, overrides=overrides)
                    rec["status"] = "ok"
                    print(f"  ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"coll={rec['collectives'].get('total', 0)/1e9:.2f}GB",
                          flush=True)
                except Exception as e:  # noqa: BLE001 — ledger records failures
                    rec = {"arch": arch_id, "shape": shape.name,
                           "mesh": mesh_kind, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    failures.append(key)
                    print(f"  FAIL {type(e).__name__}: {e}", flush=True)
                ledger[key] = rec
                save_ledger(ledger)
    print(f"\n{len(failures)} failures" if failures else "\nall cells ok")
    for f in failures:
        print("  ", f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
