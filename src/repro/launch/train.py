"""Training launcher.

Production shape: build the (pod, data, model) mesh, shard state with the
logical rules, run the jitted train step under the StepGuard (async
checkpoints, crash-resume, straggler detection). On this 1-CPU container
it runs reduced configs end-to-end; on a pod slice the SAME code runs the
full configs (the dry-run proves they compile at 512 chips).

Usage::

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs import SHAPES_BY_NAME, get_arch, reduced
from repro.data.pipeline import DataConfig, data_iterator, host_shard
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_axes, shardings_for_cell
from repro.launch.steps import StepOptions, TrainState, make_train_step
from repro.nn import model as model_lib
from repro.nn.dims import compute_dims
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.fault_tolerance import StepGuard, detect_stragglers
from repro.parallel.sharding import use_mesh


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    shape = SHAPES_BY_NAME[args.shape]

    mesh = None
    tp = 1
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        tp = mesh.shape["model"]
    dims = compute_dims(cfg, tp=tp)

    optimizer = AdamW(lr=cosine_schedule(args.lr, warmup=20,
                                         total=max(args.steps, 100)))
    opts = StepOptions(microbatch=args.microbatch)
    train_step = make_train_step(cfg, dims, optimizer, opts)

    key = jax.random.PRNGKey(0)
    b = args.batch or shape.global_batch
    s = args.seq or shape.seq_len

    def build_state():
        params = model_lib.init_params(cfg, dims, key)
        return TrainState(params, optimizer.init(params))

    step_fn = jax.jit(train_step, donate_argnums=(0,))
    data = data_iterator(cfg, dims, shape, DataConfig(),
                         batch_override=b, seq_override=s)

    ctx = use_mesh(mesh) if mesh is not None else _null_ctx()
    with ctx:
        state = build_state()
        start = 0
        if args.ckpt_dir:
            last = latest_step(args.ckpt_dir)
            if last is not None:
                print(f"[resume] restoring step {last} from {args.ckpt_dir}")
                state = restore(args.ckpt_dir, last, state)
                start = last
                data = data_iterator(cfg, dims, shape, DataConfig(),
                                     start_step=last,
                                     batch_override=b, seq_override=s)

        step_times = {}

        def on_metrics(step, metrics):
            if step % args.log_every == 0 or step == start + 1:
                loss = float(metrics["loss"])
                gn = float(metrics["grad_norm"])
                dt = step_times.get("last", 0.0)
                print(f"step {step:6d}  loss {loss:.4f}  gnorm {gn:.2f}  "
                      f"{dt*1e3:.0f} ms/step", flush=True)
            stragglers = detect_stragglers(
                {f"host{i}": step_times.get("last", 0.0)
                 for i in range(jax.process_count())})
            if stragglers:
                print(f"[straggler] {stragglers}")

        import os
        crash_at = int(os.environ.get("REPRO_CRASH_AT_STEP", "0")) or None
        steps_done = {"n": start}

        def timed_step(st, batch):
            if crash_at is not None and steps_done["n"] + 1 >= crash_at:
                # simulated node failure (examples/train_driver.py --crash-at);
                # the StepGuard commits the last good state before re-raising.
                raise RuntimeError(
                    f"simulated node failure at step {crash_at}")
            t0 = time.perf_counter()
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            st, m = step_fn(st, batch)
            jax.block_until_ready(m["loss"])
            step_times["last"] = time.perf_counter() - t0
            steps_done["n"] += 1
            return st, m

        if args.ckpt_dir:
            guard = StepGuard(AsyncCheckpointer(args.ckpt_dir),
                              save_every=args.save_every)
            state, end = guard.run(state, timed_step, data,
                                   args.steps, start_step=start,
                                   on_metrics=on_metrics)
        else:
            end = start
            for _ in range(args.steps):
                state, metrics = timed_step(state, next(data))
                end += 1
                on_metrics(end, metrics)
        print(f"[done] trained to step {end}")
    return 0


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    raise SystemExit(main())
