"""Serving launcher — the paper's on-board inference scenario.

Two modes:

* ``space``: serve one or more of the six space use-case models through
  the continuous-batching scheduler (dual-backend engine + precompiled
  batch ladder + deadline flushing), with each use case's selective-
  downlink predicate (the paper's motivating workload). ``--model``
  takes a comma list to co-serve several models from one process;
  requests arrive on a per-model Poisson trace at ``--rate`` req/s.
  ``--backend`` also takes a comma list (primary first) — under
  ``--power-budget WATTS`` dispatch becomes energy-aware: every batch
  must be admitted by the orbital power envelope (sustained watts over a
  sliding ``--window-s`` window, ``--burst-j`` allowance, optional
  ``--peak-w`` instantaneous cap) and falls back to the cheaper-power
  backends when the budget refuses the primary.
* ``lm``: autoregressive serving. Default (``--lm-compiled``) is the
  scheduler-native path (DESIGN.md §15): the decoder-block op graph
  compiles through the same Planned -> Lowered -> Compiled chain as the
  CNNs, prefill rides the compiled batch ladder, decode batches across
  in-flight requests at their static int8 KV-cache slots, and tokens
  stream with per-phase telemetry. ``--lm-legacy`` keeps the raw
  jit-function loop for an assigned LM architecture (reduced config on
  CPU; production configs go through the dry-run/pod path).

Usage::

    PYTHONPATH=src python -m repro.launch.serve \
        --model baseline_net,vae_encoder --backend flex --requests 64
    PYTHONPATH=src python -m repro.launch.serve \
        --model logistic_net --backend accel,cpu \
        --power-budget 3 --window-s 1 --clock modeled
    PYTHONPATH=src python -m repro.launch.serve --mode lm \
        --backend accel --requests 8 --tokens 6 --slots 4
    PYTHONPATH=src python -m repro.launch.serve --mode lm --lm-legacy \
        --arch tinyllama-1.1b --smoke --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import os

from repro.configs import get_arch, reduced
from repro.core import faults as faults_mod
from repro.core import radiation as radiation_mod
from repro.core.energy import PowerEnvelope
from repro.core.engine import Engine
from repro.core.scheduler import (BACKENDS, ContinuousBatchingScheduler,
                                  capped_ladder, poisson_arrivals)
from repro.core import inspector
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import SPACE_MODELS, synthetic_requests
from repro.nn import model as model_lib
from repro.nn.dims import compute_dims

# selective-downlink predicates per use case (the paper's decision layer)
KEEP_PREDICATES = {
    # MMS: keep only magnetosheath/magnetopause crossings (classes 2, 3)
    "baseline_net": lambda out: int(out["region"]) >= 2,
    "reduced_net": lambda out: int(out["region"]) >= 2,
    "logistic_net": lambda out: int(out["region"]) >= 2,
    # ESPERTA: keep if any of the six models warns
    "multi_esperta": lambda out: any(
        float(np.max(v)) > 0 for k, v in out.items() if k.startswith("warn")),
    # CNet: keep high predicted X-ray flux
    "cnet_plus_scalar": lambda out: float(np.max(list(out.values())[0])) > 0.0,
    # VAE: everything downlinks (it IS the compressed product)
    "vae_encoder": lambda out: True,
}


def serve_space(args) -> int:
    names = [n.strip() for n in args.model.split(",") if n.strip()]
    unknown = [n for n in names if n not in SPACE_MODELS]
    if unknown or not names:
        raise SystemExit(f"unknown model(s) {unknown}; choose from "
                         f"{', '.join(sorted(SPACE_MODELS))}")
    backends = tuple(b.strip() for b in args.backend.split(",") if b.strip())
    bad = [b for b in backends if b not in BACKENDS]
    if bad or not backends:
        raise SystemExit(f"unknown backend(s) {bad}; choose from "
                         f"{', '.join(BACKENDS)}")
    ladder = capped_ladder(args.batch)

    envelope = None
    if args.power_budget is not None or args.peak_w is not None:
        envelope = PowerEnvelope(
            sustained_w=(float("inf") if args.power_budget is None
                         else args.power_budget),
            peak_w=args.peak_w, burst_j=args.burst_j,
            window_s=args.window_s)
        print(f"[envelope] sustained={args.power_budget} W  "
              f"peak={args.peak_w} W  burst={args.burst_j} J  "
              f"window={args.window_s} s  clock={args.clock}")
    elif args.burst_j != 0.0 or args.window_s != 10.0:
        raise SystemExit("--burst-j/--window-s configure the power "
                         "envelope; pass --power-budget and/or --peak-w "
                         "to enable it")
    if (args.tuning_cache or args.autotune_measure) and not args.autotune:
        raise SystemExit("--tuning-cache/--autotune-measure configure the "
                         "plan-time autotuner; pass --autotune to enable it")
    sched = ContinuousBatchingScheduler(envelope=envelope, clock=args.clock,
                                        pipeline=args.pipeline,
                                        staging_buffers=args.staging_buffers)
    if args.pipeline:
        print(f"[pipeline] async ticket dispatch on, "
              f"{args.staging_buffers} staging buffer(s) per (model, rung)")
    rad_mode = args.radiation != "off"
    rad_flags = (args.base_upset_rate is not None
                 or args.saa_factor is not None
                 or args.protection != "none"
                 or args.checkpoint_cadence is not None)
    if rad_flags and not rad_mode:
        raise SystemExit("--base-upset-rate/--saa-factor/--protection/"
                         "--checkpoint-cadence configure the orbital "
                         "radiation model; pass --radiation orbit to "
                         "enable it")
    fault_mode = (args.fault_rate > 0.0 or args.self_test_period is not None
                  or rad_mode)
    if fault_mode and "accel" not in backends:
        raise SystemExit("--fault-rate/--self-test-period model SEUs in "
                         "the accel weight arenas; include 'accel' in "
                         "--backend")
    if fault_mode and args.recovery == "demote" and len(backends) < 2:
        raise SystemExit("--recovery demote quarantines the primary "
                         "backend; register a fallback (e.g. accel,cpu)")
    if (not fault_mode and (args.fault_seed != 0
                            or args.recovery != "repack")):
        raise SystemExit("--fault-seed/--recovery configure fault "
                         "injection; pass --fault-rate and/or "
                         "--self-test-period to enable it")

    trace = []
    canaries = {}
    for mi, name in enumerate(names):
        m = SPACE_MODELS[name]
        graph = m.build_graph()
        engine = Engine(graph, m.init_params(jax.random.PRNGKey(1)),
                        fuse=not args.no_fuse, autotune=args.autotune,
                        tuning_cache=args.tuning_cache,
                        autotune_measure=args.autotune_measure)
        print(inspector.inspect(graph).summary())

        reqs = synthetic_requests(m, args.requests, seed=mi)
        if "accel" in backends:
            print(f"[ptq] {name}: calibrating on 4 samples")
            engine.calibrate(reqs[:4])

        sched.register(name, engine, backend=backends, ladder=ladder,
                       keep_predicate=KEEP_PREDICATES.get(name),
                       warmup_sample=reqs[0] if reqs else None)
        canaries[name] = reqs[:1]
        trace += [(t, name, r) for t, r in
                  zip(poisson_arrivals(args.rate, args.requests, seed=mi),
                      reqs)]

    controller = None
    if fault_mode:
        horizon = max((t for t, _, _ in trace), default=0.0) + 1.0
        upsets: tuple = ()
        self_test = args.self_test_period
        if rad_mode:
            renv = radiation_mod.RadiationEnvironment(
                base_rate=(2.0 if args.base_upset_rate is None
                           else args.base_upset_rate),
                saa_factor=(40.0 if args.saa_factor is None
                            else args.saa_factor))
            upsets = renv.sample_upsets(args.fault_seed, horizon)
            if self_test is None:
                self_test = 0.05        # canary detection for 'none' mode
            print(f"[radiation] orbit model: base={renv.base_rate:g}/s  "
                  f"SAA x{renv.saa_factor:g} over "
                  f"{renv.saa_window[0]:.2f}-{renv.saa_window[1]:.2f} s  "
                  f"-> {len(upsets)} upset(s) sampled over {horizon:.2f} s"
                  f"  protection={args.protection}")
            if args.checkpoint_cadence is not None:
                # price one ledger checkpoint at the modeled save cost (a
                # state_dict .npz is small; dominated by the host write)
                plan = radiation_mod.optimize_cadence(
                    renv, horizon_s=horizon, checkpoint_cost_s=1e-3)
                print(f"[radiation] checkpoint cadence: T*="
                      f"{plan.cadence_s*1e3:.2f} ms "
                      f"({plan.n_checkpoints} checkpoints, expected "
                      f"replay+overhead {plan.expected_cost_s*1e3:.2f} ms "
                      f"over the horizon)")
        controller = faults_mod.FaultController(faults_mod.FaultConfig(
            seed=args.fault_seed, fault_rate=args.fault_rate,
            horizon_s=horizon if args.fault_rate > 0 else 0.0,
            self_test_period=self_test,
            recovery=args.recovery, upsets=upsets,
            protection=args.protection))
        sched.attach_faults(controller)
        for name in names:
            controller.arm(sched, name, canaries[name])
        print(f"[faults] armed {len(names)} model(s): rate="
              f"{args.fault_rate}/s  self-test period="
              f"{self_test} s  recovery={args.recovery}")

    if args.checkpoint and os.path.exists(args.checkpoint):
        # the watchdog-reboot path: a fresh process re-registers the same
        # models (reloading the pristine bitstream + weights), then
        # resumes the accepted-request ledger from the checkpoint.
        sched.load_state_dict(faults_mod.load_checkpoint(args.checkpoint))
        pending = sched.pending()
        done = {c.rid for c in sched.completions}
        print(f"[checkpoint] restored {args.checkpoint}: "
              f"{len(done)} completed, {pending} queued")
        trace = []                 # the checkpoint owns the accepted queue

    t0 = time.perf_counter()
    end = sched.serve_trace(trace)
    wall = time.perf_counter() - t0
    print(f"[serve] {len(trace)} requests over {len(names)} model(s)  "
          f"virtual={end:.3f} s  wall={wall:.3f} s")
    print(sched.summary())
    if controller is not None:
        rep = controller.report()
        print(f"[faults] injected={rep['n_injected']}  detected="
              f"{rep['n_detected']}  recovered={rep['n_recovered']}  "
              f"self-tests={rep['n_self_tests']}  overhead="
              f"{rep['overhead_energy_j']*1e3:.3f} mJ  max detection "
              f"latency={rep['max_detection_latency_s']*1e3:.2f} ms")
    if args.checkpoint:
        faults_mod.save_checkpoint(args.checkpoint, sched.state_dict())
        print(f"[checkpoint] saved {args.checkpoint}")
    return 0


def serve_lm_compiled(args) -> int:
    """The scheduler-native LM path (DESIGN.md §15): decoder-block op
    graph -> PTQ -> compiled prefill ladder + jitted decode rungs over
    static int8 KV slots -> LMScheduler token streaming."""
    from repro.core.lm import LMEngine
    from repro.core.scheduler import LMRequest, LMScheduler
    from repro.models import lm as lm_model

    backend = args.backend.split(",")[0].strip()
    cfg = lm_model.DEFAULT_CONFIG
    graph = lm_model.build_graph(cfg)
    params = lm_model.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(graph, params, autotune=args.autotune,
                    tuning_cache=args.tuning_cache if args.autotune
                    else None)
    if backend == "accel":
        calib = [lm_model.synthetic_input(k, cfg) for k in
                 jax.random.split(jax.random.PRNGKey(1), 8)]
        engine.calibrate(calib)
    lm = LMEngine(engine, backend=backend, n_slots=args.slots,
                  max_new_tokens=max(args.tokens, 1))
    print(lm.plan.summary())
    sched = LMScheduler(lm)
    rng = np.random.default_rng(7)
    for rid in range(args.requests):
        sched.submit(LMRequest(
            rid=rid,
            x=rng.normal(size=(cfg.seq_len, cfg.d_model)
                         ).astype(np.float32) * 0.5,
            max_new_tokens=max(args.tokens, 1)))
    comps = sched.run()
    print(sched.summary())
    sample = comps[0].tokens[:16] if comps else ()
    print(f"[lm] sample continuation: {list(sample)}")
    return 0 if len(comps) == args.requests else 1


def serve_lm(args) -> int:
    import dataclasses
    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    if args.kv8 and cfg.attends:
        cfg = dataclasses.replace(cfg, kv_quant=True)   # §Perf B2 int8 cache
    dims = compute_dims(cfg, tp=1)
    params = model_lib.init_params(cfg, dims, jax.random.PRNGKey(0))
    if args.w8:
        # §Perf B1: int8 weight storage, dequantized bf16 at use sites
        from repro.core import lm_quant
        params = lm_quant.dequantize_params(lm_quant.quantize_params(params))

    b, s = args.batch, args.prompt_len
    s_max = s + args.tokens
    prefill = jax.jit(make_prefill_step(cfg, dims, s_max=s_max))
    decode = jax.jit(make_decode_step(cfg, dims), donate_argnums=(1,))

    key = jax.random.PRNGKey(7)
    if cfg.frontend == "text":
        prompt = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        batch = {"tokens": prompt}
    else:
        batch = {"embeds": jax.random.normal(key, (b, s, dims.d_model),
                                             jnp.bfloat16)}

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_pre = time.perf_counter() - t0

    toks = jnp.argmax(logits, axis=-1)[:, None]
    out_tokens = [toks]
    t0 = time.perf_counter()
    for i in range(args.tokens):
        inp = toks if cfg.frontend == "text" else jax.random.normal(
            jax.random.fold_in(key, i), (b, 1, dims.d_model), jnp.bfloat16)
        logits, cache = decode(params, cache, inp, jnp.int32(s + i))
        toks = jnp.argmax(logits, axis=-1)[:, None]
        out_tokens.append(toks)
    jax.block_until_ready(toks)
    t_dec = time.perf_counter() - t0

    print(f"[lm] prefill {b}x{s}: {t_pre*1e3:.1f} ms  "
          f"({b*s/t_pre:.0f} tok/s)")
    print(f"[lm] decode {args.tokens} steps: {t_dec*1e3:.1f} ms  "
          f"({b*args.tokens/t_dec:.1f} tok/s)")
    sample = jnp.concatenate(out_tokens, axis=1)[0, :16]
    print(f"[lm] sample continuation: {list(np.asarray(sample))}")
    return 0


def trace_demo(args) -> int:
    """Jaxpr front-end demo (DESIGN.md §14): trace the depthwise-
    separable cloud-mask CNN — a model with no hand-built graph anywhere
    in models/ — and drive it trace -> inspect -> PTQ -> autotune ->
    scheduler serve."""
    from repro.frontend.demo import run_demo
    backends = tuple(b.strip() for b in args.backend.split(",") if b.strip())
    facts = run_demo(n_requests=args.requests, rate_hz=args.rate,
                     batch_top=args.batch, autotune=args.autotune,
                     backends=backends, verbose=True)
    print(f"[trace-demo] {facts['n_completed']}/{facts['n_requests']} "
          f"served, {facts['n_kept']} kept for downlink "
          f"({facts['mac_coverage']:.1%} of MACs on accel, "
          f"{facts['n_segments']} segments)")
    return 0 if facts["n_completed"] == facts["n_requests"] else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="space", choices=["space", "lm"])
    ap.add_argument("--trace-demo", action="store_true",
                    help="jaxpr front-end demo (DESIGN.md §14): trace "
                         "the depthwise-separable cloud-mask CNN (never "
                         "hand-built) and serve it end to end; honours "
                         "--requests/--rate/--batch/--backend/--autotune")
    ap.add_argument("--model", default="baseline_net",
                    help="comma list of space models to co-serve "
                         f"({', '.join(sorted(SPACE_MODELS))})")
    ap.add_argument("--backend", default="flex",
                    help="comma list of backends, primary first "
                         "(cpu, flex, accel); later entries are the "
                         "power-envelope fallbacks")
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per model")
    ap.add_argument("--batch", type=int, default=16,
                    help="top batch-ladder rung")
    ap.add_argument("--rate", type=float, default=256.0,
                    help="per-model Poisson arrival rate (req/s)")
    # orbital power envelope (space mode)
    ap.add_argument("--power-budget", type=float, default=None,
                    help="sustained power budget in W (enables "
                         "energy-aware dispatch)")
    ap.add_argument("--peak-w", type=float, default=None,
                    help="instantaneous power cap in W")
    ap.add_argument("--burst-j", type=float, default=0.0,
                    help="burst energy allowance in J per window")
    ap.add_argument("--window-s", type=float, default=10.0,
                    help="sliding accounting window in s")
    ap.add_argument("--clock", default="measured",
                    choices=["measured", "modeled"],
                    help="virtual-clock source: host wall time per batch "
                         "or the plan's modeled latency (deterministic)")
    ap.add_argument("--pipeline", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="async pipelined dispatch (DESIGN.md §12): "
                         "staging/compute/readback overlap across "
                         "batches; --no-pipeline reproduces the fully "
                         "synchronous path (identical dispatches and "
                         "outputs)")
    ap.add_argument("--staging-buffers", type=int, default=2,
                    help="host staging slots per (model, rung) = max "
                         "in-flight dispatches (2 = double buffering)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="skip the graph-compiler pass pipeline "
                         "(DESIGN.md §10) and serve the op-by-op plans")
    ap.add_argument("--autotune", action="store_true",
                    help="plan-time kernel tile search + prepacked "
                         "weight arenas (DESIGN.md §11); off = the "
                         "heuristic kernel blocks, bit-for-bit")
    ap.add_argument("--tuning-cache", default=None, metavar="PATH",
                    help="JSON tuning-cache path: warm caches skip all "
                         "candidate evaluations across processes")
    ap.add_argument("--autotune-measure", action="store_true",
                    help="refine the autotuner's top-K picks by "
                         "wall-clock measurement (measures the Pallas "
                         "interpreter on non-TPU hosts)")
    # degraded-mode fault injection + checkpointing (space mode; §13)
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="SEU injection rate in faults per virtual "
                         "second (Poisson, seeded); flips bits in the "
                         "accel prepacked weight arenas")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault schedule and flip targets")
    ap.add_argument("--self-test-period", type=float, default=None,
                    metavar="S",
                    help="run an in-band golden-canary self-test per "
                         "model every S virtual seconds (low-priority "
                         "scheduler work; detects silent corruption)")
    ap.add_argument("--recovery", default="repack",
                    choices=["repack", "demote"],
                    help="on canary mismatch: re-pack arenas from "
                         "pristine host weights, or quarantine the "
                         "primary backend (dispatch falls back) until a "
                         "delayed repair")
    # orbit-aware radiation environment (space mode; §16)
    ap.add_argument("--radiation", default="off", choices=["off", "orbit"],
                    help="orbit-aware upset model (DESIGN.md §16): sample "
                         "a typed single/MBU/control upset schedule from "
                         "the eclipse-phase + SAA rate trace (seeded by "
                         "--fault-seed) instead of / on top of the flat "
                         "--fault-rate Poisson storm")
    ap.add_argument("--base-upset-rate", type=float, default=None,
                    metavar="R",
                    help="GCR background upset rate in upsets per virtual "
                         "second (default 2.0)")
    ap.add_argument("--saa-factor", type=float, default=None, metavar="X",
                    help="South Atlantic Anomaly rate multiplier over the "
                         "orbit-relative SAA window (default 40)")
    ap.add_argument("--protection", default="none",
                    choices=["none", "ecc", "tmr"],
                    help="arena protection mode: canary-only detection, "
                         "SEC ECC per byte-interleaved domain (+12.5%% "
                         "footprint + scrub), or TMR (3x footprint, "
                         "upsets voted away)")
    ap.add_argument("--checkpoint-cadence", default=None, metavar="auto",
                    help="print the expected-replay-loss-optimal ledger "
                         "checkpoint cadence for the radiation "
                         "environment (pass 'auto')")
    ap.add_argument("--checkpoint", default=None, metavar="PATH",
                    help="scheduler-ledger checkpoint (.npz): restored "
                         "at startup if present (the watchdog-reboot "
                         "path — zero accepted requests lost), saved at "
                         "exit")
    # lm mode
    ap.add_argument("--lm-compiled", dest="lm_compiled", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="lm mode: serve the decoder-block op graph "
                         "through the compiled prefill/decode rung "
                         "ladder with int8 KV-cache slots (DESIGN.md "
                         "§15); --lm-legacy selects the raw jit loop")
    ap.add_argument("--lm-legacy", dest="lm_compiled",
                    action="store_false",
                    help="lm mode: the pre-§15 raw jit prefill/decode "
                         "loop over an --arch config")
    ap.add_argument("--slots", type=int, default=4,
                    help="lm mode: KV-cache slots (max in-flight "
                         "decode requests)")
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--kv8", action="store_true",
                    help="int8 KV cache (lm mode; §Perf B2)")
    ap.add_argument("--w8", action="store_true",
                    help="int8 PTQ weights (lm mode; §Perf B1)")
    args = ap.parse_args(argv)
    if args.trace_demo:
        return trace_demo(args)
    if args.mode == "space":
        return serve_space(args)
    if args.lm_compiled:
        return serve_lm_compiled(args)
    return serve_lm(args)


if __name__ == "__main__":
    raise SystemExit(main())
