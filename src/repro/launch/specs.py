"""ShapeDtypeStruct stand-ins for every model input / state.

The dry-run lowers against these — weak-type-correct, shardable, zero
device allocation. The same functions back the launcher's sharding setup,
so dry-run and real launch cannot drift.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.nn import model as model_lib
from repro.nn.dims import Dims
from repro.optim.adamw import AdamW
from repro.parallel.sharding import tree_shardings

# logical axes for batch fields
BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "embeds": ("batch", "seq", None),
}


def input_specs(cfg: ArchConfig, dims: Dims, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract model inputs for one (arch x shape) cell.

    train/prefill: the full batch. decode: one new token (or stub frame
    embedding) per sequence.
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        out: Dict[str, Any] = {
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.frontend == "text":
            out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        else:
            out["embeds"] = jax.ShapeDtypeStruct((b, s, dims.d_model),
                                                 jnp.bfloat16)
        return out
    # decode: single-token step against a seq_len-deep cache
    if cfg.frontend == "text":
        return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    return {"token": jax.ShapeDtypeStruct((b, 1, dims.d_model), jnp.bfloat16)}


def batch_axes(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Tuple]:
    specs = {}
    if shape.kind in ("train", "prefill"):
        specs["labels"] = BATCH_AXES["labels"]
        specs["tokens" if cfg.frontend == "text" else "embeds"] = (
            BATCH_AXES["tokens"] if cfg.frontend == "text" else BATCH_AXES["embeds"])
    else:
        specs["token"] = ("batch", None) if cfg.frontend == "text" \
            else ("batch", None, None)
    return specs


def abstract_train_state(cfg: ArchConfig, dims: Dims, optimizer: AdamW):
    params = model_lib.abstract_model_params(cfg, dims)
    return params, optimizer.abstract_init(params)


def state_axes(cfg: ArchConfig, dims: Dims):
    """Logical axes for params and optimizer state (state inherits params')."""
    p_axes = model_lib.param_axes(cfg, dims)
    opt_axes = {
        "step": (),
        "m": p_axes,
        "v": p_axes,
        "master": p_axes,
    }
    return p_axes, opt_axes


def shardings_for_cell(cfg: ArchConfig, dims: Dims, shape: ShapeSpec,
                       mesh, optimizer: AdamW, rules=None):
    """(in_shardings-ready pytrees) for the cell's step function."""
    from repro.optim.adamw import AdamWState

    p_axes, opt_axes = state_axes(cfg, dims)
    params_abs = model_lib.abstract_model_params(cfg, dims)
    p_shard = tree_shardings(params_abs, p_axes, mesh, rules)

    out: Dict[str, Any] = {"params": p_shard}
    if shape.kind == "train":
        opt_abs = optimizer.abstract_init(params_abs)
        m = tree_shardings(opt_abs.m, p_axes, mesh, rules)
        v = tree_shardings(opt_abs.v, p_axes, mesh, rules)
        w = tree_shardings(opt_abs.master, p_axes, mesh, rules)
        step_sh = tree_shardings(jax.ShapeDtypeStruct((), jnp.int32), (), mesh,
                                 rules)
        out["opt"] = AdamWState(step=step_sh, m=m, v=v, master=w)
    if shape.kind == "decode":
        cache_abs = model_lib.abstract_cache(cfg, dims, shape.global_batch,
                                             shape.seq_len)
        cache_ax = model_lib.cache_axes(cfg, dims, shape.global_batch,
                                        shape.seq_len)
        out["cache"] = tree_shardings(cache_abs, cache_ax, mesh, rules)
    inputs_abs = input_specs(cfg, dims, shape)
    in_ax = batch_axes(cfg, shape)
    out["inputs"] = {k: tree_shardings(v, in_ax[k], mesh, rules)
                     for k, v in inputs_abs.items()}
    return out
