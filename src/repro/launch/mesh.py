"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state, so tests and benches keep their 1-CPU world
unless a caller explicitly builds the mesh (the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh for unit tests (requires enough local devices)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def tp_degree(mesh) -> int:
    return mesh.shape["model"]


def dp_degree(mesh) -> int:
    d = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        d *= mesh.shape["pod"]
    return d
