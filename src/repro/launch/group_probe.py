"""Per-group dry-run probes — the scan-correction term for the roofline.

The models scan over stacked layer *groups* (``jax.lax.scan``), which keeps
HLO size O(1) in depth but makes XLA's ``cost_analysis()`` count the scan
body ONCE instead of ``n_groups`` times. The roofline would then undercount
FLOPs / bytes / collective traffic by ~the layer count.

Fix: lower ONE group application under the exact same mesh/shardings and
record its cost. benchmarks/roofline.py then reconstructs

    corrected = full_program + (n_groups - 1) * group
                (+ (n_tail - 1) * tail_block for the hybrid tail scan)

This is *measured* (lower+compile of the real block code), not an analytic
estimate — the same philosophy as the full-cell dry-run.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.nn import blocks
from repro.nn import model as model_lib
from repro.nn.dims import Dims
from repro.nn.params import abstract_params, build_axes
from repro.parallel.sharding import (current_rules, sharding_for,
                                     tree_shardings)


def _x_spec(b: int, s: int, d: int):
    return jax.ShapeDtypeStruct((b, s, d), jnp.bfloat16)


def _group_params(cfg: ArchConfig, dims: Dims):
    spec = model_lib._group_spec(cfg, dims)
    return abstract_params(spec), build_axes(spec)


def _shared_params(cfg: ArchConfig, dims: Dims):
    spec = blocks.dense_block_spec(cfg, dims)
    return abstract_params(spec), build_axes(spec)


def _fwd_once(cfg: ArchConfig, dims: Dims, attn_impl: str, want_cache: bool,
              s_max: int):
    """One group forward — hybrid groups need the shared block as an arg."""
    if cfg.family == "hybrid":
        p = cfg.hybrid_attn_period

        def f(gp, shared, x, positions):
            caches: Dict[str, Any] = {}
            ssm_caches = []
            for j in range(p):
                sub = jax.tree.map(lambda a: a[j], gp["ssm_subs"])
                if want_cache:
                    x, c = blocks.ssm_block(sub, x, cfg, dims, return_cache=True)
                    ssm_caches.append(c)
                else:
                    x = blocks.ssm_block(sub, x, cfg, dims)
            if want_cache:
                x, kv = blocks.dense_block(shared, x, cfg, dims, positions,
                                           attn_impl, return_cache=True,
                                           s_max=s_max)
                caches["ssm_subs"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                                  *ssm_caches)
                caches["attn"] = kv
                return x, caches
            x = blocks.dense_block(shared, x, cfg, dims, positions, attn_impl)
            return x, None
        return f, True

    def f(gp, x, positions):
        return model_lib._group_forward(gp, x, cfg, dims, positions, attn_impl,
                                        want_cache, s_max)
    return f, False


def build_group_cell(cfg: ArchConfig, dims: Dims, shape: ShapeSpec, mesh,
                     attn_impl: str = "chunked", remat: bool = True,
                     remat_policy: str = "nothing",
                     quant: str = None) -> Tuple[Any, tuple, tuple, tuple]:
    """(fn, abstract_args, in_shardings, donate) for ONE group step of the
    given cell kind — the exact block code the full model scans."""
    b, s = shape.global_batch, shape.seq_len
    gp_abs, gp_axes = _group_params(cfg, dims)
    dequant_gp = None
    if quant == "w8" and shape.kind == "decode":
        from repro.core import lm_quant
        gp_axes = lm_quant.quantized_axes(gp_abs, gp_axes)
        gp_abs = lm_quant.abstract_quantized(gp_abs)
        dequant_gp = lm_quant.dequantize_params
    gp_sh = tree_shardings(gp_abs, gp_axes, mesh, current_rules())
    x_sh = sharding_for((b, max(s, 1), dims.d_model),
                        ("batch", "seq", None), mesh, current_rules())
    pos_sh = sharding_for((b, max(s, 1)), ("batch", "seq"), mesh, current_rules())

    if shape.kind in ("train", "prefill"):
        x_abs = _x_spec(b, s, dims.d_model)
        pos_abs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        want_cache = shape.kind == "prefill"
        fwd, needs_shared = _fwd_once(cfg, dims, attn_impl, want_cache, s)

        if shape.kind == "prefill":
            if needs_shared:
                sh_abs, sh_axes = _shared_params(cfg, dims)
                sh_sh = tree_shardings(sh_abs, sh_axes, mesh, current_rules())
                return (fwd, (gp_abs, sh_abs, x_abs, pos_abs),
                        (gp_sh, sh_sh, x_sh, pos_sh), ())
            return fwd, (gp_abs, x_abs, pos_abs), (gp_sh, x_sh, pos_sh), ()

        # train: fwd + bwd through one group, remat-matched to the step fn
        if needs_shared:
            def y_of(gp, shared, x, positions):
                return fwd(gp, shared, x, positions)[0]
            step = y_of
            if remat:
                step = jax.checkpoint(
                    y_of, policy=model_lib.remat_policy_fn(remat_policy))

            def train_probe(gp, shared, x, positions, ct):
                y, vjp = jax.vjp(lambda g, sh, xx: step(g, sh, xx, positions),
                                 gp, shared, x)
                return (y, *vjp(ct))
            sh_abs, sh_axes = _shared_params(cfg, dims)
            sh_sh = tree_shardings(sh_abs, sh_axes, mesh, current_rules())
            ct_abs = _x_spec(b, s, dims.d_model)
            return (train_probe, (gp_abs, sh_abs, x_abs, pos_abs, ct_abs),
                    (gp_sh, sh_sh, x_sh, pos_sh, x_sh), ())

        def y_of(gp, x, positions):
            return fwd(gp, x, positions)[0]
        step = y_of
        if remat:
            step = jax.checkpoint(
                y_of, policy=model_lib.remat_policy_fn(remat_policy))

        def train_probe(gp, x, positions, ct):
            y, vjp = jax.vjp(lambda g, xx: step(g, xx, positions), gp, x)
            return (y, *vjp(ct))
        ct_abs = _x_spec(b, s, dims.d_model)
        return (train_probe, (gp_abs, x_abs, pos_abs, ct_abs),
                (gp_sh, x_sh, pos_sh, x_sh), ())

    # decode: one group decode step against this cell's cache depth
    gc_spec = model_lib.group_cache_spec(cfg, dims, b, s)
    gc_abs = abstract_params(gc_spec)
    gc_sh = tree_shardings(gc_abs, build_axes(gc_spec), mesh, current_rules())
    x_abs = _x_spec(b, 1, dims.d_model)
    x1_sh = sharding_for((b, 1, dims.d_model), ("batch", None, None), mesh,
                         current_rules())
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_sh = sharding_for((), (), mesh, current_rules())

    if cfg.family == "hybrid":
        sh_abs, sh_axes = _shared_params(cfg, dims)
        sh_sh = tree_shardings(sh_abs, sh_axes, mesh, current_rules())

        def decode_probe(gp, shared, gc, x, pos):
            if dequant_gp is not None:
                gp = dequant_gp(gp)
            return model_lib._group_decode(gp, gc, x, pos, cfg, dims, shared)
        return (decode_probe, (gp_abs, sh_abs, gc_abs, x_abs, pos_abs),
                (gp_sh, sh_sh, gc_sh, x1_sh, pos_sh), (2,))

    def decode_probe(gp, gc, x, pos):
        if dequant_gp is not None:
            gp = dequant_gp(gp)
        return model_lib._group_decode(gp, gc, x, pos, cfg, dims, None)
    return (decode_probe, (gp_abs, gc_abs, x_abs, pos_abs),
            (gp_sh, gc_sh, x1_sh, pos_sh), (1,))


def build_tail_cell(cfg: ArchConfig, dims: Dims, shape: ShapeSpec, mesh
                    ) -> Tuple[Any, tuple, tuple, tuple]:
    """One hybrid-tail ssm block (the tail scan is also counted once)."""
    assert cfg.family == "hybrid"
    b, s = shape.global_batch, shape.seq_len
    spec = blocks.ssm_block_spec(cfg, dims)
    lp_abs = abstract_params(spec)
    lp_sh = tree_shardings(lp_abs, build_axes(spec), mesh, current_rules())

    if shape.kind in ("train", "prefill"):
        x_abs = _x_spec(b, s, dims.d_model)
        x_sh = sharding_for((b, s, dims.d_model), ("batch", "seq", None), mesh,
                            current_rules())
        if shape.kind == "prefill":
            def f(lp, x):
                return blocks.ssm_block(lp, x, cfg, dims, return_cache=True)
            return f, (lp_abs, x_abs), (lp_sh, x_sh), ()

        def y_of(lp, x):
            return blocks.ssm_block(lp, x, cfg, dims)
        step = jax.checkpoint(y_of,
                              policy=jax.checkpoint_policies.nothing_saveable)

        def train_probe(lp, x, ct):
            y, vjp = jax.vjp(step, lp, x)
            return (y, *vjp(ct))
        return (train_probe, (lp_abs, x_abs, x_abs),
                (lp_sh, x_sh, x_sh), ())

    from repro.nn.ssm import ssm_cache_spec
    cs = ssm_cache_spec(b, cfg, dims)
    c_abs = abstract_params(cs)
    c_sh = tree_shardings(c_abs, build_axes(cs), mesh, current_rules())
    x_abs = _x_spec(b, 1, dims.d_model)
    x1_sh = sharding_for((b, 1, dims.d_model), ("batch", None, None), mesh,
                         current_rules())

    def decode_probe(lp, x, c):
        return blocks.ssm_block_decode(lp, x, c, cfg, dims)
    return decode_probe, (lp_abs, x_abs, c_abs), (lp_sh, x1_sh, c_sh), (2,)
