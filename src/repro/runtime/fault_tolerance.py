"""Fault tolerance & straggler mitigation for 1000+-node runs.

On a real multi-pod deployment the coordinator runs these policies; here
the mechanisms are implemented host-side (pure numpy/python, unit-tested)
and wired into the launcher:

* :class:`HeartbeatTable` — per-host liveness with configurable timeout;
  a missed deadline marks the host dead and triggers elastic re-mesh.
* :func:`detect_stragglers` — median-rule step-time outlier detection
  (the spot-checkable version of TPU runtime preemption signals).
* :func:`elastic_mesh_shape` — given surviving host count, the largest
  (pod, data, model) mesh reachable without resharding the model axis
  (TP degree is fixed by weight layout; we shed data-parallel rows).
* :class:`StepGuard` — wraps the train step with checkpoint-on-failure +
  resume bookkeeping; used by launch/train.py and the restart test.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Heartbeats
# ---------------------------------------------------------------------------


class HeartbeatTable:
    def __init__(self, hosts: Sequence[str], timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[str, float] = {h: now for h in hosts}
        self._dead: set = set()

    def beat(self, host: str) -> None:
        if host not in self._dead:
            self._last[host] = self._clock()

    def dead_hosts(self) -> List[str]:
        now = self._clock()
        for h, t in self._last.items():
            if h not in self._dead and now - t > self.timeout_s:
                self._dead.add(h)
        return sorted(self._dead)

    def alive_hosts(self) -> List[str]:
        dead = set(self.dead_hosts())
        return sorted(h for h in self._last if h not in dead)


# ---------------------------------------------------------------------------
# Stragglers
# ---------------------------------------------------------------------------


def detect_stragglers(step_times: Dict[str, float],
                      tolerance: float = 2.0) -> List[str]:
    """Hosts whose step time exceeds ``tolerance`` x median."""
    if len(step_times) < 3:
        return []
    med = float(np.median(list(step_times.values())))
    return sorted(h for h, t in step_times.items() if t > tolerance * med)


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------


def elastic_mesh_shape(alive_chips: int, model_degree: int,
                       pod_size: int = 256) -> Tuple[int, int, int]:
    """Largest (pods, data, model) using <= alive_chips, keeping TP fixed.

    TP (model) degree is pinned by the weight sharding already on the
    devices; data-parallel width is shed in whole rows, pods in whole pods.
    Returns (n_pods, data, model); raises if not even one TP group survives.
    """
    if alive_chips < model_degree:
        raise RuntimeError(
            f"only {alive_chips} chips alive; need >= {model_degree} for one "
            f"TP group — unrecoverable without re-sharding weights")
    rows_per_pod = pod_size // model_degree
    full_pods = alive_chips // pod_size
    if full_pods >= 2:
        return full_pods, rows_per_pod, model_degree
    data = min(alive_chips // model_degree, rows_per_pod)
    return 1, data, model_degree


def rebalance_batch(global_batch: int, old_data: int, new_data: int) -> int:
    """Keep per-replica batch constant when DP width shrinks (the standard
    elastic policy: global batch scales with surviving capacity)."""
    per = global_batch // old_data
    return per * new_data


# ---------------------------------------------------------------------------
# Step guard (checkpoint-on-failure / resume)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepGuard:
    """Run steps with periodic async checkpoints and crash-resume.

    ``save_every`` steps -> async checkpoint; on exception the guard
    synchronously commits the last good state before re-raising, so restart
    resumes at ``latest_step`` with at most ``save_every`` steps recomputed
    (and zero recomputed data — the pipeline is step-seeded).
    """

    checkpointer: "object"            # AsyncCheckpointer
    save_every: int = 100

    def run(self, state, step_fn, batches, n_steps: int, start_step: int = 0,
            on_metrics: Optional[Callable] = None):
        step = start_step
        try:
            for _ in range(n_steps):
                batch = next(batches)
                state, metrics = step_fn(state, batch)
                step += 1
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if step % self.save_every == 0:
                    self.checkpointer.save(step, state)
        except Exception:
            # best-effort durable state before dying
            self.checkpointer.wait()
            self.checkpointer.save(step, state)
            self.checkpointer.wait()
            raise
        self.checkpointer.wait()
        return state, step
