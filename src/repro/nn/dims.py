"""Runtime dimensions: config sizes padded for the tensor-parallel degree.

Head counts / vocab sizes from public configs are not always divisible by
the 16-way `model` mesh axis (yi-34b has 56 q heads, tinyllama 4 kv heads,
internvl2 a 92,553 vocab). We pad them up to the nearest multiple so every
TP-sharded dim splits evenly; the padding waste is accounted for in the
roofline's MODEL_FLOPS/HLO_FLOPs ratio rather than hidden.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.parallel.sharding import pad_to_multiple


@dataclasses.dataclass(frozen=True)
class Dims:
    tp: int                      # model-axis size the padding targets
    num_heads: int               # padded q heads
    num_kv_heads: int            # padded kv heads
    head_dim: int
    vocab: int                   # padded vocab
    d_model: int
    d_ff: int
    # ssm
    d_inner: int = 0
    ssm_heads: int = 0
    conv_dim: int = 0

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


def compute_dims(cfg: ArchConfig, tp: int = 1) -> Dims:
    heads = pad_to_multiple(cfg.num_heads, tp) if cfg.num_heads else 0
    kv = cfg.num_kv_heads
    if kv:
        kv = kv if kv % tp == 0 else pad_to_multiple(kv, tp)
        kv = min(kv, heads)
        # keep grouping integral: q heads must be a multiple of kv heads
        heads = pad_to_multiple(heads, kv)
    d_inner = ssm_heads = conv_dim = 0
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
        ssm_heads = d_inner // cfg.ssm.head_dim
        conv_dim = d_inner + 2 * cfg.ssm.state_dim
    return Dims(
        tp=tp,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=cfg.head_dim,
        vocab=pad_to_multiple(cfg.vocab_size, tp),
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
        d_inner=d_inner,
        ssm_heads=ssm_heads,
        conv_dim=conv_dim,
    )
