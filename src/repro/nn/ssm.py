"""Mamba2 (SSD — state-space duality) sequence mixer.

Train/prefill use the chunked SSD algorithm (arXiv:2405.21060): intra-chunk
terms are dense matmuls (MXU-friendly — this is the whole point of SSD),
inter-chunk terms are a short ``lax.scan`` over chunk states. Decode is the
O(1)-state recurrence, which is what makes the long_500k cell tractable.

Per head h (H heads, head_dim P, state N):
    state_t = exp(dt_t * A_h) * state_{t-1} + dt_t * B_t (x) x_t
    y_t     = C_t . state_t + D_h * x_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.dims import Dims
from repro.nn.params import ParamSpec
from repro.parallel.sharding import constrain

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def ssm_spec(cfg: ArchConfig, dims: Dims) -> dict:
    s = cfg.ssm
    d, di, h, n = dims.d_model, dims.d_inner, dims.ssm_heads, s.state_dim
    w = s.conv_width
    return {
        "w_z": ParamSpec((d, di), ("fsdp", "ffn")),
        "w_x": ParamSpec((d, di), ("fsdp", "ffn")),
        "w_B": ParamSpec((d, n), ("fsdp", None)),
        "w_C": ParamSpec((d, n), ("fsdp", None)),
        "w_dt": ParamSpec((d, h), ("fsdp", "ssm_heads")),
        "conv_x": ParamSpec((w, di), (None, "ffn"), scale=0.5),
        "conv_B": ParamSpec((w, n), (None, None), scale=0.5),
        "conv_C": ParamSpec((w, n), (None, None), scale=0.5),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "gate_norm": ParamSpec((di,), ("ffn",), init="ones"),
        "w_out": ParamSpec((di, d), ("ffn", "fsdp")),
    }


def ssm_cache_spec(batch: int, cfg: ArchConfig, dims: Dims,
                   dtype=jnp.bfloat16) -> dict:
    s = cfg.ssm
    return {
        # last (conv_width - 1) pre-activation inputs of x / B / C streams
        "conv_x": ParamSpec((batch, s.conv_width - 1, dims.d_inner),
                            ("batch", None, "ffn"), dtype=dtype),
        "conv_B": ParamSpec((batch, s.conv_width - 1, s.state_dim),
                            ("batch", None, None), dtype=dtype),
        "conv_C": ParamSpec((batch, s.conv_width - 1, s.state_dim),
                            ("batch", None, None), dtype=dtype),
        "state": ParamSpec((batch, dims.ssm_heads, s.head_dim, s.state_dim),
                           ("batch", "ssm_heads", None, None), dtype=jnp.float32),
    }


def init_ssm_cache(batch: int, cfg: ArchConfig, dims: Dims, dtype=jnp.bfloat16):
    from repro.nn.params import build_params
    return build_params(
        jax.tree.map(
            lambda p: ParamSpec(p.shape, p.logical, init="zeros", dtype=p.dtype),
            ssm_cache_spec(batch, cfg, dims, dtype),
            is_leaf=lambda x: isinstance(x, ParamSpec),
        ),
        jax.random.PRNGKey(0),
    )


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x [B, S, C], w [W, C] -> [B, S, C]."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):  # width is 4: unrolled shifts beat a gather here
        out = out + pad[:, i: i + x.shape[1], :] * w[i]
    return out


def _conv_step(cache: jax.Array, x_t: jax.Array, w: jax.Array):
    """One-token causal conv. cache [B, W-1, C], x_t [B, C]."""
    win = jnp.concatenate([cache, x_t[:, None, :]], axis=1)        # [B, W, C]
    y = jnp.einsum("bwc,wc->bc", win, w)
    return y, win[:, 1:, :]


def _dt_activation(dt_raw: jax.Array, dt_bias: jax.Array) -> jax.Array:
    return jax.nn.softplus(dt_raw.astype(jnp.float32) + dt_bias)


# ---------------------------------------------------------------------------
# Chunked SSD forward (train / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(
    x: jax.Array,        # [B, S, H, P]   (fp32-ish values; any float dtype)
    B_: jax.Array,       # [B, S, N]
    C_: jax.Array,       # [B, S, N]
    dt: jax.Array,       # [B, S, H]      (already softplus'd, fp32)
    A: jax.Array,        # [H]            (negative, fp32)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # [B, H, P, N]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B, S, H, P], final_state [B, H, P, N])."""
    b, s, h, p = x.shape
    n = B_.shape[-1]
    if s % chunk:
        # largest divisor of s <= chunk (keeps the algorithm exact for
        # odd lengths; production shapes are multiples of the chunk size)
        chunk = next(c for c in range(min(chunk, s), 0, -1) if s % c == 0)
    nc, q = s // chunk, chunk

    xr = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    Br = B_.astype(jnp.float32).reshape(b, nc, q, n)
    Cr = C_.astype(jnp.float32).reshape(b, nc, q, n)
    dtr = dt.reshape(b, nc, q, h)

    a = dtr * A                                   # [b,nc,q,h] log-decay
    cum = jnp.cumsum(a, axis=2)                   # inclusive cumsum

    # --- intra-chunk (dense, MXU-shaped) ---
    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # [b,nc,i,j,h]
    tri = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(li), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)              # [b,nc,i,j]
    M = scores[..., None] * L * dtr[:, :, None, :, :]           # [b,nc,i,j,h]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xr)

    # --- chunk boundary states ---
    suffix = jnp.exp(cum[:, :, -1:, :] - cum)                   # [b,nc,q,h]
    S_c = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", suffix * dtr, Br, xr)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # [b,nc,h]

    # --- inter-chunk recurrence ---
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        s_c, dec = inp                                          # [b,h,p,n], [b,h]
        new = carry * dec[:, :, None, None] + s_c
        return new, carry                                       # emit state BEFORE chunk

    final, prevs = jax.lax.scan(
        step, init_state,
        (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    # prevs: [nc, b, h, p, n] — state entering each chunk
    y_inter = jnp.einsum("bcin,cbhpn->bcihp", Cr, prevs) * jnp.exp(cum)[..., None]

    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype), final


# ---------------------------------------------------------------------------
# Layer forward
# ---------------------------------------------------------------------------


def ssm_mixer(
    params: dict,
    x: jax.Array,            # [B, S, D]
    cfg: ArchConfig,
    dims: Dims,
    return_cache: bool = False,
):
    """Full-sequence Mamba2 block core (no residual/norm — block adds those)."""
    s_cfg = cfg.ssm
    b, s, _ = x.shape
    h, p, n = dims.ssm_heads, s_cfg.head_dim, s_cfg.state_dim

    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, params["w_x"])
    Bs = jnp.einsum("bsd,dn->bsn", x, params["w_B"])
    Cs = jnp.einsum("bsd,dn->bsn", x, params["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"])

    xs_pre, Bs_pre, Cs_pre = xs, Bs, Cs       # pre-conv streams (cache tail)
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x"]).astype(jnp.float32))
    Bs = jax.nn.silu(_causal_conv(Bs, params["conv_B"]).astype(jnp.float32))
    Cs = jax.nn.silu(_causal_conv(Cs, params["conv_C"]).astype(jnp.float32))

    dt = _dt_activation(dt_raw, params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    xh = xs.reshape(b, s, h, p)
    xh = constrain(xh, "batch", None, "ssm_heads", None)
    # On TPU the Pallas SSD kernel keeps the [P,N] state and the [Q,Q]
    # decay masks VMEM-resident; the XLA path is the CPU/dry-run lowering.
    from repro.kernels import ops as kops
    if kops.on_tpu():
        y, final_state = kops.ssd(xh, Bs, Cs, dt, A,
                                  chunk=min(s_cfg.chunk_size, s))
    else:
        y, final_state = ssd_chunked(xh, Bs, Cs, dt, A,
                                     chunk=min(s_cfg.chunk_size, s))
    y = y + params["D"][None, None, :, None] * xh
    y = y.reshape(b, s, dims.d_inner).astype(x.dtype)

    # gated RMSNorm (mamba2's norm-before-out-proj)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps)).astype(x.dtype)
    y = y * params["gate_norm"] * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)

    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    if not return_cache:
        return out
    w = s_cfg.conv_width
    cache = {
        "conv_x": xs_pre[:, s - (w - 1):, :],
        "conv_B": Bs_pre[:, s - (w - 1):, :],
        "conv_C": Cs_pre[:, s - (w - 1):, :],
        "state": final_state,
    }
    return out, cache


def ssm_decode_step(
    params: dict,
    x: jax.Array,            # [B, 1, D]
    cache: dict,
    cfg: ArchConfig,
    dims: Dims,
):
    """O(1) recurrent step; returns (y [B,1,D], new cache)."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    h, p, n = dims.ssm_heads, s_cfg.head_dim, s_cfg.state_dim
    xt = x[:, 0, :]

    z = xt @ params["w_z"]
    xs = xt @ params["w_x"]
    Bs = xt @ params["w_B"]
    Cs = xt @ params["w_C"]
    dt_raw = xt @ params["w_dt"]

    xs, conv_x = _conv_step(cache["conv_x"], xs, params["conv_x"])
    Bs, conv_B = _conv_step(cache["conv_B"], Bs, params["conv_B"])
    Cs, conv_C = _conv_step(cache["conv_C"], Cs, params["conv_C"])
    xs = jax.nn.silu(xs.astype(jnp.float32))
    Bs = jax.nn.silu(Bs.astype(jnp.float32))
    Cs = jax.nn.silu(Cs.astype(jnp.float32))

    dt = _dt_activation(dt_raw, params["dt_bias"])              # [B, H]
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                                     # [B, H]

    xh = xs.reshape(b, h, p)
    state = cache["state"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bs, xh)
    y = jnp.einsum("bn,bhpn->bhp", Cs, state) + params["D"][None, :, None] * xh
    y = y.reshape(b, dims.d_inner)

    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.norm_eps))
    y = y * params["gate_norm"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = y.astype(x.dtype)

    out = (y @ params["w_out"])[:, None, :]
    new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
                 "state": state}
    return out, new_cache
