"""Declarative parameter specs.

Each module describes its parameters once as a nested dict whose leaves are
:class:`ParamSpec` (shape, logical axes, init style). From that single
source of truth we derive:

* concrete initialized params            (:func:`build_params`)
* the logical-axes pytree                 (:func:`build_axes`)
* abstract ShapeDtypeStruct params        (via ``jax.eval_shape``)

keeping values and shardings impossible to drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float = 0.02
    dtype: jnp.dtype = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"ParamSpec rank mismatch: {self.shape} vs {self.logical}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def stack(spec_tree, n: int):
    """Prefix every spec in the tree with a stacked 'layers' dim of size n."""
    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n, *s.shape), (None, *s.logical), s.init, s.scale, s.dtype)
    return jax.tree.map(one, spec_tree, is_leaf=is_spec)


def build_params(spec_tree, key: jax.Array):
    """Initialize a params pytree from a spec tree (deterministic per-leaf)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))

    def one(s: ParamSpec, k):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        if s.init == "normal":
            # fan-in scaled truncated normal keeps forward variance sane
            return (jax.random.truncated_normal(k, -2.0, 2.0, s.shape, jnp.float32)
                    * s.scale).astype(s.dtype)
        raise ValueError(f"unknown init {s.init!r}")

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])


def build_axes(spec_tree):
    """The logical-axes pytree matching :func:`build_params` output."""
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=is_spec)


def abstract_params(spec_tree):
    """ShapeDtypeStruct pytree — no device allocation (dry-run path)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=is_spec
    )


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))
