"""Basic transformer layers: RMSNorm, SwiGLU MLP, embeddings, RoPE."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.dims import Dims
from repro.nn.params import ParamSpec
from repro.parallel.sharding import constrain, sp_gather_seq, tp_proj_scatter

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


def norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_spec(dims: Dims) -> dict:
    d, f = dims.d_model, dims.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("fsdp", "ffn")),
        "w_up": ParamSpec((d, f), ("fsdp", "ffn")),
        "w_down": ParamSpec((f, d), ("ffn", "fsdp")),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    # SP gather once (explicit bf16), TP-sharded gate/up, explicit
    # reduce-scatter down-projection (§Perf A2+A3).
    x = sp_gather_seq(x)
    h = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, "batch", None, "ffn")
    return tp_proj_scatter(h, params["w_down"], "bsf,fd->bsd",
                           ("batch", None, "ffn"), w_sharded_dim=0)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_spec(dims: Dims, tie: bool) -> dict:
    out = {"embedding": ParamSpec((dims.vocab, dims.d_model), ("vocab", "fsdp"))}
    if not tie:
        out["lm_head"] = ParamSpec((dims.d_model, dims.vocab), ("fsdp", "vocab"))
    return out


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["embedding"], tokens, axis=0)


def lm_logits(params: dict, x: jax.Array) -> jax.Array:
    head = params.get("lm_head")
    if head is None:
        head = params["embedding"].T
    return jnp.einsum("bsd,dv->bsv", x, head)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim//2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, H, hd]; positions: [B, S] (absolute token positions)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, S, hd/2]
    cos = jnp.cos(angles)[..., None, :]                        # [B, S, 1, hd/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Cross entropy (fp32, label-gather formulation — never materializes
# a one-hot over the padded vocab)
# ---------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  valid: Optional[jax.Array] = None) -> jax.Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if valid is None:
        return jnp.mean(nll)
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
