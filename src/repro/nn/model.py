"""Model assembly: stacked layer groups scanned with ``jax.lax.scan``.

Scanning over a *stacked* parameter pytree keeps HLO size O(1) in depth —
a 60-layer 34B model lowers in seconds, which is what makes the 40-cell
multi-pod dry-run tractable on this host.

The scan unit is a *group* (see blocks.py):
  dense   — 1 dense block per group, L groups
  moe     — ``layer_period`` blocks per group (period-1 dense FFN + 1 MoE)
  ssm     — 1 Mamba2 block per group
  hybrid  — ``hybrid_attn_period`` ssm blocks + one application of the
            weight-tied shared attention block; tail layers scanned after

Caches mirror the group structure so prefill output == decode input.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import blocks
from repro.nn.attention import kv_cache_spec
from repro.nn.dims import Dims, compute_dims
from repro.nn.layers import cross_entropy, embed, embed_spec, lm_logits, norm_spec, rmsnorm
from repro.nn.params import (ParamSpec, abstract_params, build_axes,
                             build_params, stack)
from repro.nn.ssm import ssm_cache_spec
from repro.parallel.sharding import constrain

# Activation-checkpoint policies (§Perf cell D): 'nothing' = full remat
# (recompute everything in bwd — smallest live set, most recompute traffic);
# 'dots' = save matmul outputs (no dot recompute — less HBM traffic and
# FLOPs in bwd, bigger live set).
REMAT_POLICIES = {
    "nothing": lambda: jax.checkpoint_policies.nothing_saveable,
    "dots": lambda: jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def remat_policy_fn(name: str):
    return REMAT_POLICIES[name]()


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------


def group_layout(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(n_groups, blocks_per_group, n_tail_ssm_layers)."""
    if cfg.family == "dense":
        return cfg.num_layers, 1, 0
    if cfg.family == "moe":
        p = cfg.moe.layer_period
        assert cfg.num_layers % p == 0, "moe period must divide num_layers"
        return cfg.num_layers // p, p, 0
    if cfg.family == "ssm":
        return cfg.num_layers, 1, 0
    if cfg.family == "hybrid":
        p = cfg.hybrid_attn_period
        return cfg.num_layers // p, p, cfg.num_layers % p
    raise ValueError(cfg.family)


def _group_spec(cfg: ArchConfig, dims: Dims) -> dict:
    if cfg.family == "dense":
        return blocks.dense_block_spec(cfg, dims)
    if cfg.family == "moe":
        p = cfg.moe.layer_period
        spec: Dict[str, Any] = {"moe": blocks.moe_block_spec(cfg, dims)}
        if p > 1:
            spec["subs"] = stack(blocks.dense_block_spec(cfg, dims), p - 1)
        return spec
    if cfg.family == "ssm":
        return blocks.ssm_block_spec(cfg, dims)
    if cfg.family == "hybrid":
        p = cfg.hybrid_attn_period
        return {"ssm_subs": stack(blocks.ssm_block_spec(cfg, dims), p)}
    raise ValueError(cfg.family)


def model_spec(cfg: ArchConfig, dims: Dims) -> dict:
    n_groups, _, tail = group_layout(cfg)
    spec: Dict[str, Any] = {
        "embed": embed_spec(dims, cfg.tie_embeddings),
        "groups": stack(_group_spec(cfg, dims), n_groups),
        "final_norm": norm_spec(dims.d_model),
    }
    if cfg.family == "hybrid":
        spec["shared_attn"] = blocks.dense_block_spec(cfg, dims)
        if tail:
            spec["tail"] = stack(blocks.ssm_block_spec(cfg, dims), tail)
    return spec


def init_params(cfg: ArchConfig, dims: Dims, key: jax.Array):
    return build_params(model_spec(cfg, dims), key)


def param_axes(cfg: ArchConfig, dims: Dims):
    return build_axes(model_spec(cfg, dims))


def abstract_model_params(cfg: ArchConfig, dims: Dims):
    return abstract_params(model_spec(cfg, dims))


# ---------------------------------------------------------------------------
# Cache layout (mirrors groups; scanned together with params in decode)
# ---------------------------------------------------------------------------


def group_cache_spec(cfg: ArchConfig, dims: Dims, batch: int, s_max: int):
    """Cache spec for ONE scan group (the per-group dry-run probes this)."""
    _, p, _ = group_layout(cfg)
    if cfg.family == "dense":
        return kv_cache_spec(batch, s_max, dims, quant=cfg.kv_quant)
    if cfg.family == "moe":
        g = {"moe": kv_cache_spec(batch, s_max, dims, quant=cfg.kv_quant)}
        if p > 1:
            g["subs"] = stack(
                kv_cache_spec(batch, s_max, dims, quant=cfg.kv_quant), p - 1)
        return g
    if cfg.family == "ssm":
        return ssm_cache_spec(batch, cfg, dims)
    if cfg.family == "hybrid":
        return {
            "ssm_subs": stack(ssm_cache_spec(batch, cfg, dims), p),
            "attn": kv_cache_spec(batch, s_max, dims, quant=cfg.kv_quant),
        }
    raise ValueError(cfg.family)


def cache_spec(cfg: ArchConfig, dims: Dims, batch: int, s_max: int) -> dict:
    n_groups, p, tail = group_layout(cfg)
    g = group_cache_spec(cfg, dims, batch, s_max)
    spec: Dict[str, Any] = {"groups": stack(g, n_groups)}
    if cfg.family == "hybrid" and tail:
        spec["tail"] = stack(ssm_cache_spec(batch, cfg, dims), tail)
    return spec


def init_cache(cfg: ArchConfig, dims: Dims, batch: int, s_max: int):
    zeroed = jax.tree.map(
        lambda s: ParamSpec(s.shape, s.logical, init="zeros", dtype=s.dtype),
        cache_spec(cfg, dims, batch, s_max),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )
    return build_params(zeroed, jax.random.PRNGKey(0))


def abstract_cache(cfg: ArchConfig, dims: Dims, batch: int, s_max: int):
    return abstract_params(cache_spec(cfg, dims, batch, s_max))


def cache_axes(cfg: ArchConfig, dims: Dims, batch: int, s_max: int):
    return build_axes(cache_spec(cfg, dims, batch, s_max))


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(
    params: dict,
    inputs: jax.Array,              # tokens [B,S] int32 | embeds [B,S,D]
    cfg: ArchConfig,
    dims: Dims,
    *,
    mode: str = "train",            # train | prefill
    s_max: Optional[int] = None,    # cache capacity for prefill
    attn_impl: str = "chunked",
    remat: bool = True,
    remat_policy: str = "nothing",
):
    """Returns logits [B,S,V] (and the cache pytree when mode='prefill')."""
    want_cache = mode == "prefill"
    if cfg.frontend == "text":
        x = embed(params["embed"], inputs)
    else:
        x = inputs                                   # stub frontend: embeddings
    b, s = x.shape[:2]
    s_max = s_max or s
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(x, "batch", "seq", None)

    if cfg.family == "hybrid":
        x, group_caches = _hybrid_forward(params, x, cfg, dims, positions,
                                          attn_impl, want_cache, s_max, remat,
                                          remat_policy)
    else:
        def group_step(x, gp):
            return _group_forward(gp, x, cfg, dims, positions, attn_impl,
                                  want_cache, s_max)

        step = group_step
        if remat and not want_cache:
            step = jax.checkpoint(group_step, policy=remat_policy_fn(remat_policy))

        x, group_caches = jax.lax.scan(step, x, params["groups"])

    tail_caches = None
    if cfg.family == "hybrid" and "tail" in params:
        def tail_step(x, lp):
            if want_cache:
                x, c = blocks.ssm_block(lp, x, cfg, dims, return_cache=True)
                return x, c
            return blocks.ssm_block(lp, x, cfg, dims), None
        tstep = tail_step if want_cache or not remat else jax.checkpoint(
            tail_step, policy=remat_policy_fn(remat_policy))
        x, tail_caches = jax.lax.scan(tstep, x, params["tail"])

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x)
    logits = constrain(logits, "batch", "seq", None)
    if not want_cache:
        return logits
    cache = {"groups": group_caches}
    if tail_caches is not None:
        cache["tail"] = tail_caches
    return logits, cache


def _group_forward(gp, x, cfg, dims, positions, attn_impl, want_cache, s_max):
    """One scan step. Returns (x, caches-or-None)."""
    if cfg.family == "dense":
        if want_cache:
            x, kv = blocks.dense_block(gp, x, cfg, dims, positions, attn_impl,
                                       return_cache=True, s_max=s_max)
            return x, kv
        return blocks.dense_block(gp, x, cfg, dims, positions, attn_impl), None

    if cfg.family == "moe":
        caches: Dict[str, Any] = {}
        if "subs" in gp:
            sub_caches = []
            p_minus_1 = jax.tree.leaves(gp["subs"])[0].shape[0]
            for j in range(p_minus_1):
                sub = jax.tree.map(lambda a: a[j], gp["subs"])
                if want_cache:
                    x, kv = blocks.dense_block(sub, x, cfg, dims, positions,
                                               attn_impl, return_cache=True,
                                               s_max=s_max)
                    sub_caches.append(kv)
                else:
                    x = blocks.dense_block(sub, x, cfg, dims, positions, attn_impl)
            if want_cache:
                caches["subs"] = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *sub_caches)
        if want_cache:
            x, kv = blocks.moe_block(gp["moe"], x, cfg, dims, positions,
                                     attn_impl, return_cache=True, s_max=s_max)
            caches["moe"] = kv
            return x, caches
        return blocks.moe_block(gp["moe"], x, cfg, dims, positions, attn_impl), None

    if cfg.family == "ssm":
        if want_cache:
            return blocks.ssm_block(gp, x, cfg, dims, return_cache=True)
        return blocks.ssm_block(gp, x, cfg, dims), None

    raise ValueError(cfg.family)  # hybrid is handled by _hybrid_forward


# ---------------------------------------------------------------------------
# Decode (one token against a cache)
# ---------------------------------------------------------------------------


def decode(
    params: dict,
    token_or_embed: jax.Array,      # [B,1] int32 | [B,1,D]
    cache: dict,
    pos: jax.Array,                 # scalar int32 — write index
    cfg: ArchConfig,
    dims: Dims,
):
    """One decode step. Returns (logits [B,1,V], new cache)."""
    if cfg.frontend == "text":
        x = embed(params["embed"], token_or_embed)
    else:
        x = token_or_embed
    x = constrain(x, "batch", None, None)

    shared = params.get("shared_attn")

    def group_step(x, inp):
        gp, gc = inp
        return _group_decode(gp, gc, x, pos, cfg, dims, shared)

    x, new_group_caches = jax.lax.scan(group_step, x,
                                       (params["groups"], cache["groups"]))
    new_cache = {"groups": new_group_caches}

    if cfg.family == "hybrid" and "tail" in params:
        def tail_step(x, inp):
            lp, lc = inp
            return blocks.ssm_block_decode(lp, x, lc, cfg, dims)
        x, new_tail = jax.lax.scan(tail_step, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = new_tail

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_logits(params["embed"], x)
    return logits, new_cache


def _group_decode(gp, gc, x, pos, cfg, dims, shared):
    if cfg.family == "dense":
        return blocks.dense_block_decode(gp, x, gc, pos, cfg, dims)
    if cfg.family == "moe":
        new_c: Dict[str, Any] = {}
        if "subs" in gp:
            p_minus_1 = jax.tree.leaves(gp["subs"])[0].shape[0]
            subs_new = []
            for j in range(p_minus_1):
                sub = jax.tree.map(lambda a: a[j], gp["subs"])
                subc = jax.tree.map(lambda a: a[j], gc["subs"])
                x, c = blocks.dense_block_decode(sub, x, subc, pos, cfg, dims)
                subs_new.append(c)
            new_c["subs"] = jax.tree.map(lambda *xs: jnp.stack(xs), *subs_new)
        x, c = blocks.moe_block_decode(gp["moe"], x, gc["moe"], pos, cfg, dims)
        new_c["moe"] = c
        return x, new_c
    if cfg.family == "ssm":
        return blocks.ssm_block_decode(gp, x, gc, cfg, dims)
    if cfg.family == "hybrid":
        p = jax.tree.leaves(gp["ssm_subs"])[0].shape[0]
        ssm_new = []
        for j in range(p):
            sub = jax.tree.map(lambda a: a[j], gp["ssm_subs"])
            subc = jax.tree.map(lambda a: a[j], gc["ssm_subs"])
            x, c = blocks.ssm_block_decode(sub, x, subc, cfg, dims)
            ssm_new.append(c)
        x, attn_c = blocks.dense_block_decode(shared, x, gc["attn"], pos, cfg, dims)
        return x, {"ssm_subs": jax.tree.map(lambda *xs: jnp.stack(xs), *ssm_new),
                   "attn": attn_c}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Hybrid full-sequence forward needs the shared block in closure, so the
# generic scan above delegates here.
# ---------------------------------------------------------------------------


def _hybrid_forward(params, x, cfg, dims, positions, attn_impl, want_cache,
                    s_max, remat, remat_policy="nothing"):
    shared = params["shared_attn"]
    p = cfg.hybrid_attn_period

    def group_step(x, gp):
        caches: Dict[str, Any] = {}
        ssm_caches = []
        for j in range(p):
            sub = jax.tree.map(lambda a: a[j], gp["ssm_subs"])
            if want_cache:
                x_new, c = blocks.ssm_block(sub, x, cfg, dims, return_cache=True)
                x = x_new
                ssm_caches.append(c)
            else:
                x = blocks.ssm_block(sub, x, cfg, dims)
        if want_cache:
            x, kv = blocks.dense_block(shared, x, cfg, dims, positions,
                                       attn_impl, return_cache=True, s_max=s_max)
            caches["ssm_subs"] = jax.tree.map(lambda *xs: jnp.stack(xs),
                                              *ssm_caches)
            caches["attn"] = kv
            return x, caches
        x = blocks.dense_block(shared, x, cfg, dims, positions, attn_impl)
        return x, None

    step = group_step
    if remat and not want_cache:
        step = jax.checkpoint(group_step, policy=remat_policy_fn(remat_policy))
    return jax.lax.scan(step, x, params["groups"])
