"""Mixture-of-experts FFN (llama4-style top-1 routing + shared expert).

Dispatch is scatter-based: tokens are written into a per-expert capacity
buffer ``[E, C, D]`` (overflow dropped, standard capacity-factor semantics),
expert SwiGLU runs as one batched einsum over the buffer, and results are
gathered back. The buffer is the *only* E-indexed activation, sharded
``('expert' -> model, 'expert_cap' -> data)``, so expert weights reach
256-way sharding on the production mesh (maverick's 128 x 3 x 5120 x 8192
routed params would not fit 16-way).

The router (data-dependent top-k + scatter) is a *flexible-path* op in the
paper's operator-coverage sense — see core/inspector.py; the expert matmuls
themselves are accelerator ops.

NB: capacity-based dispatch couples sequences within a global batch — a
routing change in one row can evict another row's token from a full expert
buffer (overflow is dropped to the residual). This is the standard
Switch/GShard semantics; causality holds *within* each sequence.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from repro.parallel.sharding import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.nn.dims import Dims
from repro.nn.params import ParamSpec
from repro.parallel.sharding import constrain, current_mesh, current_rules, spec_for


def moe_spec(cfg: ArchConfig, dims: Dims) -> dict:
    m = cfg.moe
    d, f, e = dims.d_model, dims.d_ff, m.num_experts
    # a2a dispatch needs F-complete expert weights per model shard (tokens
    # a2a'd to the shard contract the full F); scatter dispatch second-level
    # shards F over the data axis.
    ffn_axis = None if m.ep_impl == "a2a" else "expert_ffn"
    spec = {
        "router": ParamSpec((d, e), ("fsdp", None), scale=0.006),
        "w_gate": ParamSpec((e, d, f), ("expert", None, ffn_axis)),
        "w_up": ParamSpec((e, d, f), ("expert", None, ffn_axis)),
        "w_down": ParamSpec((e, f, d), ("expert", ffn_axis, None)),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        spec["shared"] = {
            "w_gate": ParamSpec((d, fs), ("fsdp", "ffn")),
            "w_up": ParamSpec((d, fs), ("fsdp", "ffn")),
            "w_down": ParamSpec((fs, d), ("ffn", "fsdp")),
        }
    return spec


def _capacity(tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    cap = int(tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, (cap + 7) // 8 * 8)


def moe_ffn(params: dict, x: jax.Array, cfg: ArchConfig, dims: Dims) -> jax.Array:
    """x: [B, S, D] -> [B, S, D]. Top-1 routed + shared expert."""
    mesh = current_mesh()
    if (cfg.moe.ep_impl == "a2a" and mesh is not None
            and "model" in mesh.axis_names
            and cfg.moe.num_experts % mesh.shape["model"] == 0):
        y = _moe_routed_a2a(params, x, cfg, mesh)
        return y + _shared_expert(params, x, cfg)
    return _moe_ffn_scatter(params, x, cfg, dims)


def _shared_expert(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if not cfg.moe.num_shared_experts:
        return jnp.zeros_like(x)
    sp = params["shared"]
    hg = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
    hu = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
    hs = jax.nn.silu(hg.astype(jnp.float32)).astype(x.dtype) * hu
    return jnp.einsum("bsf,fd->bsd", hs, sp["w_down"])


def _moe_routed_a2a(params: dict, x: jax.Array, cfg: ArchConfig, mesh
                    ) -> jax.Array:
    """Expert parallelism with explicit all_to_all over the 'model' axis.

    §Perf iteration A1. Tokens move (2 x T_local x D bf16 per layer over
    the EP axis) instead of expert capacity buffers being all-reduced —
    the baseline scatter dispatch measured ~114 GB/device/layer of
    all-reduce on llama4-scout prefill_32k; this moves ~0.1 GB.

    Per-device plan (inside shard_map):
      1. route local tokens (router replicated — 160 KB),
      2. pack per-destination-shard send buffers [tp, cap, D] by cumsum
         position (overflow past per-pair capacity dropped, standard
         capacity-factor semantics applied per (src, dst) pair),
      3. all_to_all tokens + local-expert indices,
      4. per-local-expert capacity scatter (LOCAL — no collectives),
         batched expert SwiGLU,
      5. all_to_all results back, unpack to token order, gate at source.
    """
    m = cfg.moe
    tp = mesh.shape["model"]
    e_per = m.num_experts // tp
    rules = current_rules()
    x_spec = spec_for(x.shape, ("batch", "seq", None), mesh, rules)
    wg = params["w_gate"]
    w_spec = spec_for(wg.shape, ("expert", None, None), mesh, rules)
    wd_spec = spec_for(params["w_down"].shape, ("expert", None, None), mesh,
                       rules)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(x_spec, P(), w_spec, w_spec, wd_spec),
        out_specs=x_spec, check_vma=False)
    def routed(x_blk, router, w_gate, w_up, w_down):
        bl, sl, d = x_blk.shape
        tl = bl * sl
        xf = x_blk.reshape(tl, d)
        logits = (xf @ router).astype(jnp.float32)              # [tl, E]
        eidx = jnp.argmax(logits, axis=-1)                      # global expert
        gate = jax.nn.sigmoid(jnp.max(logits, axis=-1))
        dest = eidx // e_per                                    # model shard
        e_loc = (eidx % e_per).astype(jnp.int32)

        cap = max(8, -(-int(tl * m.top_k * m.capacity_factor) // tp) // 8 * 8)
        dest_1h = jax.nn.one_hot(dest, tp, dtype=jnp.int32)     # [tl, tp]
        pos = jnp.take_along_axis(jnp.cumsum(dest_1h, axis=0) - 1,
                                  dest[:, None], axis=1)[:, 0]
        keep = pos < cap
        pos_c = jnp.where(keep, pos, cap - 1)

        send = jnp.zeros((tp, cap, d), x_blk.dtype)
        send = send.at[dest, pos_c].add(
            jnp.where(keep[:, None], xf, 0).astype(x_blk.dtype))
        send_e = jnp.full((tp, cap), e_per, jnp.int32)          # pad -> dummy
        send_e = send_e.at[dest, pos_c].min(
            jnp.where(keep, e_loc, e_per).astype(jnp.int32))

        recv = jax.lax.all_to_all(send, "model", 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, "model", 0, 0, tiled=False)
        rt = tp * cap
        tok_in = recv.reshape(rt, d)
        e_in = recv_e.reshape(rt)

        if e_per == 1:
            valid = (e_in == 0)[:, None].astype(tok_in.dtype)
            h = (tok_in * valid) @ w_gate[0]
            u = (tok_in * valid) @ w_up[0]
            h = jax.nn.silu(h.astype(jnp.float32)).astype(tok_in.dtype) * u
            y_r = h @ w_down[0]
        else:
            # LOCAL capacity scatter over my e_per experts (+1 dummy slot)
            cap2 = max(8, -(-rt // e_per) // 8 * 8)
            oh = jax.nn.one_hot(e_in, e_per + 1, dtype=jnp.int32)
            pos2 = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - 1,
                                       e_in[:, None], axis=1)[:, 0]
            keep2 = (pos2 < cap2) & (e_in < e_per)
            pos2_c = jnp.where(keep2, pos2, cap2 - 1)
            e_c = jnp.where(keep2, e_in, 0)
            buf = jnp.zeros((e_per, cap2, d), tok_in.dtype)
            buf = buf.at[e_c, pos2_c].add(
                jnp.where(keep2[:, None], tok_in, 0).astype(tok_in.dtype))
            h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
            u = jnp.einsum("ecd,edf->ecf", buf, w_up)
            h = jax.nn.silu(h.astype(jnp.float32)).astype(buf.dtype) * u
            out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)
            y_r = out_buf[e_c, pos2_c] * keep2[:, None].astype(out_buf.dtype)

        y_back = jax.lax.all_to_all(y_r.reshape(tp, cap, d), "model", 0, 0,
                                    tiled=False)
        y_tok = y_back[dest, pos_c]                             # [tl, D]
        y_tok = y_tok * (keep.astype(jnp.float32) * gate
                         )[:, None].astype(y_tok.dtype)
        return y_tok.reshape(bl, sl, d)

    return routed(x, params["router"], params["w_gate"], params["w_up"],
                  params["w_down"])


def _moe_ffn_scatter(params: dict, x: jax.Array, cfg: ArchConfig,
                     dims: Dims) -> jax.Array:
    """Baseline: sharded capacity-buffer scatter (XLA SPMD dispatch)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e = m.num_experts
    cap = _capacity(t, cfg)

    xf = x.reshape(t, d)
    logits = (xf @ params["router"]).astype(jnp.float32)        # [T, E]
    # llama4 routes with sigmoid gates on the top-1 expert
    eidx = jnp.argmax(logits, axis=-1)                          # [T]
    gate = jax.nn.sigmoid(jnp.max(logits, axis=-1))             # [T]

    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)           # [T, E]
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1,
                              eidx[:, None], axis=1)[:, 0]      # [T]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[eidx, pos_c].add(jnp.where(keep[:, None], xf, 0))
    buf = constrain(buf, "expert", "expert_cap", None)

    h = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    h = constrain(h, "expert", "expert_cap", "expert_ffn")
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = constrain(out_buf, "expert", "expert_cap", None)

    y = out_buf[eidx, pos_c]                                    # [T, D]
    y = y * (keep.astype(jnp.float32) * gate)[:, None].astype(x.dtype)
    y = y.reshape(b, s, d)

    if m.num_shared_experts:
        sp = params["shared"]
        hg = jnp.einsum("bsd,df->bsf", x, sp["w_gate"])
        hu = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        hs = jax.nn.silu(hg.astype(jnp.float32)).astype(x.dtype) * hu
        y = y + jnp.einsum("bsf,fd->bsd", hs, sp["w_down"])
    return y


def aux_load_balance_loss(logits: jax.Array, eidx: jax.Array, e: int) -> jax.Array:
    """Switch-style load-balance auxiliary (exposed for the training loop)."""
    probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=0)
    imp = jnp.mean(probs, axis=0)
    return e * jnp.sum(frac * imp)
