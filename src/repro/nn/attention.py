"""Grouped-query attention with RoPE, KV cache, and memory-bounded softmax.

Three interchangeable implementations (``impl=``):

* ``naive``   — materializes the full [.., S, S] score matrix. Reference.
* ``chunked`` — lax.scan over query chunks; each step computes exact
  softmax rows against the full key set, so peak memory is O(chunk × S)
  instead of O(S²). This is the XLA-native "flash-style" path used by the
  dry-run (the compiled artifact is honest HLO, not an interpreted kernel).
* ``pallas``  — the Pallas flash-attention kernel from
  ``repro.kernels.flash_attention`` (TPU target; interpret-mode on CPU).

Decode attends one new token against a cached [B, S_max, Hkv, hd] KV.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn.dims import Dims
from repro.nn.layers import apply_rope
from repro.nn.params import ParamSpec
from repro.parallel.sharding import constrain, sp_gather_seq, tp_proj_scatter

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_spec(cfg: ArchConfig, dims: Dims) -> dict:
    d, hq, hkv, hd = dims.d_model, dims.num_heads, dims.num_kv_heads, dims.head_dim
    spec = {
        "w_q": ParamSpec((d, hq, hd), ("fsdp", "heads", None)),
        "w_k": ParamSpec((d, hkv, hd), ("fsdp", "kv_heads", None)),
        "w_v": ParamSpec((d, hkv, hd), ("fsdp", "kv_heads", None)),
        "w_o": ParamSpec((hq, hd, d), ("heads", None, "fsdp")),
    }
    if cfg.qkv_bias:
        spec["b_q"] = ParamSpec((hq, hd), ("heads", None), init="zeros")
        spec["b_k"] = ParamSpec((hkv, hd), ("kv_heads", None), init="zeros")
        spec["b_v"] = ParamSpec((hkv, hd), ("kv_heads", None), init="zeros")
    return spec


def _project_qkv(params, x, cfg: ArchConfig, positions):
    # SP -> TP transition: all-gather the sequence dim ONCE on the [B,S,D]
    # activation (Megatron-SP style), so the three projections read gathered
    # x and emit head-sharded outputs with no further collectives.
    # (§Perf A2: one gather instead of three; A3: explicit bf16 shard_map
    # all_gather so XLA cannot promote the wire dtype to f32.)
    x = sp_gather_seq(x)
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    if cfg.qkv_bias:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return q, k, v


# ---------------------------------------------------------------------------
# Cores
# ---------------------------------------------------------------------------


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    b, s, hq, hd = q.shape
    return q.reshape(b, s, n_kv, hq // n_kv, hd)


def _attend_naive(q, k, v, scale: float) -> jax.Array:
    b, sq, n_kv, g, hd = q.shape
    sk = k.shape[1]
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, n_kv * g, hd)


def _attend_chunked(q, k, v, scale: float, chunk: int) -> jax.Array:
    """Exact causal attention, O(chunk*S) memory, scan over query chunks."""
    b, s, n_kv, g, hd = q.shape
    n_chunks = s // chunk
    qc = q.reshape(b, n_chunks, chunk, n_kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kpos = jnp.arange(s)

    def step(_, args):
        i, q_i = args                                       # q_i: [b,chunk,kv,g,hd]
        scores = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k).astype(jnp.float32) * scale
        qpos = i * chunk + jnp.arange(chunk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
        return None, out

    _, outs = jax.lax.scan(step, None, (jnp.arange(n_chunks), qc))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, n_kv * g, hd)
    return out


def multihead_attention(
    params: dict,
    x: jax.Array,
    cfg: ArchConfig,
    dims: Dims,
    positions: jax.Array,
    impl: str = "chunked",
    chunk: int = 512,
    return_kv: bool = False,
    s_max: Optional[int] = None,
):
    """Full (train/prefill) causal self-attention. x: [B, S, D].

    With ``return_kv``, also returns the rope'd K/V (padded to ``s_max``)
    so prefill can hand a cache to the decode loop."""
    q, k, v = _project_qkv(params, x, cfg, positions)
    qg = _group(q, dims.num_kv_heads)
    scale = dims.head_dim ** -0.5
    s = x.shape[1]
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True)
    elif impl == "naive" or s <= chunk:
        out = _attend_naive(qg, k, v, scale)
    elif impl == "chunked":
        out = _attend_chunked(qg, k, v, scale, min(chunk, s))
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    out = constrain(out, "batch", None, "heads", None)
    # TP -> SP: einsum + psum_scatter in one shard_map — an explicit bf16
    # reduce-scatter instead of the partitioner's f32 all-reduce (§Perf A3).
    y = tp_proj_scatter(out, params["w_o"], "bshk,hkd->bsd",
                        ("batch", None, "heads", None), w_sharded_dim=0)
    if not return_kv:
        return y
    s_max = s_max or s
    pad = s_max - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if cfg.kv_quant:
        from repro.core.lm_quant import quantize_kv
        k_q, k_s = quantize_kv(k)
        v_q, v_s = quantize_kv(v)
        return y, {"k_q": k_q, "k_s": k_s, "v_q": v_q, "v_s": v_s}
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Decode (KV cache)
# ---------------------------------------------------------------------------


def init_kv_cache(batch: int, s_max: int, dims: Dims,
                  dtype=jnp.bfloat16, quant: bool = False) -> dict:
    from repro.nn.params import build_params
    return build_params(kv_cache_spec(batch, s_max, dims, dtype, quant),
                        jax.random.PRNGKey(0))


def kv_cache_spec(batch: int, s_max: int, dims: Dims, dtype=jnp.bfloat16,
                  quant: bool = False) -> dict:
    shape = (batch, s_max, dims.num_kv_heads, dims.head_dim)
    if quant:
        # INT8 codes + per-(b, pos, head) f32 scales (§Perf B2): halves the
        # decode-dominating cache reads vs bf16.
        sshape = (batch, s_max, dims.num_kv_heads)
        ax = ("batch", None, "kv_heads", None)
        sax = ("batch", None, "kv_heads")
        return {
            "k_q": ParamSpec(shape, ax, init="zeros", dtype=jnp.int8),
            "k_s": ParamSpec(sshape, sax, init="zeros", dtype=jnp.float32),
            "v_q": ParamSpec(shape, ax, init="zeros", dtype=jnp.int8),
            "v_s": ParamSpec(sshape, sax, init="zeros", dtype=jnp.float32),
        }
    return {
        "k": ParamSpec(shape, ("batch", None, "kv_heads", None), dtype=dtype),
        "v": ParamSpec(shape, ("batch", None, "kv_heads", None), dtype=dtype),
    }


def decode_attention(
    params: dict,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    cfg: ArchConfig,
    dims: Dims,
) -> Tuple[jax.Array, dict]:
    """One-token step. x: [B, 1, D]; cache k/v: [B, S_max, Hkv, hd];
    pos: scalar int32 — index the new token is written at (attends 0..pos)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, dtype=jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, cfg, positions)

    if cfg.kv_quant:
        # §Perf B2: int8 cache — update codes+scales in place, attend on the
        # dequantized view (fused dequant+dot on TPU; HBM reads are 1 B/elem)
        from repro.core.lm_quant import dequantize_kv, quantize_kv
        kq_new, ks_new = quantize_kv(k_new)
        vq_new, vs_new = quantize_kv(v_new)
        new_cache = {
            "k_q": jax.lax.dynamic_update_slice(cache["k_q"], kq_new,
                                                (0, pos, 0, 0)),
            "k_s": jax.lax.dynamic_update_slice(cache["k_s"], ks_new,
                                                (0, pos, 0)),
            "v_q": jax.lax.dynamic_update_slice(cache["v_q"], vq_new,
                                                (0, pos, 0, 0)),
            "v_s": jax.lax.dynamic_update_slice(cache["v_s"], vs_new,
                                                (0, pos, 0)),
        }
        k = dequantize_kv(new_cache["k_q"], new_cache["k_s"], x.dtype)
        v = dequantize_kv(new_cache["v_q"], new_cache["v_s"], x.dtype)
        k = constrain(k, "batch", None, "kv_heads", None)
        v = constrain(v, "batch", None, "kv_heads", None)
        return _decode_core(params, x, q, k, v, pos, dims), new_cache

    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, pos, 0, 0))
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    return _decode_core(params, x, q, k, v, pos, dims), {"k": k, "v": v}


def _decode_core(params, x, q, k, v, pos, dims) -> jax.Array:
    b = x.shape[0]
    qg = _group(q, dims.num_kv_heads)[:, 0]                  # [B, kv, g, hd]
    scale = dims.head_dim ** -0.5
    s_max = k.shape[1]
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k).astype(jnp.float32) * scale
    mask = jnp.arange(s_max)[None, None, None, :] <= pos
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v)
    out = out.reshape(b, 1, dims.num_heads, dims.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
