"""Residual blocks per family, in both full-sequence and decode forms.

A *group* is the scan unit (see model.py): dense/ssm groups hold one block,
moe groups hold ``layer_period`` blocks (dense FFN subs + one MoE block),
hybrid groups hold ``hybrid_attn_period`` ssm blocks followed by one
application of the weight-tied shared attention block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.nn import attention as attn_mod
from repro.nn import moe as moe_mod
from repro.nn import ssm as ssm_mod
from repro.nn.dims import Dims
from repro.nn.layers import mlp, mlp_spec, norm_spec, rmsnorm
from repro.nn.params import ParamSpec
from repro.parallel.sharding import constrain


def _res(x):
    return constrain(x, "batch", "seq", None)


# ---------------------------------------------------------------------------
# Dense (attention + SwiGLU) block
# ---------------------------------------------------------------------------


def dense_block_spec(cfg: ArchConfig, dims: Dims) -> dict:
    return {
        "ln1": norm_spec(dims.d_model),
        "attn": attn_mod.attn_spec(cfg, dims),
        "ln2": norm_spec(dims.d_model),
        "mlp": mlp_spec(dims),
    }


def dense_block(params, x, cfg, dims, positions, attn_impl="chunked",
                return_cache=False, s_max=None):
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    a = attn_mod.multihead_attention(params["attn"], h, cfg, dims, positions,
                                     impl=attn_impl, return_kv=return_cache,
                                     s_max=s_max)
    if return_cache:
        a, kv = a
    x = _res(x + a)
    h = rmsnorm(x, params["ln2"], cfg.norm_eps)
    x = _res(x + mlp(params["mlp"], h))
    return (x, kv) if return_cache else x


def dense_block_decode(params, x, cache, pos, cfg, dims):
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    a, cache = attn_mod.decode_attention(params["attn"], h, cache, pos, cfg, dims)
    x = x + a
    h = rmsnorm(x, params["ln2"], cfg.norm_eps)
    x = x + mlp(params["mlp"], h)
    return x, cache


# ---------------------------------------------------------------------------
# MoE block (dense attention + routed FFN)
# ---------------------------------------------------------------------------


def moe_block_spec(cfg: ArchConfig, dims: Dims) -> dict:
    return {
        "ln1": norm_spec(dims.d_model),
        "attn": attn_mod.attn_spec(cfg, dims),
        "ln2": norm_spec(dims.d_model),
        "moe": moe_mod.moe_spec(cfg, dims),
    }


def moe_block(params, x, cfg, dims, positions, attn_impl="chunked",
              return_cache=False, s_max=None):
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    a = attn_mod.multihead_attention(params["attn"], h, cfg, dims, positions,
                                     impl=attn_impl, return_kv=return_cache,
                                     s_max=s_max)
    if return_cache:
        a, kv = a
    x = _res(x + a)
    h = rmsnorm(x, params["ln2"], cfg.norm_eps)
    x = _res(x + moe_mod.moe_ffn(params["moe"], h, cfg, dims))
    return (x, kv) if return_cache else x


def moe_block_decode(params, x, cache, pos, cfg, dims):
    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    a, cache = attn_mod.decode_attention(params["attn"], h, cache, pos, cfg, dims)
    x = x + a
    h = rmsnorm(x, params["ln2"], cfg.norm_eps)
    x = x + moe_mod.moe_ffn(params["moe"], h, cfg, dims)
    return x, cache


# ---------------------------------------------------------------------------
# SSM block
# ---------------------------------------------------------------------------


def ssm_block_spec(cfg: ArchConfig, dims: Dims) -> dict:
    return {"ln": norm_spec(dims.d_model), "ssm": ssm_mod.ssm_spec(cfg, dims)}


def ssm_block(params, x, cfg, dims, return_cache=False):
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    if return_cache:
        y, cache = ssm_mod.ssm_mixer(params["ssm"], h, cfg, dims, return_cache=True)
        return _res(x + y), cache
    return _res(x + ssm_mod.ssm_mixer(params["ssm"], h, cfg, dims))


def ssm_block_decode(params, x, cache, cfg, dims):
    h = rmsnorm(x, params["ln"], cfg.norm_eps)
    y, cache = ssm_mod.ssm_decode_step(params["ssm"], h, cache, cfg, dims)
    return x + y, cache
