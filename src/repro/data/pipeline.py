"""Data pipeline: deterministic synthetic streams + host-sharded loading.

Real missions feed sensor frames; for training/benchmarks we generate
deterministic synthetic batches (seeded per step, so a restarted job
resumes on *identical* data — important for checkpoint/restart tests).

``host_shard`` mimics the multi-host layout: each host materializes only
its slice of the global batch, then ``jax.make_array_from_process_local_data``
(or direct device_put on one host) assembles the global array. On this
single-process container the shard is the whole batch, but the code path
is the production one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.nn.dims import Dims


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # synthetic LM stream: a noisy copy task so loss actually decreases —
    # next token = (current + stride) mod vocab with flip noise
    stride: int = 7
    noise: float = 0.05


def _tokens_for_step(step: int, batch: int, seq: int, vocab: int,
                     dc: DataConfig) -> np.ndarray:
    rng = np.random.default_rng(dc.seed * 1_000_003 + step)
    start = rng.integers(0, vocab, size=(batch, 1))
    ramp = (start + dc.stride * np.arange(seq + 1)[None, :]) % vocab
    flips = rng.random((batch, seq + 1)) < dc.noise
    noise = rng.integers(0, vocab, size=(batch, seq + 1))
    return np.where(flips, noise, ramp).astype(np.int32)


def synthetic_batch(step: int, cfg: ArchConfig, dims: Dims, shape: ShapeSpec,
                    dc: DataConfig = DataConfig(),
                    batch_override: Optional[int] = None,
                    seq_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Host-side numpy batch for one step (tokens shifted into labels)."""
    b = batch_override or shape.global_batch
    s = seq_override or shape.seq_len
    seqs = _tokens_for_step(step, b, s, cfg.vocab_size, dc)
    batch: Dict[str, np.ndarray] = {"labels": seqs[:, 1:]}
    if cfg.frontend == "text":
        batch["tokens"] = seqs[:, :-1]
    else:
        # stub modality frontend: deterministic pseudo-embeddings derived
        # from the token stream (same shape contract as a real encoder)
        rng = np.random.default_rng(dc.seed * 7_000_003 + step)
        proj = rng.standard_normal((cfg.vocab_size, 1)).astype(np.float32)
        base = proj[seqs[:, :-1], 0]
        phases = np.arange(dims.d_model, dtype=np.float32)[None, None, :]
        emb = np.sin(base[..., None] * 0.1 + phases * 0.01).astype(np.float32)
        batch["embeds"] = emb
    return batch


def data_iterator(cfg: ArchConfig, dims: Dims, shape: ShapeSpec,
                  dc: DataConfig = DataConfig(), start_step: int = 0,
                  batch_override: Optional[int] = None,
                  seq_override: Optional[int] = None) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(step, cfg, dims, shape, dc,
                              batch_override, seq_override)
        step += 1


# ---------------------------------------------------------------------------
# Host sharding
# ---------------------------------------------------------------------------


def host_shard(batch: Dict[str, np.ndarray], mesh, shardings) -> Dict[str, jax.Array]:
    """Assemble global device arrays from (this process's slice of) a batch.

    Single-process: jax.device_put with the target sharding. Multi-process:
    each host owns global_batch / process_count rows and we use
    make_array_from_process_local_data so no host materializes the full
    global batch.
    """
    if jax.process_count() == 1:
        return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        out[k] = jax.make_array_from_process_local_data(shardings[k], v)
    return out


def local_slice(step: int, cfg: ArchConfig, dims: Dims, shape: ShapeSpec,
                dc: DataConfig = DataConfig()) -> Dict[str, np.ndarray]:
    """The rows this host is responsible for (identical across hosts only
    in the single-process case)."""
    b_global = shape.global_batch
    n_proc = jax.process_count()
    b_local = max(b_global // n_proc, 1)
    full = synthetic_batch(step, cfg, dims, shape, dc)
    lo = (jax.process_index() * b_local) % b_global
    return {k: v[lo: lo + b_local] for k, v in full.items()}
