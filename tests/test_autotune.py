"""Autotuner + prepacked weight arena tests (DESIGN.md §11).

Pins the four contracts ISSUE 5 gates on:

* every candidate tile config — and the prepacked kernel paths — is
  bit-exact to the heuristic default (int8 cells exactly equal);
* the tuning cache is deterministic: same graph -> same picks, and a
  warm cache performs ZERO candidate evaluations;
* ``Engine(..., autotune=False)`` (the default) reproduces today's
  plans node-for-node;
* tuned plans are never worse than the heuristic default under the
  kernel-level pricer, and the packed (padded) weight footprint is what
  the arena budget and cost signatures charge.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import autotune as autotune_mod
from repro.core.autotune import Autotuner, KernelConfig, TuningCache
from repro.core.engine import Engine
from repro.kernels import ops as kops
from repro.models import SPACE_MODELS

CHEAP_MODELS = ("logistic_net", "reduced_net", "multi_esperta")
N_CALIB = 4


_ENGINES = {}


def engines(name: str):
    """(model, default engine, autotuned engine), memoized per module —
    calibration is shared so the interpret-mode cost is paid once."""
    if name not in _ENGINES:
        m = SPACE_MODELS[name]
        e0 = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e0.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                      for i in range(N_CALIB)])
        e1 = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)),
                    autotune=True)
        e1.share_calibration(e0)
        _ENGINES[name] = (m, e0, e1)
    return _ENGINES[name]


# ---------------------------------------------------------------------------
# Kernel-level bit-exactness across the whole candidate space
# ---------------------------------------------------------------------------


def test_int8_matmul_bit_exact_across_tile_configs():
    rng = np.random.default_rng(0)
    m, k, n = 5, 70, 13
    x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.uniform(0.01, 1, m), jnp.float32)
    ws = jnp.asarray(rng.uniform(0.01, 1, n), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, n), jnp.float32)
    ref = kops.int8_matmul(x, w, xs, ws, b, act="relu")
    for cfg in autotune_mod.dense_candidates(m, k, n):
        out = kops.int8_matmul(x, w, xs, ws, b, act="relu",
                               bm=cfg.bm, bn=cfg.bn, bk=cfg.bk)
        assert np.array_equal(np.asarray(ref), np.asarray(out)), cfg


def test_int8_matmul_prepacked_bit_exact():
    rng = np.random.default_rng(1)
    m, k, n = 4, 50, 10
    x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.uniform(0.01, 1, m), jnp.float32)
    ws = jnp.asarray(rng.uniform(0.01, 1, n), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, n), jnp.float32)
    for requant in (None, 0.37):
        ref = kops.int8_matmul(x, w, xs, ws, b, requant_scale=requant)
        for bk, bn in ((8, 8), (64, 16), (128, 128)):
            kp, np_ = -(-k // bk) * bk, -(-n // bn) * bn
            wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
            wsp = jnp.pad(ws, (0, np_ - n), constant_values=1.0)
            bp = jnp.pad(b, (0, np_ - n))
            out = kops.int8_matmul(x, wp, xs, wsp, bp,
                                   requant_scale=requant, bm=8, bn=bn,
                                   bk=bk, prepacked=True, n_out=n)
            assert ref.dtype == out.dtype
            assert np.array_equal(np.asarray(ref), np.asarray(out)), (bk, bn)


@pytest.mark.parametrize("stride,padding", [(1, "SAME"), (2, "SAME"),
                                            (1, "VALID")])
def test_conv2d_int8_bit_exact_across_tile_configs(stride, padding):
    rng = np.random.default_rng(2)
    h, wd, cin, cout, kk = 9, 7, 3, 12, 3
    x = jnp.asarray(rng.integers(-127, 128, (2, h, wd, cin)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (kk, kk, cin, cout)), jnp.int8)
    ws = jnp.asarray(rng.uniform(0.01, 1, cout), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, cout), jnp.float32)
    ref = kops.conv2d_int8(x, w, ws, b, x_scale=0.5, stride=stride,
                           padding=padding, act="relu")
    from repro.kernels.conv2d import conv_geometry
    h_out = conv_geometry(h, wd, kk, kk, stride, padding, 1).h_out
    for cfg in autotune_mod.conv_candidates(h_out, cout):
        out = kops.conv2d_int8(
            x, w, ws, b, x_scale=0.5, stride=stride, padding=padding,
            act="relu",
            rows_per_block=cfg.rows_per_block or 8,
            cout_per_block=cfg.cout_per_block)
        assert np.array_equal(np.asarray(ref), np.asarray(out)), cfg


def test_conv2d_int8_prepacked_prepadded_bit_exact():
    rng = np.random.default_rng(3)
    from repro.kernels.conv2d import conv_geometry, pad_input
    from repro.kernels.epilogue import pad_channel_params
    h, wd, cin, cout, kk = 10, 10, 4, 9, 3
    x = jnp.asarray(rng.integers(-127, 128, (2, h, wd, cin)), jnp.int8)
    w = jnp.asarray(rng.integers(-127, 128, (kk, kk, cin, cout)), jnp.int8)
    ws = jnp.asarray(rng.uniform(0.01, 1, cout), jnp.float32)
    b = jnp.asarray(rng.uniform(-1, 1, cout), jnp.float32)
    ref = kops.conv2d_int8(x, w, ws, b, x_scale=0.5, stride=2,
                           requant_scale=0.11)
    rows, bc = 3, 8
    g = conv_geometry(h, wd, kk, kk, 2, "SAME", rows)
    xp = pad_input(x, g)
    pad_c = -(-cout // bc) * bc - cout
    wp = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
    wsp, bp = pad_channel_params(ws, b, pad_c)
    out = kops.conv2d_int8(xp, wp, wsp, bp, x_scale=0.5, stride=2,
                           requant_scale=0.11, rows_per_block=rows,
                           cout_per_block=bc, cout=cout, pre_padded=True,
                           in_hw=(h, wd))
    assert ref.dtype == out.dtype == jnp.int8
    assert np.array_equal(np.asarray(ref), np.asarray(out))


# ---------------------------------------------------------------------------
# Engine-level equivalence: tuned plans bit-exact to untuned, all models
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_prepacked_vs_on_the_fly_equivalence(name):
    """Autotuned (prepacked arenas + tuned tiles) == heuristic engine,
    bit-exact, on both backends, for all six models."""
    m, e0, e1 = engines(name)
    n = 2
    inputs = m.synthetic_batch(jax.random.PRNGKey(9), n)
    rngs = jax.random.split(jax.random.PRNGKey(3), n)
    for backend in ("flex", "accel"):
        a = e0.run_batch(inputs, backend, rngs)
        b = e1.run_batch(inputs, backend, rngs)
        for k in a:
            assert a[k].dtype == b[k].dtype
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), \
                (name, backend, k)


def test_autotune_off_reproduces_plans_node_for_node():
    """Engine() (the default) and an explicitly-untuned engine build the
    same plans as before the autotuner existed: no tuning state, no
    packed weights, identical graphs/segments/qplans."""
    m, e0, _ = engines("reduced_net")
    e_off = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)),
                   autotune=False)
    e_off.share_calibration(e0)
    for backend in ("flex", "accel"):
        p0, p1 = e0.planned(backend), e_off.planned(backend)
        assert p0.tuner is None and p1.tuner is None
        assert not p0._tuning and not p0.packed
        assert p0.graph.order == p1.graph.order
        assert [(s.backend, s.nodes) for s in p0.segments] == \
            [(s.backend, s.nodes) for s in p1.segments]
        assert sorted(p0.qplans) == sorted(p1.qplans)
        # untuned cost signatures are the pre-autotune model, unchanged
        s0, s1 = p0.cost_signature(8), p1.cost_signature(8)
        assert s0 == s1


# ---------------------------------------------------------------------------
# Cache determinism + the no-research contract
# ---------------------------------------------------------------------------


def _tuned_configs(engine, backend="accel", rungs=(1, 4)):
    out = {}
    for r in rungs:
        engine.compile(backend, r)
    plan = engine.planned(backend)
    for r, dec in plan._tuning.items():
        out[r] = {n: d.config for n, d in dec.items()}
    return out


def test_cache_roundtrip_same_graph_same_picks(tmp_path):
    cache_path = str(tmp_path / "tuning.json")
    m, e0, _ = engines("reduced_net")

    def fresh():
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)),
                   autotune=True, tuning_cache=cache_path)
        e.share_calibration(e0)
        return e

    e1 = fresh()
    picks1 = _tuned_configs(e1)
    assert e1.tuner.stats["evaluated"] > 0
    assert len(e1.tuner.cache) > 0

    # a brand-new engine with the warm JSON cache: identical picks and
    # ZERO candidate evaluations (the acceptance-criteria assertion)
    e2 = fresh()
    picks2 = _tuned_configs(e2)
    assert picks1 == picks2
    assert e2.tuner.stats["evaluated"] == 0
    assert e2.tuner.stats["cache_hits"] == e2.tuner.stats["nodes"]


def test_second_lower_same_engine_no_research():
    m, e0, _ = engines("logistic_net")
    e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)),
               autotune=True)
    e.share_calibration(e0)
    e.compile("accel", 4)
    evaluated = e.tuner.stats["evaluated"]
    n0 = e.planned("accel").n_traces
    e.compile("accel", 4)               # plan cache: no tuning, no trace
    assert e.tuner.stats["evaluated"] == evaluated
    assert e.planned("accel").n_traces == n0


# ---------------------------------------------------------------------------
# Pricing gates + packed-footprint accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", CHEAP_MODELS)
@pytest.mark.parametrize("backend", ["flex", "accel"])
def test_tuned_never_worse_than_default_pricing(name, backend):
    _, _, e1 = engines(name)
    plan = e1.planned(backend)
    for rung in (1, 32):
        tuned = plan.cost_signature(rung)
        default = plan.default_cost_signature(rung)
        assert tuned.latency_s <= default.latency_s * (1 + 1e-9)
        assert tuned.j_per_inference <= default.j_per_inference * (1 + 1e-9)


def test_packed_footprint_charged_to_arena_and_signature():
    from repro.core import energy as energy_mod
    _, _, e1 = engines("reduced_net")
    plan = e1.planned("accel")
    plan.lower(4)                       # triggers pack at pack_batch
    assert plan.packed, "accel plan should prepack its quantized nodes"
    unpacked = energy_mod.weight_bytes(plan.graph, "accel",
                                       set(plan.qplans))
    packed = energy_mod.weight_bytes(plan.graph, "accel",
                                     set(plan.qplans),
                                     plan._packed_bytes)
    assert packed >= unpacked           # padding only ever adds bytes
    for nm, p in plan.packed.items():
        assert p.packed_bytes >= 1
    assert plan.arena.weight_bytes == packed
    # the arena budget shrank by exactly the packing overhead
    hw = energy_mod.BACKEND_HW["accel"]
    assert plan.arena.bram_budget == int(hw.onchip_bytes) - packed


def test_as_text_prints_tile_configs_and_packed_bytes():
    _, _, e1 = engines("reduced_net")
    plan = e1.planned("accel")
    plan.lower(4)
    text = plan.as_text()
    assert "autotune @ batch" in text
    assert "tile " in text
    assert "packed=" in text
    # flex plans print the HLS schedule configs
    fplan = e1.planned("flex")
    fplan.lower(4)
    assert "unroll x" in fplan.as_text()


# ---------------------------------------------------------------------------
# Measured refinement (opt-in) + cache key stability
# ---------------------------------------------------------------------------


def test_measured_refinement_smoke():
    tuner = Autotuner(TuningCache(None), measure=True, measure_top_k=2,
                      measure_repeats=1)
    from repro.core import energy as energy_mod
    hw = energy_mod.BACKEND_HW["accel"]
    dec = tuner._search("int8_dense", (4, 64, 16), hw, True, None)
    assert dec.source == "measured"
    assert tuner.stats["measured"] > 0
    assert dec.modeled_s > 0


def test_cache_key_sensitive_to_shape_backend_and_hw():
    from repro.core import energy as energy_mod
    hw_a = energy_mod.BACKEND_HW["accel"]
    hw_f = energy_mod.BACKEND_HW["flex"]
    k0 = autotune_mod.cache_key("int8_dense", (4, 64, 16), "accel", hw_a)
    assert k0 == autotune_mod.cache_key("int8_dense", (4, 64, 16),
                                        "accel", hw_a)
    assert k0 != autotune_mod.cache_key("int8_dense", (8, 64, 16),
                                        "accel", hw_a)
    assert k0 != autotune_mod.cache_key("int8_dense", (4, 64, 16),
                                        "flex", hw_f)
    assert k0 != autotune_mod.cache_key(
        "int8_dense", (4, 64, 16), "accel", hw_a,
        fixed=KernelConfig(bn=16, bk=64))
    # residency changes the stored restream pricing; the measured
    # refinement changes the winner itself — both get their own entries
    assert k0 != autotune_mod.cache_key("int8_dense", (4, 64, 16),
                                        "accel", hw_a, resident=False)
    assert k0 != autotune_mod.cache_key("int8_dense", (4, 64, 16),
                                        "accel", hw_a, measured=True)


def test_stale_cache_schema_discarded(tmp_path):
    import json
    path = tmp_path / "tuning.json"
    path.write_text(json.dumps({"version": -1, "entries": {"x": {}}}))
    cache = TuningCache(str(path))
    assert len(cache) == 0


def test_corrupt_cache_file_is_cold_not_fatal(tmp_path, capsys):
    """A truncated/corrupt/foreign cache file (half-written at the last
    power cut — the exact scenario a tuning cache exists for) must load
    as a COLD cache with a one-line warning, never crash plan building.
    The seed raised json.JSONDecodeError from the constructor."""
    for blob in ('{"version": 1, "entries": {"trunc',      # cut mid-write
                 "\x00\x7fELF garbage",                    # not JSON at all
                 "[1, 2, 3]",                              # JSON, not a dict
                 '"just a string"'):
        path = tmp_path / "tuning.json"
        path.write_text(blob)
        cache = TuningCache(str(path))
        assert len(cache) == 0
        out = capsys.readouterr().out
        assert "ignoring" in out and "cold cache" in out
    # and an unreadable path (directory) degrades the same way
    cache = TuningCache(str(tmp_path))
    assert len(cache) == 0
    assert "cold cache" in capsys.readouterr().out


def test_corrupt_cache_recovers_end_to_end(tmp_path):
    """An Engine pointed at a corrupt cache file still autotunes (cold),
    then persists a fresh valid cache over the corpse."""
    import json
    path = tmp_path / "tuning.json"
    path.write_text('{"version": 1, "entries"')            # torn write
    m = SPACE_MODELS["multi_esperta"]
    e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)),
               autotune=True, tuning_cache=str(path))
    e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                 for i in range(2)])
    e.compile("accel", 4)                                  # tunes + saves
    payload = json.loads(path.read_text())                 # valid again
    assert payload["version"] == autotune_mod.SCHEMA_VERSION
    assert isinstance(payload["entries"], dict)
