"""Jaxpr front-end tests (DESIGN.md §14): per-primitive translator
units, the clear-error contract for unsupported primitives, the
six-model traced-vs-hand-built bit-exactness sweep on both backends, the
inspector's structural-kind exclusion, and the never-hand-built demo
model serving end to end from a trace.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import inspector
from repro.core.engine import Engine
from repro.frontend import (UnsupportedPrimitiveError, sample_normal,
                            trace)
from repro.frontend import demo
from repro.models import SPACE_MODELS, synthetic_requests


def _ops(tm):
    return [tm.graph.nodes[n].op for n in tm.graph.order]


# ---------------------------------------------------------------------------
# per-primitive translator units
# ---------------------------------------------------------------------------


def test_conv_same_stride_translates_with_folded_bias():
    w = jax.random.normal(jax.random.PRNGKey(0), (3, 3, 2, 4)) * 0.1
    b = jnp.arange(4, dtype=jnp.float32)

    def fn(inp):
        y = jax.lax.conv_general_dilated(
            inp["x"], w, (2, 2), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
        return {"y": y}

    tm = trace(fn, {"x": (9, 9, 2)})
    assert _ops(tm) == ["input", "conv2d"]
    node = tm.graph.nodes["y"]
    assert node.attrs["kernel"] == (3, 3)
    assert node.attrs["features"] == 4
    assert node.attrs["stride"] == 2
    assert node.attrs["padding"] == "SAME"
    assert node.out_shape == (5, 5, 4)
    np.testing.assert_array_equal(tm.params["y"]["b"], np.asarray(b))


def test_conv_valid_padding_translates():
    w = jnp.ones((2, 2, 1, 3), jnp.float32)

    def fn(inp):
        return {"y": jax.lax.conv_general_dilated(
            inp["x"], w, (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))}

    tm = trace(fn, {"x": (6, 6, 1)})
    assert tm.graph.nodes["y"].attrs["padding"] == "VALID"
    # no bias in the function -> zero bias param (the impl always adds b)
    np.testing.assert_array_equal(tm.params["y"]["b"], np.zeros(3))


def test_depthwise_conv_translates_to_grouped_conv2d():
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 1, 6)) * 0.1

    def fn(inp):
        return {"y": jax.lax.conv_general_dilated(
            inp["x"], w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=6)}

    tm = trace(fn, {"x": (8, 8, 6)})
    node = tm.graph.nodes["y"]
    assert node.op == "conv2d" and node.attrs["groups"] == 6
    # grouped conv has no int8 kernel -> flex, plain conv2d stays accel
    assert not inspector.accel_supports(node)


def test_conv3d_translates():
    w = jnp.ones((2, 2, 2, 1, 3), jnp.float32)

    def fn(inp):
        return {"y": jax.lax.conv_general_dilated(
            inp["x"], w, (1, 1, 1), "SAME",
            dimension_numbers=("NDHWC", "DHWIO", "NDHWC"))}

    tm = trace(fn, {"x": (4, 4, 4, 1)})
    assert tm.graph.nodes["y"].op == "conv3d"
    assert tm.graph.nodes["y"].attrs["kernel"] == (2, 2, 2)


def test_dot_general_with_bias_folds_to_dense():
    w = jax.random.normal(jax.random.PRNGKey(2), (6, 3))
    b = jnp.asarray([1.0, -1.0, 0.5])

    def fn(inp):
        return {"y": inp["x"] @ w + b}

    tm = trace(fn, {"x": (6,)})
    assert _ops(tm) == ["input", "dense"]
    assert tm.graph.nodes["y"].attrs["features"] == 3
    assert tm.graph.nodes["y"].attrs["bias"] is True
    np.testing.assert_array_equal(tm.params["y"]["b"], np.asarray(b))


def test_dense_without_bias_keeps_bias_false():
    w = jnp.ones((4, 2), jnp.float32)
    tm = trace(lambda inp: {"y": inp["x"] @ w}, {"x": (4,)})
    assert tm.graph.nodes["y"].attrs["bias"] is False
    assert "b" not in tm.params["y"]


def test_bias_fold_refuses_shared_pre_bias_tensor():
    """If the pre-bias matmul output is read elsewhere, folding the bias
    into the dense node would corrupt that other reader — the fold must
    refuse and emit const+add instead."""
    w = jnp.ones((4, 2), jnp.float32)
    b = jnp.asarray([1.0, 2.0])

    def fn(inp):
        z = inp["x"] @ w
        return {"y": z + b, "raw": z * 1.0}

    tm = trace(fn, {"x": (4,)})
    raw = np.ones((2, 4), np.float32)
    eng = Engine(tm.graph, tm.params)
    out = eng.run_batch({"x": raw}, backend="flex")
    ref = fn({"x": jnp.asarray(raw)})
    np.testing.assert_array_equal(out["y"], np.asarray(ref["y"]))
    np.testing.assert_array_equal(out["raw"], np.asarray(ref["raw"]))


def test_relu_and_unary_activations_translate():
    def fn(inp):
        x = inp["x"]
        return {"r": jax.nn.relu(x), "s": jax.nn.sigmoid(x),
                "t": jnp.tanh(x), "e": jnp.exp(x)}

    tm = trace(fn, {"x": (5,)})
    got = {tm.graph.nodes[n].op for n in ("r", "s", "t", "e")}
    assert got == {"relu", "sigmoid", "tanh", "exp"}


def test_add_mul_of_two_traced_tensors():
    def fn(inp):
        a = jnp.exp(inp["x"])
        b = jnp.tanh(inp["x"])
        return {"s": a + b, "p": a * b}

    tm = trace(fn, {"x": (3,)})
    assert tm.graph.nodes["s"].op == "add"
    assert tm.graph.nodes["p"].op == "mul"


def test_scalar_mul_emits_const_node():
    tm = trace(lambda inp: {"y": jnp.exp(inp["x"]) * 2.0}, {"x": (3,)})
    ops = _ops(tm)
    assert "const" in ops and "mul" in ops
    x = np.linspace(-1, 1, 6).reshape(2, 3).astype(np.float32)
    out = Engine(tm.graph, tm.params).run_batch({"x": x}, backend="flex")
    np.testing.assert_array_equal(out["y"],
                                  np.asarray(jnp.exp(x) * 2.0))


def test_maxpool_translates():
    def fn(inp):
        return {"y": jax.lax.reduce_window(
            inp["x"], -jnp.inf, jax.lax.max,
            (1, 2, 2, 1), (1, 2, 2, 1), "VALID")}

    tm = trace(fn, {"x": (6, 6, 2)})
    assert tm.graph.nodes["y"].op == "maxpool2d"
    assert tm.graph.nodes["y"].attrs["kernel"] == 2


def test_avgpool_sum_div_peephole_is_bit_exact():
    def fn(inp):
        s = jax.lax.reduce_window(inp["x"], 0.0, jax.lax.add,
                                  (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
        return {"y": s / 4.0}

    tm = trace(fn, {"x": (6, 6, 2)})
    assert _ops(tm) == ["input", "avgpool2d"]
    x = np.random.default_rng(3).normal(size=(2, 6, 6, 2)) \
        .astype(np.float32)
    out = Engine(tm.graph, tm.params).run_batch({"x": x}, backend="flex")
    np.testing.assert_array_equal(out["y"],
                                  np.asarray(fn({"x": jnp.asarray(x)})["y"]))


def test_sum_pool_without_div_raises():
    def fn(inp):
        return {"y": jax.lax.reduce_window(
            inp["x"], 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1),
            "VALID")}

    with pytest.raises(UnsupportedPrimitiveError, match="average pool"):
        trace(fn, {"x": (6, 6, 2)})


def test_global_reduce_max_becomes_pool_plus_flatten():
    tm = trace(lambda inp: {"y": jnp.max(inp["x"], axis=(1, 2))},
               {"x": (4, 4, 3)})
    assert _ops(tm) == ["input", "maxpool2d", "flatten"]
    x = np.random.default_rng(4).normal(size=(2, 4, 4, 3)) \
        .astype(np.float32)
    out = Engine(tm.graph, tm.params).run_batch({"x": x}, backend="flex")
    np.testing.assert_array_equal(out["y"], x.max(axis=(1, 2)))


def test_flatten_reshape_and_identity_reshape():
    def fn(inp):
        x = inp["x"].reshape(inp["x"].shape[0], -1)   # flatten
        return {"y": x.reshape(x.shape)}               # identity: aliased

    tm = trace(fn, {"x": (3, 4, 2)})
    assert _ops(tm) == ["input", "flatten"]


def test_concat_translates_with_per_sample_axis():
    def fn(inp):
        return {"y": jnp.concatenate([inp["a"], inp["b"]], axis=1)}

    tm = trace(fn, {"a": (4,), "b": (2,)})
    assert tm.graph.nodes["y"].op == "concat"
    assert tm.graph.nodes["y"].attrs["axis"] == 0
    assert tm.graph.nodes["y"].out_shape == (6,)


def test_gt_threshold_translates_to_greater():
    tm = trace(lambda inp: {"y": (inp["x"] > 0.25).astype(jnp.float32)},
               {"x": (3,)})
    assert tm.graph.nodes["y"].op == "greater"
    assert tm.graph.nodes["y"].attrs["threshold"] == 0.25


def test_argmax_translates():
    tm = trace(lambda inp: {"y": jnp.argmax(inp["x"], axis=1)
                            .astype(jnp.int32)}, {"x": (5,)})
    assert tm.graph.nodes["y"].op == "argmax"
    assert tm.graph.nodes["y"].out_shape == ()


def test_sample_normal_primitive_translates():
    def fn(inp):
        mu = jnp.exp(inp["x"])
        logvar = jnp.tanh(inp["x"])
        return {"z": sample_normal(mu, logvar)}

    tm = trace(fn, {"x": (4,)})
    assert tm.graph.nodes["z"].op == "sample_normal"


def test_pjit_and_custom_jvp_inline():
    inner = jax.jit(lambda x: jax.nn.relu(x) * 2.0)
    tm = trace(lambda inp: {"y": inner(inp["x"])}, {"x": (3,)})
    ops = _ops(tm)
    assert "relu" in ops and "mul" in ops


def test_trace_time_constant_math_is_evaluated_eagerly():
    w = jnp.ones((4, 2), jnp.float32)
    tm = trace(lambda inp: {"y": inp["x"] @ (w * 3.0)}, {"x": (4,)})
    assert _ops(tm) == ["input", "dense"]
    np.testing.assert_array_equal(tm.params["y"]["w"], np.full((4, 2), 3.0))


# ---------------------------------------------------------------------------
# unsupported-primitive contract
# ---------------------------------------------------------------------------


def test_unsupported_primitive_names_the_eqn():
    with pytest.raises(UnsupportedPrimitiveError) as exc:
        trace(lambda inp: {"y": jnp.sin(inp["x"])}, {"x": (3,)})
    msg = str(exc.value)
    assert "sin" in msg and "register" in msg


def test_unsupported_parameterization_names_the_eqn():
    w = jnp.ones((3, 3, 2, 4), jnp.float32)

    def fn(inp):
        return {"y": jax.lax.conv_general_dilated(
            inp["x"], w, (1, 1), "SAME", rhs_dilation=(2, 2),
            dimension_numbers=("NHWC", "HWIO", "NHWC"))}

    with pytest.raises(UnsupportedPrimitiveError, match="dilated"):
        trace(fn, {"x": (8, 8, 2)})


def test_unsupported_is_never_a_bare_keyerror():
    try:
        trace(lambda inp: {"y": jnp.cumsum(inp["x"], axis=1)}, {"x": (4,)})
    except UnsupportedPrimitiveError:
        pass
    else:                                          # pragma: no cover
        pytest.fail("expected UnsupportedPrimitiveError")


def test_non_dict_output_rejected():
    with pytest.raises(TypeError, match="flat dict"):
        trace(lambda inp: jnp.exp(inp["x"]), {"x": (3,)})


# ---------------------------------------------------------------------------
# inspector: structural kinds stay out of coverage (satellite regression)
# ---------------------------------------------------------------------------


def test_inspector_excludes_const_nodes_from_coverage():
    """Regression: const nodes (constant folding or tracer-captured
    literals) were counted into supported/fully_supported, reporting
    plan-time values as compute the accelerator 'runs'."""
    from repro.core.opgraph import Graph
    g = Graph("structural")
    x = g.input("x", (4,))
    g.add("const", [], name="k", value=np.ones((4,), np.float32))
    g.add("add", [x, "k"], name="y")
    g.mark_output("y")
    rep = inspector.inspect(g)
    assert "const" not in rep.supported + rep.unsupported
    assert "input" not in rep.supported + rep.unsupported
    assert rep.supported == ["add"]
    assert rep.fully_supported


def test_traced_const_graph_calibrates():
    """quantize._trace used to KeyError on const nodes — traced graphs
    carrying captured literals must calibrate."""
    tm = trace(lambda inp: {"y": jnp.exp(inp["x"]) * 2.0}, {"x": (3,)})
    eng = Engine(tm.graph, tm.params)
    eng.calibrate([{"x": np.ones((3,), np.float32)}])
    assert eng._calib["y"] > 0


# ---------------------------------------------------------------------------
# six-model traced-vs-hand-built bit-exactness sweep
# ---------------------------------------------------------------------------


_PAIRS = {}


def _pair(name):
    if name not in _PAIRS:
        model = SPACE_MODELS[name]
        g = model.build_graph()
        params = model.init_params(jax.random.PRNGKey(0))
        tm = trace(functools.partial(model.jax_forward, params),
                   dict(g.graph_inputs), name=name + "_traced")
        _PAIRS[name] = (model, g, params, tm)
    return _PAIRS[name]


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_traced_graph_structure_matches_hand_built(name):
    model, g, params, tm = _pair(name)
    hand = [g.nodes[n].op for n in g.order]
    traced = [tm.graph.nodes[n].op for n in tm.graph.order]
    assert hand == traced
    assert sorted(tm.graph.outputs) == sorted(g.outputs)
    assert tm.graph.n_params == g.n_params
    assert tm.graph.n_macs == g.n_macs


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_traced_model_bit_exact_on_flex_and_accel(name):
    model, g, params, tm = _pair(name)
    calib = synthetic_requests(model, 2, seed=0)
    reqs = synthetic_requests(model, 2, seed=123)
    batch = {k: np.stack([r[k] for r in reqs]) for k in reqs[0]}
    rngs = jax.random.split(jax.random.PRNGKey(7), 2)
    hand_eng, traced_eng = Engine(g, params), Engine(tm.graph, tm.params)
    hand_eng.calibrate(calib)
    traced_eng.calibrate(calib)
    for backend in ("flex", "accel"):
        h = hand_eng.run_batch(batch, backend=backend, rngs=rngs)
        t = traced_eng.run_batch(batch, backend=backend, rngs=rngs)
        assert set(h) == set(t)
        for k in h:
            np.testing.assert_array_equal(
                np.asarray(h[k]), np.asarray(t[k]),
                err_msg=f"{name}/{backend}/{k} diverged")


# ---------------------------------------------------------------------------
# demo: never-hand-built model, trace -> inspect -> PTQ -> autotune -> serve
# ---------------------------------------------------------------------------


def test_demo_trace_matches_jax_reference():
    tm = demo.build_traced()
    params = demo.init_params(jax.random.PRNGKey(42))
    reqs = demo.synthetic_requests(2, seed=9)
    batch = {k: np.stack([r[k] for r in reqs]) for k in reqs[0]}
    out = Engine(tm.graph, tm.params).run_batch(batch, backend="flex")
    ref = demo.jax_forward(params, {k: jnp.asarray(v)
                                    for k, v in batch.items()})
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(ref[k]))


def test_demo_partial_offload_routing():
    tm = demo.build_traced()
    rep = inspector.inspect(tm.graph)
    assert not rep.fully_supported          # depthwise + sigmoid/greater
    assert rep.mac_coverage > 0.5           # pointwise + dense on accel
    assignment = inspector.assign_backends(tm.graph)
    grouped = [n for n in tm.graph.order
               if tm.graph.nodes[n].attrs.get("groups", 1) != 1]
    assert grouped and all(assignment[n] == "flex" for n in grouped)


def test_demo_serves_end_to_end():
    facts = demo.run_demo(n_requests=8, batch_top=4, verbose=False)
    assert facts["n_completed"] == facts["n_requests"] == 8
    assert 0 <= facts["n_kept"] <= 8
    assert facts["outputs"] == ["cloud_flag", "cloud_prob"]
    assert facts["n_segments"] >= 3         # accel/flex interleaving
