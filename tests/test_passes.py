"""Graph-compiler pass pipeline unit tests (core/passes.py, DESIGN.md
§10): constant folding, dead-node elimination, epilogue fusion, requant
fusion — structure AND numerics, on synthetic graphs small enough to run
the int8 interpret-mode kernels fast.
"""
import jax
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.opgraph import Graph, base_op, param_node
from repro.core.passes import (PassContext, PassManager, constant_fold,
                               eliminate_dead_nodes)
from repro.models.common import init_graph_params


def _engine(g, fuse=True, demote=1e9, n_calib=2, seed=1):
    e = Engine(g, init_graph_params(g, jax.random.PRNGKey(seed)),
               ptq_demote_threshold=demote, fuse=fuse)
    rng = np.random.default_rng(0)
    shape = next(iter(g.graph_inputs.values()))
    calib = [{next(iter(g.graph_inputs)): rng.standard_normal(shape)
              .astype(np.float32)} for _ in range(n_calib)]
    e.calibrate(calib)
    return e


# ---------------------------------------------------------------------------
# constant folding + DCE
# ---------------------------------------------------------------------------


def test_constant_fold_evaluates_input_free_subgraph():
    g = Graph("fold")
    x = g.input("x", (4,))
    c = g.add("const", [], name="c", value=np.arange(4, dtype=np.float32))
    c2 = g.add("relu", [c], name="c_relu")       # foldable: no input dep
    y = g.add("add", [x, c2], name="y")
    g.mark_output(y)
    ctx = PassContext(params={}, assignment={n: "flex" for n in g.order})
    out, report = PassManager().run(g, ctx)
    assert out.nodes["c_relu"].op == "const"
    assert "c_relu" in report.folded
    np.testing.assert_array_equal(out.nodes["c_relu"].attrs["value"],
                                  np.arange(4, dtype=np.float32))
    assert out.nodes["y"].op == "add"            # depends on x: not folded


def test_constant_fold_executes_correctly_end_to_end():
    g = Graph("fold_exec")
    x = g.input("x", (4,))
    c = g.add("const", [], name="c",
              value=np.asarray([1.0, -2.0, 3.0, -4.0], np.float32))
    cr = g.add("relu", [c], name="cr")
    y = g.add("add", [x, cr], name="y")
    g.mark_output(y)
    e = Engine(g, {})
    xs = np.asarray([[0.5, 0.5, 0.5, 0.5]], np.float32)
    out = e.run_batch({"x": xs}, "flex")
    np.testing.assert_allclose(np.asarray(out["y"]),
                               [[1.5, 0.5, 3.5, 0.5]])


def test_dead_node_elimination_drops_unreachable():
    g = Graph("dce")
    x = g.input("x", (8,))
    live = g.add("relu", [x], name="live")
    dead = g.add("sigmoid", [x], name="dead")
    g.add("exp", [dead], name="dead2")
    g.mark_output(live)
    ctx = PassContext(params={}, assignment={n: "flex" for n in g.order})
    report_graph, report = PassManager().run(g, ctx)
    assert set(report.eliminated) == {"dead", "dead2"}
    assert "dead" not in report_graph.nodes
    assert "dead" not in report_graph.order
    assert "x" in report_graph.nodes            # inputs always survive
    # source graph untouched (the engine's graph is never mutated)
    assert "dead" in g.nodes


def test_dce_keeps_dead_random_nodes():
    """A dead sample_normal must survive DCE: it advances the per-sample
    RNG split chain, so removing it would shift every later random
    node's keys vs the fuse=False plan (bit-exactness contract)."""
    g = Graph("rng")
    mu = g.input("mu", (4,))
    lv = g.input("lv", (4,))
    g.add("sample_normal", [mu, lv], name="dead_sample")
    live = g.add("sample_normal", [mu, lv], name="live_sample")
    g.mark_output(live)
    ctx = PassContext(params={}, assignment={n: "flex" for n in g.order})
    out, report = PassManager().run(g, ctx)
    assert "dead_sample" in out.nodes
    assert "dead_sample" not in report.eliminated
    # numerics: fused and unfused plans draw identical samples
    from repro.models.common import init_graph_params
    e1 = Engine(g, {})
    e0 = Engine(g, {}, fuse=False)
    import jax
    feed = {"mu": np.zeros((2, 4), np.float32),
            "lv": np.zeros((2, 4), np.float32)}
    rngs = jax.random.split(jax.random.PRNGKey(0), 2)
    np.testing.assert_array_equal(
        np.asarray(e1.run_batch(feed, "flex", rngs)["live_sample"]),
        np.asarray(e0.run_batch(feed, "flex", rngs)["live_sample"]))


def test_all_demoted_accel_plan_prices_fp32_weights():
    """An accel plan whose every quantizable node was PTQ-demoted runs
    fp32 — its cost signature must charge fp32 weight widths, not the
    assume-int8 graph approximation."""
    g = _conv_relu_dense_graph()
    e = _engine(g, demote=-1.0)                 # demote everything
    plan = e.planned("accel")
    assert not plan.qplans and plan.demoted
    from repro.core import energy as energy_mod
    # the plan prices with the exact (empty) quantized set — identical
    # to an explicit fp32-widths signature, NOT the assume-int8 default
    assert plan.cost_signature(4) == energy_mod.plan_cost_signature(
        plan.graph, "accel", 4, plan.arena, quantized=set())
    assert energy_mod.weight_bytes(plan.graph, "accel", set()) \
        == energy_mod.weight_bytes(plan.graph, "flex")


def test_dce_keeps_everything_reachable():
    g = Graph("dce_live")
    x = g.input("x", (4,))
    a = g.add("relu", [x], name="a")
    b = g.add("exp", [a], name="b")
    g.mark_output(b)
    ctx = PassContext(params={}, assignment={n: "flex" for n in g.order})
    out, report = PassManager().run(g, ctx)
    assert not report.eliminated
    assert list(out.order) == list(g.order)


# ---------------------------------------------------------------------------
# epilogue fusion
# ---------------------------------------------------------------------------


def _conv_relu_dense_graph():
    g = Graph("fusion")
    x = g.input("x", (12, 12, 4))
    c = g.add("conv2d", [x], name="conv", kernel=(3, 3), features=8)
    r = g.add("relu", [c], name="act")
    p = g.add("maxpool2d", [r], name="pool", kernel=2)
    f = g.add("flatten", [p], name="flat")
    d = g.add("dense", [f], name="head", features=5)
    g.mark_output(d)
    return g


def test_epilogue_fusion_structure_and_params():
    e = _engine(_conv_relu_dense_graph())
    plan = e.planned("accel")
    act = plan.graph.nodes["act"]
    assert act.op == "fused"
    assert base_op(act) == "conv2d"
    assert param_node(act) == "conv"
    assert act.attrs["epilogue"] == ("relu",)
    assert "conv" not in plan.graph.nodes       # producer slot absorbed
    assert [fg.name for fg in plan.pass_report.fusion_groups] == ["act"]
    # ops accounting survives fusion (fused node carries conv + relu ops)
    assert act.macs > 0 and act.ops > act.macs * 2


def test_requant_fusion_through_pool_and_flatten():
    """conv+relu -> maxpool -> flatten -> dense: the producer requantizes
    in-kernel, the chain runs int8, the dense consumes int8 — bit-exact
    vs the unfused plan (monotone quantizer commutes with max/reshape)."""
    g = _conv_relu_dense_graph()
    e = _engine(g)
    plan = e.planned("accel")
    qp = plan.qplans["act"]
    assert qp.requant_scale is not None
    assert plan.qplans["head"].int8_input
    assert plan.graph.nodes["pool"].attrs.get("int8")
    assert plan.graph.nodes["flat"].attrs.get("int8")
    (rq,) = plan.pass_report.requant_groups
    assert rq.producer == "act" and rq.consumers == ("head",)
    assert rq.chain == ("pool", "flat")

    e0 = _engine(_conv_relu_dense_graph(), fuse=False)
    B = 3
    rng = np.random.default_rng(7)
    xs = rng.standard_normal((B, 12, 12, 4)).astype(np.float32)
    a = e.run_batch({"x": xs}, "accel")
    b = e0.run_batch({"x": xs}, "accel")
    np.testing.assert_array_equal(np.asarray(a["head"]),
                                  np.asarray(b["head"]))


def test_sigmoid_epilogue_fuses_onto_accel_dense():
    g = Graph("sig")
    x = g.input("x", (6,))
    d = g.add("dense", [x], name="logit", features=3)
    s = g.add("sigmoid", [d], name="prob")
    g.mark_output(s)
    e = _engine(g)
    plan = e.planned("accel")
    prob = plan.graph.nodes["prob"]
    assert prob.op == "fused" and prob.attrs["epilogue"] == ("sigmoid",)
    # sigmoid moved ONTO the accel segment (it was flex-assigned)
    assert plan.assignment["prob"] == "accel"
    e0 = _engine(_sig_graph_copy(), fuse=False)
    xs = np.random.default_rng(3).standard_normal((4, 6)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(e.run_batch({"x": xs}, "accel")["prob"]),
        np.asarray(e0.run_batch({"x": xs}, "accel")["prob"]))


def _sig_graph_copy():
    g = Graph("sig")
    x = g.input("x", (6,))
    d = g.add("dense", [x], name="logit", features=3)
    g.add("sigmoid", [d], name="prob")
    g.mark_output("prob")
    return g


def test_no_fusion_when_producer_is_output_or_shared():
    g = Graph("shared")
    x = g.input("x", (6,))
    d = g.add("dense", [x], name="d", features=4)
    r = g.add("relu", [d], name="r")
    g.add("exp", [d], name="e2")                # second consumer of d
    g.mark_output(r, "e2")
    e = _engine(g)
    plan = e.planned("accel")
    assert plan.graph.nodes["d"].op == "dense"  # not fused: two consumers
    assert plan.graph.nodes["r"].op == "relu"


def test_no_requant_across_graph_output():
    """A producer whose value is a graph output must keep its fp32
    result — the downlink payload cannot be int8."""
    g = Graph("outp")
    x = g.input("x", (8,))
    d1 = g.add("dense", [x], name="d1", features=8)
    d2 = g.add("dense", [d1], name="d2", features=4)
    g.mark_output(d1, d2)                       # d1 is both output + input
    e = _engine(g)
    plan = e.planned("accel")
    assert plan.qplans["d1"].requant_scale is None
    assert not plan.qplans["d2"].int8_input


def test_fuse_false_runs_no_passes():
    e = _engine(_conv_relu_dense_graph(), fuse=False)
    plan = e.planned("accel")
    assert plan.pass_report is None
    assert plan.arena is None
    assert plan.graph is e.graph
    assert plan.fused_into == {"act": "conv"}   # legacy alias fusion


def test_plan_summary_and_as_text_show_pipeline():
    e = _engine(_conv_relu_dense_graph())
    plan = e.planned("accel")
    s = plan.summary()
    assert "fused [accel] conv + relu -> act" in s
    assert "int8-chain" in s
    assert "arena:" in s
    t = plan.as_text()
    assert "conv2d+relu+requant" in t
    assert "bram@" in t or "ddr(" in t
