"""SSD Pallas kernel vs the naive-recurrence oracle: shape/dtype/chunk
sweeps + the state-continuation property (prefill -> decode handoff)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels.ref import ssd_ref


def _inputs(b, s, h, p, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), dtype)
    B = jnp.asarray(rng.standard_normal((b, s, n)), dtype)
    C = jnp.asarray(rng.standard_normal((b, s, n)), dtype)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.5 + 0.05, jnp.float32)
    A = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.3), jnp.float32)
    return x, B, C, dt, A


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 3, 8, 16, 16),
    (1, 128, 2, 16, 8, 32),
    (2, 96, 1, 8, 8, 32),      # s not a multiple of chunk -> divisor fallback
    (1, 32, 4, 64, 128, 16),   # mamba2-780m head shape
])
def test_ssd_kernel_matches_recurrence(b, s, h, p, n, chunk):
    x, B, C, dt, A = _inputs(b, s, h, p, n, jnp.float32)
    y, final = kops.ssd(x, B, C, dt, A, chunk=chunk)
    y_ref, final_ref = ssd_ref(x, B, C, dt, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_ref),
                               atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_dtypes(dtype):
    x, B, C, dt, A = _inputs(2, 64, 2, 8, 16, dtype)
    y, final = kops.ssd(x, B, C, dt, A, chunk=16)
    y_ref, final_ref = ssd_ref(x, B, C, dt, A)
    assert y.dtype == dtype
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_kernel_state_continuation():
    """Running [0:S/2] then [S/2:S] with the carried state == full run —
    the exact property the prefill->decode handoff relies on."""
    b, s, h, p, n = 1, 64, 2, 8, 16
    x, B, C, dt, A = _inputs(b, s, h, p, n, jnp.float32, seed=3)
    y_full, final_full = kops.ssd(x, B, C, dt, A, chunk=16)
    half = s // 2
    y1, st = kops.ssd(x[:, :half], B[:, :half], C[:, :half], dt[:, :half],
                      A, chunk=16)
    y2, final2 = kops.ssd(x[:, half:], B[:, half:], C[:, half:],
                          dt[:, half:], A, init_state=st, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(final2), np.asarray(final_full),
                               atol=2e-3, rtol=1e-3)


def test_ssd_kernel_matches_xla_chunked():
    """Kernel == the XLA ssd_chunked path (the CPU/dry-run lowering)."""
    from repro.nn.ssm import ssd_chunked
    x, B, C, dt, A = _inputs(2, 128, 3, 8, 16, jnp.float32, seed=7)
    y_k, f_k = kops.ssd(x, B, C, dt, A, chunk=32)
    y_x, f_x = ssd_chunked(x, B, C, dt, A, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_x),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_x),
                               atol=1e-4, rtol=1e-4)
