"""Golden regression fixtures — every space model's outputs for a fixed
PRNG synthetic batch, digested into an in-repo JSON file.

Future kernel/plan/scheduler refactors cannot silently drift numerics:
any change to what the compiled plans actually compute shows up as a
mismatch against ``tests/golden/space_models.json``. Float outputs are
compared at float-associativity tolerance (BLAS/XLA may reorder last-ulp
across hosts); integer outputs (argmax classes) must match exactly.

Regenerate (after an INTENTIONAL numeric change, with justification in
the PR):

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden.py
"""
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.models import SPACE_MODELS

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden" / "space_models.json"
BACKENDS = ("flex", "accel")
BATCH = 2
INPUT_KEY = 123
PARAM_KEY = 0
N_CALIB = 2
MAX_STORED = 64          # per output: head of the flattened array


def _compute_digest():
    digest = {}
    for name in sorted(SPACE_MODELS):
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(PARAM_KEY)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(N_CALIB)])
        inputs = m.synthetic_batch(jax.random.PRNGKey(INPUT_KEY), BATCH)
        rngs = jax.random.split(jax.random.PRNGKey(7), BATCH)
        digest[name] = {}
        for backend in BACKENDS:
            out = e.run_batch(inputs, backend, rngs)
            digest[name][backend] = {}
            for k, v in out.items():
                a = np.asarray(v)
                flat = a.ravel()[:MAX_STORED]
                digest[name][backend][k] = {
                    "shape": list(a.shape),
                    "dtype": str(a.dtype),
                    "sum": float(a.astype(np.float64).sum()),
                    "values": [float(x) for x in flat.astype(np.float64)],
                }
    return digest


@pytest.fixture(scope="module")
def computed():
    return _compute_digest()


def test_golden_fixture_exists_or_regen(computed):
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN_PATH.parent.mkdir(exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(computed, f, indent=1, sort_keys=True)
    assert GOLDEN_PATH.exists(), (
        f"missing {GOLDEN_PATH}; run with REGEN_GOLDEN=1 to create it")


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_outputs_match(name, backend, computed):
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    assert name in golden, f"no golden entry for {name}; REGEN_GOLDEN=1"
    want = golden[name][backend]
    got = computed[name][backend]
    assert set(want) == set(got), (set(want), set(got))
    for k in want:
        w, g = want[k], got[k]
        assert g["shape"] == w["shape"], (name, backend, k)
        assert g["dtype"] == w["dtype"], (name, backend, k)
        if np.issubdtype(np.dtype(w["dtype"]), np.integer):
            np.testing.assert_array_equal(
                g["values"], w["values"],
                err_msg=f"{name}/{backend}/{k} (integer output drifted)")
            assert g["sum"] == w["sum"], (name, backend, k)
        else:
            np.testing.assert_allclose(
                g["values"], w["values"], rtol=1e-4, atol=1e-5,
                err_msg=f"{name}/{backend}/{k} (numeric drift vs golden)")
            np.testing.assert_allclose(
                g["sum"], w["sum"], rtol=1e-4, atol=1e-4,
                err_msg=f"{name}/{backend}/{k} (sum drifted)")
