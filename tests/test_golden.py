"""Golden regression fixtures — every space model's outputs for a fixed
PRNG synthetic batch, digested into an in-repo JSON file.

Future kernel/plan/scheduler refactors cannot silently drift numerics:
any change to what the compiled plans actually compute shows up as a
mismatch against ``tests/golden/space_models.json``. Float outputs are
compared at float-associativity tolerance (BLAS/XLA may reorder last-ulp
across hosts); integer outputs (argmax classes) must match exactly.

Regenerate (after an INTENTIONAL numeric change, with justification in
the PR):

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_golden.py
"""
import json
import os
import pathlib

import jax
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.models import SPACE_MODELS

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden" / "space_models.json"
BACKENDS = ("flex", "accel")
BATCH = 2
INPUT_KEY = 123
PARAM_KEY = 0
N_CALIB = 2
MAX_STORED = 64          # per output: head of the flattened array


def _compute_digest():
    digest = {}
    for name in sorted(SPACE_MODELS):
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(PARAM_KEY)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(N_CALIB)])
        inputs = m.synthetic_batch(jax.random.PRNGKey(INPUT_KEY), BATCH)
        rngs = jax.random.split(jax.random.PRNGKey(7), BATCH)
        digest[name] = {}
        for backend in BACKENDS:
            out = e.run_batch(inputs, backend, rngs)
            digest[name][backend] = {}
            for k, v in out.items():
                a = np.asarray(v)
                flat = a.ravel()[:MAX_STORED]
                digest[name][backend][k] = {
                    "shape": list(a.shape),
                    "dtype": str(a.dtype),
                    "sum": float(a.astype(np.float64).sum()),
                    "values": [float(x) for x in flat.astype(np.float64)],
                }
    return digest


@pytest.fixture(scope="module")
def computed():
    return _compute_digest()


def test_golden_fixture_exists_or_regen(computed):
    if os.environ.get("REGEN_GOLDEN"):
        GOLDEN_PATH.parent.mkdir(exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(computed, f, indent=1, sort_keys=True)
    assert GOLDEN_PATH.exists(), (
        f"missing {GOLDEN_PATH}; run with REGEN_GOLDEN=1 to create it")


def _compare(want, got, name, backend):
    """The golden comparison: exact shape/dtype, exact integer outputs,
    float-associativity tolerance on float outputs. Raises AssertionError
    naming the (model, backend, output) on any drift — also reused by
    the in-band serving canaries' test coverage below."""
    assert set(want) == set(got), (set(want), set(got))
    for k in want:
        w, g = want[k], got[k]
        assert g["shape"] == w["shape"], (name, backend, k)
        assert g["dtype"] == w["dtype"], (name, backend, k)
        if np.issubdtype(np.dtype(w["dtype"]), np.integer):
            np.testing.assert_array_equal(
                g["values"], w["values"],
                err_msg=f"{name}/{backend}/{k} (integer output drifted)")
            assert g["sum"] == w["sum"], (name, backend, k)
        else:
            np.testing.assert_allclose(
                g["values"], w["values"], rtol=1e-4, atol=1e-5,
                err_msg=f"{name}/{backend}/{k} (numeric drift vs golden)")
            np.testing.assert_allclose(
                g["sum"], w["sum"], rtol=1e-4, atol=1e-4,
                err_msg=f"{name}/{backend}/{k} (sum drifted)")


@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_outputs_match(name, backend, computed):
    with open(GOLDEN_PATH) as f:
        golden = json.load(f)
    assert name in golden, f"no golden entry for {name}; REGEN_GOLDEN=1"
    _compare(golden[name][backend], computed[name][backend], name, backend)


# -- the mismatch path itself (a comparison that cannot fail detects
# nothing — ISSUE 7 exercises the detector, not just the happy path) ----


def test_golden_mismatch_is_detected(computed):
    name = sorted(SPACE_MODELS)[0]
    want = computed[name]["accel"]
    drifted = json.loads(json.dumps(want))       # deep copy via JSON
    k = sorted(drifted)[0]
    drifted[k]["values"][0] += 1.0
    drifted[k]["sum"] += 1.0
    with pytest.raises(AssertionError, match=f"{name}/accel/{k}"):
        _compare(want, drifted, name, "accel")
    wrong_shape = json.loads(json.dumps(want))
    wrong_shape[k]["shape"] = [9999]
    with pytest.raises(AssertionError):
        _compare(want, wrong_shape, name, "accel")
    missing = {f"not_{k}": v for k, v in want.items()}
    with pytest.raises(AssertionError):
        _compare(want, missing, name, "accel")


def test_regen_roundtrip_reproduces_passing_fixture(computed, tmp_path):
    """What REGEN_GOLDEN=1 writes must round-trip through JSON into a
    fixture the comparison accepts verbatim — regeneration can never
    produce a file that immediately fails its own suite."""
    path = tmp_path / "space_models.json"
    with open(path, "w") as f:
        json.dump(computed, f, indent=1, sort_keys=True)
    with open(path) as f:
        reloaded = json.load(f)
    assert sorted(reloaded) == sorted(SPACE_MODELS)
    for name in reloaded:
        for backend in BACKENDS:
            _compare(reloaded[name][backend], computed[name][backend],
                     name, backend)
