"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.optim.compress import (ErrorFeedback, ef_compress, decompress_tree,
                                  int8_compress, int8_decompress)
from repro.parallel.sharding import (MULTI_POD_RULES, SINGLE_POD_RULES,
                                     spec_for)
from repro.runtime.fault_tolerance import (detect_stragglers,
                                           elastic_mesh_shape,
                                           rebalance_batch)

# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64),
       st.floats(1e-3, 1e3), st.integers(0, 2 ** 31 - 1))
def test_quantize_roundtrip_bound(m, n, scale_mag, seed):
    """|x - dequant(quant(x))| <= column_scale/2 for every element."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, n)) * scale_mag, jnp.float32)
    q, s = ref.quantize_ref(x, axis=0)
    deq = ref.dequantize_ref(q, s, axis=0)
    err = np.abs(np.asarray(x) - np.asarray(deq))
    bound = np.asarray(s)[None, :] * 0.5 + 1e-6
    assert (err <= bound).all()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 256), st.integers(0, 2 ** 31 - 1))
def test_int8_compress_4x_and_bound(n, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    q, s = int8_compress(g)
    assert q.dtype == jnp.int8                    # 4x fewer wire bytes
    back = int8_decompress(q, s)
    assert float(jnp.max(jnp.abs(g - back))) <= float(s) * 0.5 + 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 50), st.integers(0, 2 ** 31 - 1))
def test_error_feedback_bounded_residual(steps, seed):
    """EF-SGD invariant: the residual never exceeds one quantization step,
    so compressed updates sum to the true gradient up to O(scale)."""
    rng = np.random.default_rng(seed)
    g_true = jnp.asarray(rng.standard_normal(8), jnp.float32)
    ef = ErrorFeedback.init({"w": g_true})
    total = np.zeros(8, np.float32)
    for _ in range(steps):
        comp, ef = ef_compress({"w": g_true}, ef)
        total += np.asarray(decompress_tree(comp)["w"])
    # sum of decompressed == steps * g_true - final residual
    expect = steps * np.asarray(g_true) - np.asarray(ef.residual["w"])
    np.testing.assert_allclose(total, expect, atol=1e-4)
    q, s = int8_compress(g_true + ef.residual["w"])
    assert np.abs(np.asarray(ef.residual["w"])).max() <= float(s) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# sharding resolution
# ---------------------------------------------------------------------------

MESHES = st.sampled_from([(16, 16), (2, 16, 16), (4, 8), (2, 4, 4)])
LOGICALS = st.lists(
    st.sampled_from([None, "batch", "seq", "heads", "ffn", "vocab", "embed",
                     "expert", "kv_heads"]),
    min_size=1, max_size=4)


def _mk_mesh(shape):
    names = ("pod", "data", "model")[-len(shape):]
    devs = np.arange(int(np.prod(shape))).reshape(shape)
    # avoid building real device meshes in the property test: spec_for only
    # reads mesh.shape / axis names
    class FakeMesh:
        pass
    m = FakeMesh()
    m.shape = dict(zip(names, shape))
    m.axis_names = names
    return m


@settings(max_examples=200, deadline=None)
@given(MESHES, LOGICALS,
       st.lists(st.integers(1, 4096), min_size=1, max_size=4))
def test_spec_for_invariants(mesh_shape, logical, dims):
    """1) sharded dims always divide the mesh-axis product;
       2) no mesh axis is used twice;  3) rank is preserved."""
    n = min(len(logical), len(dims))
    logical, dims = logical[:n], dims[:n]
    mesh = _mk_mesh(mesh_shape)
    rules = MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES
    spec = spec_for(dims, logical, mesh, rules)
    assert len(spec) == n
    used = []
    for dim, part in zip(dims, spec):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
            used.append(a)
        assert dim % prod == 0, (dim, axes, prod)
    assert len(used) == len(set(used)), f"mesh axis reused: {spec}"


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------


@settings(max_examples=200, deadline=None)
@given(st.integers(1, 2048), st.sampled_from([4, 8, 16]),
       st.sampled_from([64, 256]))
def test_elastic_mesh_invariants(alive, model_degree, pod_size):
    if alive < model_degree:
        try:
            elastic_mesh_shape(alive, model_degree, pod_size)
            assert False, "expected unrecoverable"
        except RuntimeError:
            return
    pods, data, model = elastic_mesh_shape(alive, model_degree, pod_size)
    assert model == model_degree                  # TP never resharded
    assert pods * data * model <= max(alive, pod_size * pods)
    assert pods * data * model <= alive or pods * pod_size <= alive
    assert pods >= 1 and data >= 1


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 4096))
def test_rebalance_batch_keeps_per_replica(old_data, new_data, per):
    gb = per * old_data
    nb = rebalance_batch(gb, old_data, new_data)
    assert nb == per * new_data                   # per-replica batch constant


def test_detect_stragglers_median_rule():
    times = {f"h{i}": 1.0 for i in range(8)}
    times["h3"] = 3.5
    assert detect_stragglers(times) == ["h3"]
    assert detect_stragglers({"a": 1.0, "b": 9.0}) == []   # <3 hosts: no-op


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_data_pipeline_step_seeded(step):
    """Restart determinism: batch(step) is a pure function of step."""
    from repro.configs import get_arch, reduced
    from repro.configs.base import ShapeSpec
    from repro.data.pipeline import DataConfig, synthetic_batch
    from repro.nn.dims import compute_dims
    cfg = reduced(get_arch("tinyllama-1.1b"))
    dims = compute_dims(cfg, tp=1)
    shape = ShapeSpec("t", 32, 4, "train")
    a = synthetic_batch(step, cfg, dims, shape, DataConfig())
    b = synthetic_batch(step, cfg, dims, shape, DataConfig())
    for k in a:
        np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


# ---------------------------------------------------------------------------
# continuous-batching scheduler invariants
# ---------------------------------------------------------------------------

_SCHED_LADDER = (1, 4, 8)
_sched_engines = {}


def _scheduler_engines():
    """Two cheap space models + canned requests, built once — every
    hypothesis example reuses the engines (and their plan caches)."""
    if not _sched_engines:
        from repro.core.engine import Engine
        from repro.models import SPACE_MODELS
        for name in ("logistic_net", "multi_esperta"):
            m = SPACE_MODELS[name]
            e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
            reqs = [{k: np.asarray(v)
                     for k, v in m.synthetic_input(
                         jax.random.PRNGKey(i)).items()}
                    for i in range(8)]
            _sched_engines[name] = (e, reqs)
    return _sched_engines


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1),
       st.integers(2, 16),                 # requests per model (<= 2 batches)
       st.floats(0.02, 0.2))               # per-use-case deadline (s)
def test_scheduler_schedule_invariants(seed, n_per_model, deadline_s):
    """Under random arrival orders and queue depths:
    1) no request is dropped or duplicated,
    2) every dispatched batch size is a ladder rung (with 1 <= real
       requests <= rung),
    3) per model, requests are dispatched in arrival (FIFO) order, and
    4) no kept request exceeds its deadline by more than one dispatch
       interval per batch that could be ahead of it (the deadline-flush
       guarantee: once a request is due, the server never idles)."""
    from repro.core.scheduler import ContinuousBatchingScheduler
    engines = _scheduler_engines()
    rng = np.random.default_rng(seed)
    sched = ContinuousBatchingScheduler()
    trace = []
    for name, (e, reqs) in engines.items():
        sched.register(name, e, backend="flex", ladder=_SCHED_LADDER,
                       deadline_s=deadline_s)
        times = np.sort(rng.uniform(0.0, 0.25, size=n_per_model))
        trace += [(float(t), name, reqs[i % len(reqs)])
                  for i, t in enumerate(times)]
    sched.serve_trace(trace)

    # 1) nothing dropped, nothing duplicated
    rids = [c.rid for c in sched.completions]
    assert len(rids) == len(trace)
    assert len(set(rids)) == len(rids)

    # 2) ladder rungs only
    assert sched.dispatches
    for d in sched.dispatches:
        assert d.rung in _SCHED_LADDER
        assert 1 <= d.n_real <= d.rung

    # 3) FIFO within each model (completions append in dispatch order)
    for name in engines:
        got = [c.rid for c in sched.completions if c.model == name]
        assert got == sorted(got)

    # 4) bounded deadline overshoot: n_per_model <= 2 top rungs, so at
    #    most 2 batches/model can be queued ahead when a request comes
    #    due; with round-robin over both models that is <= 4 dispatch
    #    intervals of slack before it must have been flushed.
    max_service = max(d.service_time for d in sched.dispatches)
    slack = 2 * len(engines) * max_service + 1e-6
    for c in sched.completions:
        if c.kept:
            assert c.finished <= c.deadline + slack, (
                c.model, c.rid, c.finished - c.deadline, slack)


# ---------------------------------------------------------------------------
# orbit-aware radiation environment (DESIGN.md §16)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.floats(0.1, 50.0), st.floats(1.0, 200.0),
       st.integers(0, 2 ** 31 - 1))
def test_radiation_rate_never_exceeds_thinning_bound(base, saa, seed):
    """The NHPP thinning envelope really is an envelope: rate(t) <=
    rate_bound() everywhere, for any base rate / SAA multiplier."""
    from repro.core.radiation import RadiationEnvironment
    env = RadiationEnvironment(base_rate=base, saa_factor=saa)
    bound = env.rate_bound()
    rng = np.random.default_rng(seed)
    for t in rng.uniform(0.0, 10.0 * env.orbit_s, size=256):
        assert env.rate(float(t)) <= bound * (1 + 1e-12) + 1e-12


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(0.25, 6.0))
def test_radiation_sampling_deterministic_sorted_typed(seed, horizon):
    """sample_upsets is a pure function of (seed, horizon): bit-equal on
    replay, time-sorted, inside the horizon, and every event carries a
    well-formed class (span inside mbu_span, target a known subsystem)."""
    from repro.core.radiation import CONTROL_TARGETS, RadiationEnvironment
    env = RadiationEnvironment()
    a = env.sample_upsets(seed, horizon)
    assert a == env.sample_upsets(seed, horizon)
    ts = [ev.t for ev in a]
    assert ts == sorted(ts)
    assert all(0.0 <= t < horizon for t in ts)
    for ev in a:
        if ev.kind == "mbu":
            assert env.mbu_span[0] <= ev.span <= env.mbu_span[1]
        elif ev.kind == "control":
            assert ev.target in CONTROL_TARGETS
        else:
            assert ev.kind == "single" and ev.span == 1 and ev.target == ""


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_radiation_saa_density_exceeds_quiet_density(seed):
    """Orbit-awareness is visible in the samples: the per-second upset
    density inside the SAA window beats the sunlight-phase density by a
    wide margin (x40 rate multiplier, asserted at >= 3x with Poisson
    slack over 10 orbits)."""
    from repro.core.radiation import RadiationEnvironment
    env = RadiationEnvironment()            # SAA x40 over 0.12 s/orbit
    n_orbits = 10
    evs = env.sample_upsets(seed, n_orbits * env.orbit_s)
    n_saa = sum(1 for ev in evs if env.in_saa(ev.t))
    n_sun = sum(1 for ev in evs
                if env.phase_of(ev.t) == "sunlight" and not env.in_saa(ev.t))
    saa_w = (env.saa_window[1] - env.saa_window[0]) * n_orbits
    sun_w = 0.25 * n_orbits                 # 0.15 + 0.10 s of sunlight
    assert n_saa / saa_w > 3.0 * max(n_sun / sun_w, env.base_rate * 0.25)


# ---------------------------------------------------------------------------
# opgraph shape inference vs execution
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_graph_shape_inference_matches_execution(seed):
    """Inferred out_shape equals the actual executed shape for every node
    of a randomly-chosen space model."""
    from repro.core.engine import OP_IMPLS
    from repro.models import SPACE_MODELS
    rng = np.random.default_rng(seed)
    name = sorted(SPACE_MODELS)[seed % len(SPACE_MODELS)]
    m = SPACE_MODELS[name]
    g = m.build_graph()
    params = m.init_params(jax.random.PRNGKey(seed % 997))
    inputs = m.synthetic_input(jax.random.PRNGKey((seed + 1) % 997))
    vals = {k: jnp.asarray(inputs[k], jnp.float32) for k in g.graph_inputs}
    key = jax.random.PRNGKey(0)
    for node_name in g.order:
        node = g.nodes[node_name]
        if node.op == "input":
            continue
        key, sub = jax.random.split(key)
        vals[node_name] = OP_IMPLS[node.op](
            [vals[i] for i in node.inputs], params.get(node_name, {}),
            node.attrs, sub)
        assert tuple(vals[node_name].shape) == tuple(node.out_shape), (
            name, node_name, node.op, vals[node_name].shape, node.out_shape)
