"""LM serving fast path (DESIGN.md §15): flash-attention decode
equivalences, the int8 KV quantizer's f16-underflow regression, and the
decoder block through the compiled prefill/decode ladder."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lm_quant
from repro.core.engine import Engine
from repro.core.lm import LMEngine
from repro.core.plan import CompiledPlan, ExecutionPlan, LoweredPlan
from repro.core.scheduler import LMRequest, LMScheduler
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.models import lm as lm_model


# ---------------------------------------------------------------------------
# flash attention: ragged lengths + incremental decode equivalence
# ---------------------------------------------------------------------------


def _qkv(rng, s, hq, hkv, hd, b=1):
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("s,bq,bk", [
    (100, 64, 64),          # pad both grid axes
    (72, 32, 64),           # pad K only
    (65, 64, 64),           # one position past a block boundary
    (31, 64, 64),           # shorter than one block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_ragged_lengths(s, bq, bk, causal):
    """Non-multiple-of-block sequence lengths: the kernel pads to the
    grid and masks the padded K positions; output matches the ref."""
    rng = np.random.default_rng(s)
    q, k, v = _qkv(rng, s, 2, 1, 16)
    got = kops.flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert got.shape == want.shape == (1, s, 2, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def _single_token_attend(q_t, k_pref, v_pref):
    """Decode-style attend of one query over its full prefix (no mask:
    the prefix IS the causal set). q_t [H,hd], k/v [L,Hkv,hd]."""
    g = q_t.shape[0] // k_pref.shape[1]
    k_r = jnp.repeat(k_pref, g, axis=1)
    v_r = jnp.repeat(v_pref, g, axis=1)
    s = jnp.einsum("hd,lhd->hl", q_t, k_r) * (q_t.shape[-1] ** -0.5)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hl,lhd->hd", p, v_r)


@pytest.mark.parametrize("kv_int8", [False, True])
def test_incremental_decode_matches_full_recompute(kv_int8):
    """Token-at-a-time decode over a growing prefix equals the last row
    of a full causal recompute — with and without the int8 KV cache
    round-trip (both sides must see the SAME dequantized K/V)."""
    rng = np.random.default_rng(7)
    s, hq, hkv, hd = 40, 4, 2, 8
    q, k, v = _qkv(rng, s, hq, hkv, hd)
    if kv_int8:
        k = lm_quant.dequantize_kv(*lm_quant.quantize_kv(k), jnp.float32)
        v = lm_quant.dequantize_kv(*lm_quant.quantize_kv(v), jnp.float32)
    full = ref.flash_attention_ref(q, k, v, causal=True)
    for t in (0, 1, 17, s - 1):
        inc = _single_token_attend(q[0, t], k[0, :t + 1], v[0, :t + 1])
        np.testing.assert_allclose(np.asarray(inc), np.asarray(full[0, t]),
                                   rtol=1e-5, atol=1e-5)


def test_hypothesis_ragged_flash_matches_ref():
    hyp = pytest.importorskip("hypothesis",
                              reason="hypothesis not installed")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 96), st.sampled_from([1, 2, 4]),
           st.sampled_from([8, 16]), st.sampled_from([16, 32, 64]),
           st.booleans(), st.integers(0, 2 ** 31 - 1))
    def prop(s, hq, hd, blk, causal, seed):
        rng = np.random.default_rng(seed)
        hkv = 1 if hq == 1 else hq // 2
        q, k, v = _qkv(rng, s, hq, hkv, hd)
        got = kops.flash_attention(q, k, v, causal=causal, bq=blk, bk=blk)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)

    prop()


# ---------------------------------------------------------------------------
# quantize_kv: the all-zero-tile / f16-underflow regression
# ---------------------------------------------------------------------------


def test_quantize_kv_zero_tile_survives_f16_scale_plane():
    """Pre-fix, an all-zero tile got scale ~1e-12, which underflows to
    exactly 0.0 in the f16 scale planes the KV arena stores — and a zero
    scale turns the inverse into inf/NaN. The fix pins zero tiles to
    scale 1.0 (lossless for zeros). This test fails on the pre-fix code
    at the f16 assertions."""
    x = jnp.zeros((2, 5, 3, 8), jnp.float32)
    q, s = lm_quant.quantize_kv(x)
    assert np.array_equal(np.asarray(q), np.zeros_like(q))
    np.testing.assert_array_equal(np.asarray(s), 1.0)
    s16 = np.asarray(s).astype(np.float16)
    assert (s16 > 0).all()                       # underflow check
    assert np.isfinite(1.0 / s16).all()          # inverse stays finite
    back = lm_quant.dequantize_kv(q, jnp.asarray(s16), jnp.float32)
    np.testing.assert_array_equal(np.asarray(back), 0.0)


def test_quantize_kv_mixed_zero_rows_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1, 4, 2, 8)), jnp.float32)
    x = x.at[0, 1].set(0.0).at[0, 3, 0].set(0.0)
    q, s = lm_quant.quantize_kv(x)
    assert np.isfinite(np.asarray(s)).all() and (np.asarray(s) > 0).all()
    back = lm_quant.dequantize_kv(q, s, jnp.float32)
    # zero rows exact, non-zero rows within one quantization step
    np.testing.assert_array_equal(np.asarray(back[0, 1]), 0.0)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(jnp.max(jnp.abs(x))) / 127 + 1e-7)


# ---------------------------------------------------------------------------
# the decoder block through the staged chain + serving ladder
# ---------------------------------------------------------------------------


CFG = lm_model.DEFAULT_CONFIG


@pytest.fixture(scope="module")
def lm_setup():
    graph = lm_model.build_graph(CFG)
    params = lm_model.init_params(jax.random.PRNGKey(0), CFG)
    engine = Engine(graph, params)
    calib = [lm_model.synthetic_input(k, CFG) for k in
             jax.random.split(jax.random.PRNGKey(1), 4)]
    engine.calibrate(calib)
    return LMEngine(engine, backend="accel", n_slots=3, max_new_tokens=8)


def _prompts(n, seed=11):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, CFG.seq_len, CFG.d_model)
                      ).astype(np.float32) * 0.5


def test_decoder_block_compiles_staged_chain(lm_setup):
    lm = lm_setup
    planned = lm.engine.planned("accel")
    assert isinstance(planned, ExecutionPlan)
    lowered = planned.lower(2)
    assert isinstance(lowered, LoweredPlan)
    compiled = lowered.compile()
    assert isinstance(compiled, CompiledPlan)
    # partial offload: accel projections around flex attention/ssm
    backends = [seg.backend for seg in planned.segments]
    assert "flex" in backends and "accel" in backends
    assert planned.assignment["attn"] == "flex"
    assert planned.assignment["ssm"] == "flex"
    # the pass pipeline annotated the attention node for int8 KV
    assert "attn" in planned.pass_report.kv_int8_nodes
    assert planned.graph.nodes["attn"].attrs["kv_int8"] is True
    # the requant chain runs straight through the QKV projections
    chained = set()
    for rq in planned.pass_report.requant_groups:
        chained.update(rq.consumers)
    assert {"q_proj", "k_proj", "v_proj"} <= chained


def test_kv_plan_charged_to_budget_and_signature(lm_setup):
    lm = lm_setup
    sig = lm.plan.cost_signature(2)
    assert sig.kv_resident_bytes == float(lm.kv_plan.total_bytes) > 0
    assert "kv[" in lm.plan.summary()
    # 3 slots + tile-aligned capacity
    assert lm.kv_plan.n_slots == 3
    assert lm.kv_plan.capacity % 128 == 0
    assert lm.kv_plan.capacity >= CFG.seq_len + lm.max_new_tokens


def test_prefill_decode_steady_state_counters(lm_setup):
    lm = lm_setup
    x = _prompts(2)
    slots = np.array([lm.assign_slot("a"), lm.assign_slot("b")], np.int32)
    res = lm.prefill(x, slots)
    assert res.tokens.shape == (2,) and res.hidden.shape == (2, CFG.d_model)
    res = lm.decode_step(res.hidden, slots)      # warm the rung
    traces0, assigns0 = lm.n_traces, lm.slots.n_assigns
    for _ in range(4):
        res = lm.decode_step(res.hidden, slots)
        assert np.isfinite(res.hidden).all()
        assert (0 <= res.tokens).all() and (res.tokens < CFG.vocab).all()
    assert lm.n_traces == traces0                # zero re-traces
    assert lm.slots.n_assigns == assigns0        # zero slot allocations
    assert lm.release_slot("a") == slots[0]
    assert lm.release_slot("b") == slots[1]


def test_prefill_cache_codes_match_direct_quantize(lm_setup):
    lm = lm_setup
    x = _prompts(2, seed=12)
    slots = np.array([lm.assign_slot("c"), lm.assign_slot("d")], np.int32)
    lm.prefill(x, slots)
    outs = lm.engine.run_batch({"x": x}, "accel")
    codes, scale = lm_quant.quantize_kv(outs["k_heads"])
    got = np.asarray(lm.caches["attn"]["k_codes"])[slots, :CFG.seq_len]
    assert np.array_equal(got, np.asarray(codes))
    got_s = np.asarray(lm.caches["attn"]["k_scale"])[slots, :CFG.seq_len]
    assert np.array_equal(got_s, np.asarray(scale).astype(np.float16))
    lm.release_slot("c"), lm.release_slot("d")


def test_scheduler_serves_stream_and_releases_slots(lm_setup):
    lm = lm_setup
    sched = LMScheduler(lm)
    for rid in range(5):
        sched.submit(LMRequest(rid=rid, x=_prompts(1, seed=rid)[0],
                               max_new_tokens=3))
    comps = sched.run()
    assert len(comps) == 5
    assert sorted(c.rid for c in comps) == list(range(5))
    assert all(len(c.tokens) == 3 for c in comps)
    assert lm.slots.in_use == 0                  # all slots released
    tel = sched.telemetry()
    assert tel.n_completed == 5 and tel.n_tokens == 15
    assert tel.n_prefill_dispatches >= 1
    assert tel.n_decode_dispatches >= 2
    assert tel.tokens_per_s > 0
    # token stream: each request streams max_new_tokens events in order
    per_rid = {}
    for ev in sched.events:
        per_rid.setdefault(ev.rid, []).append(ev.index)
    assert all(idx == list(range(3)) for idx in per_rid.values())


def test_scheduler_validates_requests(lm_setup):
    sched = LMScheduler(lm_setup)
    with pytest.raises(ValueError, match="prompt window"):
        sched.submit(LMRequest(rid=0, x=np.zeros((3, 3), np.float32)))
    with pytest.raises(ValueError, match="decode budget"):
        sched.submit(LMRequest(rid=1, x=_prompts(1)[0],
                               max_new_tokens=10 ** 6))


def test_lm_engine_requires_fuse():
    graph = lm_model.build_graph(CFG)
    params = lm_model.init_params(jax.random.PRNGKey(0), CFG)
    with pytest.raises(ValueError, match="fuse=True"):
        LMEngine(Engine(graph, params, fuse=False))


def test_lm_autotuner_tunes_attention_and_ssd_blocks():
    graph = lm_model.build_graph(CFG)
    params = lm_model.init_params(jax.random.PRNGKey(0), CFG)
    engine = Engine(graph, params, autotune=True)
    plan = engine.planned("flex")
    plan.lower(2)
    decisions = plan._tuning[2]
    kinds = {decisions[n].kind for n in ("attn", "ssm")}
    assert kinds == {"attention", "ssd"}
    att = decisions["attn"].config
    assert att.bq > 0 and att.bk > 0
    assert decisions["ssm"].config.chunk > 0
    # tuning is numerics-neutral metadata: the tuned text mentions it
    assert "blocks bq=" in plan.as_text() and "chunk" in plan.as_text()
