"""Mathematical invariants of the LM substrate.

* causality — future tokens cannot influence past logits (all families)
* prefill/decode consistency — decoding token S against a prefilled cache
  matches the full-sequence forward at position S
* SSD chunked scan == naive recurrence oracle
* chunked attention == naive attention
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.nn import model as model_lib
from repro.nn.dims import compute_dims
from repro.nn.ssm import ssd_chunked

FAMILIES = ["tinyllama-1.1b", "llama4-scout-17b-a16e", "mamba2-780m",
            "zamba2-1.2b"]


def _setup(arch_id, key=0):
    cfg = reduced(get_arch(arch_id))
    dims = compute_dims(cfg, tp=1)
    params = model_lib.init_params(cfg, dims, jax.random.PRNGKey(key))
    return cfg, dims, params


@pytest.mark.parametrize("arch_id", FAMILIES)
def test_causality(arch_id):
    # b=1: capacity-based MoE dispatch legitimately couples sequences in a
    # batch (an eviction in row 0 can displace row 1's expert slot), so
    # causality is an intra-sequence invariant. See nn/moe.py docstring.
    cfg, dims, params = _setup(arch_id)
    b, s = 1, 32
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits1 = model_lib.forward(params, toks, cfg, dims, mode="train",
                                remat=False)
    # perturb the LAST token; logits at positions < s-1 must not move
    toks2 = toks.at[:, -1].set((toks[:, -1] + 7) % cfg.vocab_size)
    logits2 = model_lib.forward(params, toks2, cfg, dims, mode="train",
                                remat=False)
    np.testing.assert_allclose(
        np.asarray(logits1[:, :-1], np.float32),
        np.asarray(logits2[:, :-1], np.float32), atol=1e-2)


@pytest.mark.parametrize("arch_id", FAMILIES)
def test_prefill_decode_consistency(arch_id):
    """logits(prefill S tokens, decode token S) == logits(forward S+1)."""
    cfg, dims, params = _setup(arch_id)
    b, s = 2, 33
    key = jax.random.PRNGKey(2)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)

    full = model_lib.forward(params, toks, cfg, dims, mode="train",
                             remat=False)
    _, cache = model_lib.forward(params, toks[:, :-1], cfg, dims,
                                 mode="prefill", s_max=s)
    dec_logits, _ = model_lib.decode(params, toks[:, -1:], cache,
                                     jnp.int32(s - 1), cfg, dims)
    a = np.asarray(full[:, -1], np.float32)
    c = np.asarray(dec_logits[:, 0], np.float32)
    # bf16 accumulation differences across two codepaths
    np.testing.assert_allclose(a, c, atol=0.15, rtol=0.05)


def test_ssd_chunked_matches_naive_recurrence():
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 64, 3, 8, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, n)), jnp.float32)
    dt = jnp.asarray(rng.random((b, s, h)) * 0.5 + 0.1, jnp.float32)
    A = jnp.asarray(-np.exp(rng.standard_normal(h) * 0.3), jnp.float32)

    for chunk in (8, 16, 64):
        y, final = ssd_chunked(x, B, C, dt, A, chunk=chunk)
        # naive recurrence
        state = np.zeros((b, h, p, n), np.float32)
        ys = np.zeros((b, s, h, p), np.float32)
        xn, Bn, Cn, dtn, An = map(np.asarray, (x, B, C, dt, A))
        for t in range(s):
            decay = np.exp(dtn[:, t] * An)                     # [b,h]
            state = state * decay[:, :, None, None] + np.einsum(
                "bh,bn,bhp->bhpn", dtn[:, t], Bn[:, t], xn[:, t])
            ys[:, t] = np.einsum("bn,bhpn->bhp", Cn[:, t], state)
        np.testing.assert_allclose(np.asarray(y), ys, atol=2e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(final), state, atol=2e-3,
                                   rtol=1e-3)


def test_chunked_attention_matches_naive():
    from repro.nn.attention import _attend_chunked, _attend_naive, _group
    rng = np.random.default_rng(1)
    b, s, hq, hkv, hd = 2, 128, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    qg = _group(q, hkv)
    naive = _attend_naive(qg, k, v, hd ** -0.5)
    chunked = _attend_chunked(qg, k, v, hd ** -0.5, chunk=32)
    np.testing.assert_allclose(np.asarray(naive), np.asarray(chunked),
                               atol=1e-5, rtol=1e-5)


def test_param_count_analytic_vs_actual():
    """ArchConfig.param_count() (used for 6ND roofline) tracks real
    parameter tensors within the padding margin."""
    from repro.nn.params import count_params
    from repro.nn.model import model_spec
    for arch_id in ["tinyllama-1.1b", "qwen1.5-0.5b", "mamba2-780m"]:
        cfg = get_arch(arch_id)
        dims = compute_dims(cfg, tp=1)
        actual = count_params(model_spec(cfg, dims))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / analytic < 0.02, (
            arch_id, actual, analytic)
