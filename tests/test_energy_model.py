"""Unit + property tests for the energy cost model and the power
envelope (DESIGN.md §9).

Cost model: E = P x t consistency of every cost signature; J/inference
monotone non-increasing in batch size (per-dispatch staging amortizes,
per-sample work doesn't grow); weight-residency charging follows the
BRAM policy documented in ``energy.py`` (params over the on-chip budget
stream per inference, resident params are amortized away).

Envelope: admission-time checking means the recorded ledger NEVER
exceeds the sustained budget over any trailing window (verified both by
``audit`` and by brute-force sampling, under hypothesis-random draw
sequences); ``next_admit`` returns a genuinely admissible time; budget
steps scheduled in the future are respected at admission (the
pre-eclipse power-down); and scheduling under an INFINITE envelope is
dispatch-for-dispatch identical to the PR-2 (no-envelope) policy.
"""
import math

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # pragma: no cover - CI installs it
    # only the @given property tests need hypothesis; the unit tests in
    # this module must still run where it is absent
    def given(*_a, **_k):
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    def settings(*_a, **_k):
        return lambda f: f

    class st:                           # noqa: N801 - stand-in namespace
        @staticmethod
        def _none(*_a, **_k):
            return None
        integers = floats = sampled_from = lists = _none

from repro.core.energy import (BACKEND_HW, HardwareModel, PowerEnvelope,
                               cost_signature)
from repro.models import SPACE_MODELS

RUNGS = (1, 2, 4, 8, 16, 32, 64)
MODEL_NAMES = sorted(SPACE_MODELS)
_GRAPHS = {}


def _graph(name):
    if name not in _GRAPHS:
        _GRAPHS[name] = SPACE_MODELS[name].build_graph()
    return _GRAPHS[name]


# ---------------------------------------------------------------------------
# cost signatures
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", sorted(BACKEND_HW))
@pytest.mark.parametrize("name", MODEL_NAMES)
def test_cost_signature_e_equals_p_times_t(name, backend):
    """E = P x t plus the off-chip access energy of the moved bytes (the
    DDR term is what makes fusion's byte savings show up in joules even
    for compute-bound graphs)."""
    for rung in RUNGS:
        sig = cost_signature(_graph(name), backend, rung)
        hw = BACKEND_HW[backend]
        assert sig.ddr_energy_j == pytest.approx(
            sig.bytes_moved * hw.ddr_pj_per_byte, rel=1e-12)
        assert sig.energy_j == pytest.approx(
            sig.power_w * sig.latency_s + sig.ddr_energy_j, rel=1e-12)
        assert sig.j_per_inference == pytest.approx(sig.energy_j / rung,
                                                    rel=1e-12)
        assert sig.flops == pytest.approx(_graph(name).n_ops * rung)
        assert sig.latency_s > 0 and sig.power_w > 0


@pytest.mark.parametrize("backend", sorted(BACKEND_HW))
@pytest.mark.parametrize("name", MODEL_NAMES)
def test_j_per_inference_monotone_in_batch(name, backend):
    """Bigger dispatches never cost MORE energy per inference: the
    per-dispatch staging overhead amortizes and nothing else grows."""
    sigs = [cost_signature(_graph(name), backend, r) for r in RUNGS]
    for a, b in zip(sigs, sigs[1:]):
        assert b.j_per_inference <= a.j_per_inference * (1 + 1e-12), (
            name, backend, a.batch, b.batch)
        assert b.latency_s / b.batch <= a.latency_s / a.batch * (1 + 1e-12)


@settings(max_examples=40, deadline=None)
@given(st.sampled_from(MODEL_NAMES), st.sampled_from(sorted(BACKEND_HW)),
       st.integers(1, 256), st.integers(1, 256))
def test_j_per_inference_monotone_property(name, backend, b1, b2):
    if b1 > b2:
        b1, b2 = b2, b1
    s1 = cost_signature(_graph(name), backend, b1)
    s2 = cost_signature(_graph(name), backend, b2)
    assert s2.j_per_inference <= s1.j_per_inference * (1 + 1e-12)


def test_weight_residency_follows_bram_policy():
    """Params over the on-chip budget are charged DDR traffic per
    inference; resident params are amortized away (the paper's
    BaselineNet DRAM-spill effect)."""
    g = _graph("baseline_net")                     # 918,625 params
    param_bytes = g.n_params * 4                   # fp32 on flex
    # memory-bound hardware, so the residency decision shows in latency
    fits = HardwareModel(name="fits", peak_flops_f32=1e15,
                         peak_flops_bf16=1e15, peak_ops_int8=1e15,
                         hbm_bw=1e9, onchip_bytes=param_bytes,
                         power_busy=2.0, power_idle=1.0)
    spills = HardwareModel(name="spills", peak_flops_f32=1e15,
                           peak_flops_bf16=1e15, peak_ops_int8=1e15,
                           hbm_bw=1e9, onchip_bytes=param_bytes - 1,
                           power_busy=2.0, power_idle=1.0)
    for batch in (1, 8):
        res = cost_signature(g, "flex", batch, hw=fits)
        spl = cost_signature(g, "flex", batch, hw=spills)
        assert res.weights_resident and not spl.weights_resident
        # the spilled plan moves exactly the param bytes more, per sample
        assert spl.bytes_moved - res.bytes_moved == pytest.approx(
            param_bytes * batch)
        assert spl.energy_j > res.energy_j


def test_int8_residency_uses_one_byte_weights():
    g = _graph("baseline_net")
    hw = HardwareModel(name="between", peak_flops_f32=1e9,
                       peak_flops_bf16=1e9, peak_ops_int8=1e9,
                       hbm_bw=1e9, onchip_bytes=2 * g.n_params,
                       power_busy=2.0, power_idle=1.0)
    # 2 bytes/param budget: int8 weights fit, fp32 weights spill
    assert cost_signature(g, "accel", 1, hw=hw).weights_resident
    assert not cost_signature(g, "flex", 1, hw=hw).weights_resident


# ---------------------------------------------------------------------------
# power envelope
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 25),
       st.floats(0.0, 2.0))
def test_envelope_ledger_never_exceeds_budget(seed, n_attempts, burst_j):
    """Whatever mix of draws is attempted, the recorded ledger satisfies
    the sustained constraint over EVERY trailing window — by audit()'s
    candidate scan and by brute-force sampling."""
    rng = np.random.default_rng(seed)
    sustained, window = 3.0, 0.25
    env = PowerEnvelope(sustained, burst_j=burst_j, window_s=window)
    t = 0.0
    n_admitted = 0
    for _ in range(n_attempts):
        t += float(rng.uniform(0.0, 0.2))
        watts = float(rng.uniform(0.5, 8.0))
        dur = float(rng.uniform(0.001, 0.4))
        if env.admit(t, watts, dur) is not None:
            n_admitted += 1
    audit = env.audit()
    assert audit["n_violations"] == 0, audit
    assert audit["n_draws"] == n_admitted
    if env.draws:
        last = max(d.end for d in env.draws)
        for tau in rng.uniform(0.0, last + window, size=200):
            assert (env.window_energy(float(tau))
                    <= env.budget_energy(float(tau) - window, float(tau))
                    + burst_j + 1e-6), float(tau)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_envelope_next_admit_is_admissible(seed):
    rng = np.random.default_rng(seed)
    env = PowerEnvelope(3.0, window_s=0.2)
    t = 0.0
    for _ in range(6):
        t += float(rng.uniform(0.0, 0.05))
        watts = float(rng.uniform(1.0, 7.0))
        dur = float(rng.uniform(0.01, 0.15))
        if env.admit(t, watts, dur) is None:
            nt = env.next_admit(t, watts, dur)
            if nt is not None:
                assert nt >= t
                assert env.admissible(nt, watts, dur), (t, nt, watts, dur)


def test_envelope_peak_cap_and_overlap():
    env = PowerEnvelope(100.0, peak_w=5.0, window_s=1.0)
    assert env.admit(0.0, 6.0, 0.1) is None        # exceeds cap alone
    assert env.admit(0.0, 3.0, 0.1) is not None
    # a second concurrent draw would push instantaneous power over 5 W
    assert env.admit(0.05, 3.0, 0.1) is None
    assert env.admit(0.15, 3.0, 0.1) is not None   # after the first ends
    assert env.audit()["n_violations"] == 0


def test_envelope_respects_future_budget_steps():
    """The orbit is known in advance: a draw whose trailing windows cross
    into a scheduled eclipse is refused BEFORE the eclipse starts."""
    env = PowerEnvelope(6.0, window_s=1.0)
    env.set_budget(10.0, sustained_w=0.5)
    assert env.admissible(8.5, 6.0, 0.5)           # completes well before
    assert not env.admissible(9.8, 6.0, 0.5)       # crosses into eclipse
    # this schedule never exits eclipse: the draw can never fit again
    assert env.next_admit(9.8, 6.0, 0.5) is None
    env2 = PowerEnvelope(0.5, window_s=1.0)
    env2.set_budget(10.0, sustained_w=6.0)         # eclipse exit
    nt2 = env2.next_admit(0.0, 6.0, 0.5)
    assert nt2 is not None and nt2 > 5.0 and env2.admissible(nt2, 6.0, 0.5)


def test_envelope_infinite_admits_everything():
    env = PowerEnvelope()
    for i in range(5):
        assert env.admit(i * 0.1, 1e9, 10.0) is not None
    assert env.audit()["n_violations"] == 0
    assert env.feasible_ever(1e12, 1e6)


def test_envelope_rejects_bad_args():
    with pytest.raises(ValueError):
        PowerEnvelope(3.0, window_s=0.0)
    env = PowerEnvelope(3.0)
    env.set_budget(5.0, sustained_w=1.0)
    with pytest.raises(ValueError):
        env.set_budget(4.0, sustained_w=2.0)       # steps must be ordered


# ---------------------------------------------------------------------------
# infinite budget == PR-2 dispatch behavior
# ---------------------------------------------------------------------------


def _serve_logistic(envelope):
    from repro.core.engine import Engine
    from repro.core.scheduler import ContinuousBatchingScheduler
    m = SPACE_MODELS["logistic_net"]
    e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
    reqs = [{k: np.asarray(v) for k, v in
             m.synthetic_input(jax.random.PRNGKey(i)).items()}
            for i in range(8)]
    sched = ContinuousBatchingScheduler(envelope=envelope, clock="modeled")
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4, 8))
    trace = [(0.003 * i, "logistic_net", reqs[i % len(reqs)])
             for i in range(27)]
    sched.serve_trace(trace)
    return sched


def test_infinite_envelope_identical_to_no_envelope():
    """An envelope that never refuses must not change ANY dispatch
    decision vs the PR-2 scheduler: same batches, same rungs, same modes,
    same virtual dispatch times (the modeled clock makes both runs
    deterministic)."""
    base = _serve_logistic(None)
    inf_env = _serve_logistic(PowerEnvelope(math.inf))
    strip = lambda s: [(d.model, d.rung, d.n_real, d.mode, d.backend,
                        d.started) for d in s.dispatches]
    assert strip(base) == strip(inf_env)
    assert ([c.rid for c in base.completions]
            == [c.rid for c in inf_env.completions])
    assert ([(c.rung, c.finished) for c in base.completions]
            == [(c.rung, c.finished) for c in inf_env.completions])
    assert len(inf_env.deferrals) == 0
