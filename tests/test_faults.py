"""Degraded-mode fault suite tests (DESIGN.md §13).

* The SEU injector is deterministic per seed, actually corrupts what the
  COMPILED plans compute (weights are runtime arguments, not baked
  trace-time constants — and corrupting them never re-traces), and
  ``repack_weights`` restores the arena bit-exact from the pristine
  host copies.
* Golden canaries pin a digest at arm time, detect a flip, and verify
  recovery; staging-buffer flips are transient by construction.
* The fault controller under ``clock="modeled"``: detection within the
  self-test period (+aging allowance), repack recovery, demote recovery
  through backend quarantine (dispatch falls back, repair un-quarantines),
  zero requests dropped or duplicated — and a fully inert controller
  leaves the scheduler dispatch-for-dispatch bit-identical to serving
  without one.
* Checkpoint/restore: ``state_dict`` -> one pickle-free .npz ->
  ``load_state_dict`` round-trips every ledger field, and a simulated
  watchdog reboot mid-trace completes every accepted request exactly
  once, identically to the uninterrupted run.
* ``serve_trace(stop_at=...)``: every arrival at or before the returned
  time was absorbed (queued, in flight, or completed), none after.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy, faults
from repro.core.engine import Engine
from repro.core.scheduler import ContinuousBatchingScheduler, bursty_arrivals
from repro.models import SPACE_MODELS, synthetic_requests

MODEL = "multi_esperta"             # six int8 dense heads -> real arenas
CO_MODEL = "logistic_net"
BACKENDS = ("accel", "cpu")
LADDER = (1, 4)
N = 24
PERIOD = 0.05


@pytest.fixture(scope="module")
def engines():
    out = {}
    for name in (MODEL, CO_MODEL):
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(2)])
        out[name] = (m, e)
    return out


@pytest.fixture()
def accel_plan(engines):
    """The shared accel plan, guaranteed pristine again afterwards."""
    _, e = engines[MODEL]
    plan = e.planned("accel")
    yield plan
    plan.repack_weights()


def _sched(engines, names=(MODEL,), **kw):
    sched = ContinuousBatchingScheduler(clock="modeled", **kw)
    trace = []
    for mi, name in enumerate(names):
        m, e = engines[name]
        reqs = synthetic_requests(m, N, seed=5 + mi)
        sched.register(name, e, backend=BACKENDS, ladder=LADDER,
                       warmup_sample=reqs[0])
        trace += [(t, name, r) for t, r in
                  zip(bursty_arrivals(N, burst_size=4, gap_s=0.01,
                                      seed=20 + mi), reqs)]
    return sched, trace


def _controller(sched, engines, names=(MODEL,), **cfg_kw):
    ctl = faults.FaultController(faults.FaultConfig(**cfg_kw))
    sched.attach_faults(ctl)
    for mi, name in enumerate(names):
        m, _ = engines[name]
        ctl.arm(sched, name, synthetic_requests(m, 1, seed=5 + mi))
    return ctl


# ---------------------------------------------------------------------------
# injector + arena repack
# ---------------------------------------------------------------------------


def test_injector_deterministic_per_seed(accel_plan):
    a = faults.SEUInjector(seed=7).flip(accel_plan)
    accel_plan.repack_weights()
    b = faults.SEUInjector(seed=7).flip(accel_plan)
    accel_plan.repack_weights()
    c = faults.SEUInjector(seed=8).flip(accel_plan)
    assert a == b
    assert a != c                   # byte/bit space is ~1e4: seeds differ


def test_flip_corrupts_compiled_output_without_retrace(engines, accel_plan):
    """THE load-bearing property: weights are runtime arguments of the
    compiled executables, so a bit flip in the live arena changes what
    the already-compiled plan computes — with zero re-traces — and
    repacking restores it bit-exact."""
    m, e = engines[MODEL]
    inputs = m.synthetic_batch(jax.random.PRNGKey(11), 2)
    rngs = jax.random.split(jax.random.PRNGKey(7), 2)
    before = {k: np.asarray(v)
              for k, v in e.run_batch(inputs, "accel", rngs).items()}
    n_traces = accel_plan.n_traces

    node, byte, bit = faults.SEUInjector(seed=0).flip(accel_plan)
    corrupt = e.run_batch(inputs, "accel", rngs)
    assert accel_plan.n_traces == n_traces
    assert any(not np.array_equal(np.asarray(corrupt[k]), before[k])
               for k in before), (
        f"flip of {node}[{byte}]:{bit} did not reach the executable")

    nbytes = accel_plan.repack_weights()
    assert nbytes > 0
    after = e.run_batch(inputs, "accel", rngs)
    assert accel_plan.n_traces == n_traces
    for k in before:
        np.testing.assert_array_equal(np.asarray(after[k]), before[k])
    for name in accel_plan.weight_arena:
        np.testing.assert_array_equal(
            np.asarray(accel_plan.weight_arena[name]),
            accel_plan.host_weights[name])


def test_flip_pinned_target(accel_plan):
    node = max(accel_plan.weight_arena,
               key=lambda n: accel_plan.host_weights[n].nbytes)
    got = faults.SEUInjector(seed=0).flip(accel_plan, node=node,
                                          byte=1, bit=5)
    assert got == (node, 1, 5)
    host = accel_plan.host_weights[node]
    flipped = np.array(accel_plan.weight_arena[node])
    diff = host.view(np.uint8).reshape(-1) ^ \
        flipped.view(np.uint8).reshape(-1)
    assert diff[1] == (1 << 5) and int(diff.sum()) == (1 << 5)


def test_injector_rejects_empty_arena(engines):
    _, e = engines[MODEL]
    plan = e.planned("flex")        # fp32 plans carry no quantized arena
    assert plan.weight_arena == {}
    with pytest.raises(ValueError, match="no quantized weight arena"):
        faults.SEUInjector(seed=0).flip(plan)


def test_staging_flip_is_transient(engines):
    from repro.core.pipeline import ServingPipeline
    m, e = engines[MODEL]
    pipe = ServingPipeline(e, backend="accel", batch_size=4)
    reqs = synthetic_requests(m, 4, seed=3)
    ref = pipe.execute_batch(reqs, rng=jax.random.PRNGKey(0))
    faults.SEUInjector(seed=0).flip_staging(pipe.arena, slot=0)
    again = pipe.execute_batch(reqs, rng=jax.random.PRNGKey(0))
    for k in ref.outputs:           # stage() rewrote every row
        np.testing.assert_array_equal(again.outputs[k], ref.outputs[k])


# ---------------------------------------------------------------------------
# canaries
# ---------------------------------------------------------------------------


def test_output_digest_sensitive_and_stable():
    out = {"a": np.arange(6, dtype=np.float32).reshape(2, 3)}
    d1 = faults.output_digest(out)
    assert d1 == faults.output_digest(
        {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)})
    perturbed = {"a": out["a"].copy()}
    perturbed["a"][1, 2] += 0.5
    assert faults.output_digest(perturbed) != d1
    assert faults.output_digest({"b": out["a"]}) != d1


def test_canary_detects_flip_and_recovery(engines, accel_plan):
    m, _ = engines[MODEL]
    sched, _ = _sched(engines)
    ctl = _controller(sched, engines, seed=0)
    canary = ctl._models[MODEL].canary
    ok, _ = canary.check()
    assert ok
    ctl.injector.flip(accel_plan)
    ok, got = canary.check()
    assert not ok and got != canary.digest
    accel_plan.repack_weights()
    ok, _ = canary.check()
    assert ok


# ---------------------------------------------------------------------------
# the controller under the modeled clock
# ---------------------------------------------------------------------------


def test_repack_storm_detects_recovers_drops_nothing(engines):
    sched, trace = _sched(engines)
    ctl = _controller(sched, engines, seed=0, fault_times=(0.012,),
                      self_test_period=PERIOD, recovery="repack")
    sched.serve_trace(trace)
    rep = ctl.report()
    assert rep["n_injected"] == 1
    assert rep["n_detected"] == 1 and rep["n_recovered"] == 1
    (ev,) = rep["events"]
    bound = PERIOD * (1 + ctl.config.aging_fraction) + 0.01
    assert ev["detected_at"] - ev["t_injected"] <= bound
    assert ev["recovered_at"] >= ev["detected_at"]
    assert ev["action"] == "repack"
    assert rep["overhead_energy_j"] > 0 and rep["n_self_tests"] >= 1
    assert sorted(c.rid for c in sched.completions) == list(range(N))
    # modeled clock: EWMA estimates ARE the signatures -> no drift
    for ratios in ctl.drift_report(sched).values():
        assert all(r == 1.0 for r in ratios.values())


def test_demote_storm_falls_back_then_repairs(engines):
    sched, trace = _sched(engines)
    # detect early (short period) so the quarantine window still overlaps
    # live bursts — the fallback dispatches are the point of this test
    ctl = _controller(sched, engines, seed=0, fault_times=(0.005,),
                      self_test_period=0.02, recovery="demote",
                      repair_delay_s=0.03)
    sched.serve_trace(trace)
    rep = ctl.report()
    assert rep["n_detected"] == 1 and rep["n_recovered"] == 1
    assert rep["events"][0]["action"] == "demote+repack"
    assert not sched._svcs[MODEL].quarantined     # repaired + lifted
    assert any(d.backend != BACKENDS[0] for d in sched.dispatches
               if d.model == MODEL), "no fallback dispatch ran while " \
        "the primary backend was quarantined"
    assert sorted(c.rid for c in sched.completions) == list(range(N))


def test_demote_requires_fallback_backend(engines):
    m, e = engines[MODEL]
    reqs = synthetic_requests(m, 2, seed=5)
    sched = ContinuousBatchingScheduler(clock="modeled")
    sched.register(MODEL, e, backend="accel", ladder=(1,),
                   warmup_sample=reqs[0])
    ctl = _controller(sched, engines, seed=0, fault_times=(0.0,),
                      self_test_period=0.001, recovery="demote")
    with pytest.raises(RuntimeError, match="fallback backend"):
        sched.serve_trace([(0.0, MODEL, reqs[0])])
    ctl._models[MODEL].plan.repack_weights()


def test_inert_controller_is_bit_identical_to_no_controller(engines):
    plain, trace = _sched(engines, names=(MODEL, CO_MODEL))
    plain.serve_trace(trace)
    armed, _ = _sched(engines, names=(MODEL, CO_MODEL))
    ctl = _controller(armed, engines, names=(MODEL, CO_MODEL))
    armed.serve_trace(trace)
    assert ctl.report()["n_self_tests"] == 0
    assert armed.dispatches == plain.dispatches
    assert len(armed.completions) == len(plain.completions)
    for a, b in zip(armed.completions, plain.completions):
        assert (a.rid, a.model, a.kept, a.arrival, a.finished, a.rung,
                a.n_real) == (b.rid, b.model, b.kept, b.arrival,
                              b.finished, b.rung, b.n_real)
        for k in b.outputs:
            np.testing.assert_array_equal(a.outputs[k], b.outputs[k])


def test_fault_config_validation_and_schedule():
    with pytest.raises(ValueError, match="repack|demote"):
        faults.FaultConfig(recovery="reboot")
    assert faults.FaultConfig().schedule() == []
    cfg = faults.FaultConfig(seed=3, fault_rate=100.0, horizon_s=0.5)
    times = cfg.schedule()
    assert times == cfg.schedule()                  # seed-deterministic
    assert times == sorted(times)
    assert all(0 < t < 0.5 for t in times)
    assert faults.FaultConfig(fault_times=(0.3, 0.1)).schedule() == \
        [0.1, 0.3]


def test_half_specified_poisson_storm_names_missing_field():
    """A rate without a horizon (or vice versa) used to yield a silently
    empty schedule; now the error names the field that is missing."""
    with pytest.raises(ValueError, match="horizon_s"):
        faults.FaultConfig(fault_rate=5.0)
    with pytest.raises(ValueError, match="fault_rate"):
        faults.FaultConfig(horizon_s=1.0)
    # the inert default and every fully-specified shape stay valid
    assert faults.FaultConfig().upset_schedule() == []
    assert faults.FaultConfig(fault_rate=5.0, horizon_s=1.0).schedule()
    # an explicit schedule (times or typed upsets) needs no rate/horizon
    assert faults.FaultConfig(fault_times=(0.1,), horizon_s=1.0)
    from repro.core.radiation import UpsetEvent
    cfg = faults.FaultConfig(upsets=(UpsetEvent(0.2), UpsetEvent(0.1)))
    assert [ev.t for ev in cfg.upset_schedule()] == [0.1, 0.2]


def test_repack_cost_pricing():
    hw = energy.BACKEND_HW["accel"]
    small = energy.repack_cost(hw, 1024)
    big = energy.repack_cost(hw, 1 << 20)
    assert 0 < small.seconds < big.seconds
    assert 0 < small.energy_j < big.energy_j
    bw = hw.stage_bw or hw.hbm_bw
    expect = hw.overhead_s + 1024 / bw
    assert small.seconds == pytest.approx(expect)


# ---------------------------------------------------------------------------
# checkpoint / restore
# ---------------------------------------------------------------------------


def _walk_equal(a, b):
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        return (np.asarray(a).dtype == np.asarray(b).dtype
                and np.array_equal(np.asarray(a), np.asarray(b)))
    if isinstance(a, dict):
        return (isinstance(b, dict) and set(a) == set(b)
                and all(_walk_equal(a[k], b[k]) for k in a))
    if isinstance(a, (list, tuple)):
        return (isinstance(b, (list, tuple)) and len(a) == len(b)
                and all(_walk_equal(x, y) for x, y in zip(a, b)))
    return a == b


def test_checkpoint_file_roundtrip(tmp_path):
    state = {"version": 1, "pi": 3.5, "name": "sched", "flag": True,
             "nested": {"arr": np.arange(5, dtype=np.int8),
                        "list": [np.ones((2, 2), np.float32), "x", None]},
             "empty": {}}
    path = str(tmp_path / "ck.npz")
    faults.save_checkpoint(path, state)
    loaded = faults.load_checkpoint(path)
    assert _walk_equal(loaded, state)
    # the format contract: loadable with pickling disabled
    with np.load(path, allow_pickle=False) as data:
        assert "__meta__" in data


def test_scheduler_state_dict_roundtrip(engines, tmp_path):
    sched, trace = _sched(engines, names=(MODEL, CO_MODEL))
    now = sched.serve_trace(trace, stop_at=0.02)
    state = sched.state_dict()
    path = str(tmp_path / "sched.npz")
    faults.save_checkpoint(path, state)
    assert _walk_equal(faults.load_checkpoint(path), state)

    fresh, _ = _sched(engines, names=(MODEL, CO_MODEL))
    fresh.load_state_dict(faults.load_checkpoint(path))
    assert _walk_equal(fresh.state_dict(), state)
    assert fresh.pending() == sched.pending()
    assert len(fresh.completions) == len(sched.completions)


def test_load_state_dict_rejects_mismatched_registration(engines, tmp_path):
    sched, trace = _sched(engines)
    sched.serve_trace(trace, stop_at=0.01)
    state = sched.state_dict()
    other = ContinuousBatchingScheduler(clock="modeled")
    with pytest.raises(ValueError):
        other.load_state_dict(state)   # models never registered


def test_stop_at_absorbs_exactly_the_elapsed_arrivals(engines):
    sched, trace = _sched(engines)
    stop = 0.02
    now = sched.serve_trace(trace, stop_at=stop)
    assert now >= stop - 1e-12
    due = [e for e in trace if e[0] <= now + 1e-12]
    n_absorbed = len(sched.completions) + sched.pending()
    assert n_absorbed == len(due), (
        "arrivals at or before the returned stop time must be queued, "
        "dispatched, or completed — never dropped")


def test_drift_report_window_semantics(engines):
    """Windowed drift cells with zero retired dispatches are None —
    never nan/inf (the 0/0 that used to leak out of an empty window)."""
    sched, trace = _sched(engines)
    ctl = _controller(sched, engines, seed=0)
    end = sched.serve_trace(trace)

    # a window ending long after the last dispatch retired: every cell
    # is empty, every ratio is None, nothing is nan/inf
    empty = ctl.drift_report(sched, window_s=1e-6, now=end + 100.0)
    assert empty[MODEL]
    assert all(r is None for r in empty[MODEL].values())

    # a window covering the whole run: dispatched cells carry finite
    # ratios (exactly 1.0 under the modeled clock), the rest are None
    full = ctl.drift_report(sched, window_s=end + 1.0, now=end)
    used = {(d.backend, d.rung) for d in sched.dispatches
            if d.model == MODEL and not d.failed}
    for cell, r in full[MODEL].items():
        b, rung = cell.split("/b")
        if (b, int(rung)) in used:
            assert r == pytest.approx(1.0)
        else:
            assert r is None
        assert r is None or np.isfinite(r)

    # the un-windowed EWMA path never emits nan/inf either
    for ratios in ctl.drift_report(sched).values():
        assert all(r is None or np.isfinite(r) for r in ratios.values())


def test_midstorm_checkpoint_roundtrip_is_dispatch_identical(engines,
                                                             tmp_path):
    """Watchdog reboot in the MIDDLE of a fault storm: checkpointing
    {scheduler, controller} state and restoring both into a fresh
    process resumes the timeline dispatch-for-dispatch identically to
    the uninterrupted run — zero requests lost or duplicated, and the
    post-cut upsets replay bit-exact from the restored injector RNG."""
    from repro.core.radiation import UpsetEvent
    storm = dict(seed=0, self_test_period=0.01,
                 upsets=(UpsetEvent(0.005), UpsetEvent(0.008, "mbu", 3),
                         UpsetEvent(0.038), UpsetEvent(0.045, "mbu", 2)))

    full, trace = _sched(engines)
    ctl_full = _controller(full, engines, **storm)
    full.serve_trace(trace)

    first, _ = _sched(engines)
    ctl_first = _controller(first, engines, **storm)
    cut = first.serve_trace(trace, stop_at=0.03)   # pre-cut storm done,
    assert all(e.recovered_at is not None          # post-cut still pending
               for e in ctl_first.events)
    assert ctl_first._pending
    path = str(tmp_path / "midstorm.npz")
    faults.save_checkpoint(path, {"sched": first.state_dict(),
                                  "faults": ctl_first.state_dict()})

    second, _ = _sched(engines)                    # fresh arm = reboot
    ctl_second = _controller(second, engines, **storm)
    ck = faults.load_checkpoint(path)
    second.load_state_dict(ck["sched"])
    ctl_second.load_state_dict(ck["faults"])
    second.serve_trace([e for e in trace if e[0] > cut + 1e-12],
                       start=cut)

    rep = ctl_second.report()
    assert rep["n_injected"] == 4
    assert rep["n_detected"] == 4 and rep["n_recovered"] == 4
    assert sorted(c.rid for c in second.completions) == \
        list(range(len(trace)))                    # zero loss, zero dup
    assert second.dispatches == full.dispatches
    meta = [(c.rid, c.model, c.kept, c.arrival, c.finished, c.rung,
             c.n_real) for c in second.completions]
    assert meta == [(c.rid, c.model, c.kept, c.arrival, c.finished,
                     c.rung, c.n_real) for c in full.completions]
    # the two storms' ledgers agree event-for-event
    assert [dataclasses_asdict_stable(e) for e in ctl_second.events] == \
        [dataclasses_asdict_stable(e) for e in ctl_full.events]


def dataclasses_asdict_stable(ev):
    import dataclasses as _dc
    return _dc.asdict(ev)


def test_watchdog_reboot_loses_nothing(engines, tmp_path):
    names = (MODEL, CO_MODEL)
    full, trace = _sched(engines, names=names)
    full.serve_trace(trace)

    first, _ = _sched(engines, names=names)
    now = first.serve_trace(trace, stop_at=0.02)
    path = str(tmp_path / "reboot.npz")
    faults.save_checkpoint(path, first.state_dict())

    second, _ = _sched(engines, names=names)   # fresh engines = reboot
    second.load_state_dict(faults.load_checkpoint(path))
    second.serve_trace([e for e in trace if e[0] > now + 1e-12],
                       start=now)

    assert sorted(c.rid for c in second.completions) == \
        list(range(len(trace)))
    meta = [(c.rid, c.model, c.kept, c.arrival, c.finished, c.rung,
             c.n_real) for c in second.completions]
    assert meta == [(c.rid, c.model, c.kept, c.arrival, c.finished,
                     c.rung, c.n_real) for c in full.completions]
    assert second.dispatches == full.dispatches
    by_rid = {c.rid: c for c in full.completions}
    post = [c for c in second.completions if c.outputs]
    assert post, "no post-reboot completions exercised the restored queue"
    for c in post:
        for k in c.outputs:
            np.testing.assert_array_equal(c.outputs[k],
                                          by_rid[c.rid].outputs[k])
