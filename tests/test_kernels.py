"""Per-Pallas-kernel shape/dtype sweeps against the pure-jnp oracles.

Every kernel runs in interpret mode on CPU (the kernel body executes in
Python) and must match ref.py within dtype-appropriate tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.kernels import ref

# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [
    (8, 32, 16), (128, 128, 128), (64, 256, 32), (8, 64, 128), (256, 128, 64),
])
@pytest.mark.parametrize("relu,bias", [(False, False), (True, True)])
def test_int8_matmul_sweep(m, k, n, relu, bias):
    rng = np.random.default_rng(m * 1000 + k + n)
    x_q = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.random(m) * 0.1 + 1e-3, jnp.float32)
    ws = jnp.asarray(rng.random(n) * 0.1 + 1e-3, jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32) if bias else None

    bm, bk, bn = min(m, 128), min(k, 128), min(n, 128)
    got = kops.int8_matmul(x_q, w_q, xs, ws, b, relu=relu,
                           bm=bm, bn=bn, bk=bk)
    want = ref.int8_matmul_ref(x_q, w_q, xs, ws, b, relu=relu)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_int8_matmul_int32_exactness():
    """int8 x int8 accumulation must be EXACT in int32 (no float rounding)."""
    rng = np.random.default_rng(0)
    m = k = n = 128
    x_q = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    ones_m = jnp.ones((m,), jnp.float32)
    ones_n = jnp.ones((n,), jnp.float32)
    got = kops.int8_matmul(x_q, w_q, ones_m, ones_n)
    want = np.asarray(x_q, np.int64) @ np.asarray(w_q, np.int64)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("h,w,cin,cout,kh,stride,padding", [
    (16, 16, 3, 8, 3, 1, "SAME"),
    (16, 16, 3, 8, 3, 2, "SAME"),
    (12, 20, 4, 16, 5, 1, "VALID"),
    (128, 256, 3, 8, 3, 2, "SAME"),      # the VAE's first layer shape
    (9, 9, 2, 4, 3, 2, "VALID"),
])
def test_conv2d_sweep(h, w, cin, cout, kh, stride, padding):
    rng = np.random.default_rng(h * 31 + w)
    x = jnp.asarray(rng.standard_normal((2, h, w, cin)), jnp.float32)
    wgt = jnp.asarray(rng.standard_normal((kh, kh, cin, cout)) * 0.1,
                      jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout) * 0.1, jnp.float32)
    got = kops.conv2d(x, wgt, b, stride=stride, padding=padding, relu=True)
    want = ref.conv2d_ref(x, wgt, b, stride=stride, padding=padding,
                          relu=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s,hq,hkv,hd", [
    (128, 4, 4, 32),      # MHA
    (128, 4, 2, 32),      # GQA 2:1
    (256, 8, 1, 64),      # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(s, hq, hkv, hd, dtype, causal):
    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.standard_normal((2, s, hq, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((2, s, hkv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((2, s, hkv, hd)), dtype)
    got = kops.flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=atol, atol=atol)


def test_flash_attention_blocksize_invariance():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.standard_normal((1, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 32)), jnp.float32)
    a = kops.flash_attention(q, k, v, bq=64, bk=64)
    b = kops.flash_attention(q, k, v, bq=128, bk=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape,axis", [
    ((64, 32), 0), ((64, 32), None), ((128, 256), 0),
    ((7, 48), 0),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_sweep(shape, axis, dtype):
    rng = np.random.default_rng(shape[0])
    x = jnp.asarray(rng.standard_normal(shape) * 3.0, dtype)
    q, s = kops.quantize(x, axis=axis)
    q_ref, s_ref = ref.quantize_ref(x, axis=axis)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-5, atol=1e-8)
    # int8 codes may differ by 1 ULP at rounding boundaries across codepaths
    assert int(np.abs(np.asarray(q, np.int32)
                      - np.asarray(q_ref, np.int32)).max()) <= 1

    # roundtrip error bound: |x - deq(q)| <= scale/2 + eps
    deq = ref.dequantize_ref(q, s, axis=axis)
    scale_full = np.asarray(s if axis is None
                            else np.expand_dims(np.asarray(s), axis))
    err = np.abs(np.asarray(x, np.float32) - np.asarray(deq))
    assert (err <= scale_full * 0.51 + 1e-6).all()
