"""Correctness of the §Perf levers: a2a MoE dispatch, SP collectives,
int8 weight/KV quantization, serving sharding rules.

Multi-device equivalence tests run in a subprocess (the main pytest
process has already initialized jax with 1 CPU device; the probes need
--xla_force_host_platform_device_count=8).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {src!r})
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
    """).format(src=os.path.abspath(SRC)) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=500)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


@pytest.mark.slow
def test_a2a_moe_matches_scatter_multidevice():
    _run_subprocess("""
        from repro.configs import get_arch, reduced
        from repro.nn import moe as moe_mod
        from repro.nn.dims import compute_dims
        from repro.nn.params import build_params
        from repro.parallel.sharding import use_mesh

        cfg0 = reduced(get_arch("llama4-scout-17b-a16e"))
        cfg = dataclasses.replace(cfg0, moe=dataclasses.replace(
            cfg0.moe, num_experts=4, capacity_factor=8.0, ep_impl="a2a"))
        cfg_s = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, ep_impl="scatter"))
        dims = compute_dims(cfg, tp=4)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        params = build_params(moe_mod.moe_spec(cfg, dims), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32)
        with use_mesh(mesh):
            y_a = jax.jit(lambda p, x: moe_mod.moe_ffn(p, x, cfg, dims))(params, x)
            y_s = jax.jit(lambda p, x: moe_mod.moe_ffn(p, x, cfg_s, dims))(params, x)
        d = np.abs(np.asarray(y_a, np.float32) - np.asarray(y_s, np.float32)).max()
        assert d < 2e-5, d
    """)


@pytest.mark.slow
def test_meshed_forward_matches_unmeshed_multidevice():
    """The explicit SP gather/reduce-scatter path is numerically the same
    model (bf16 tolerance) as the single-device path."""
    _run_subprocess("""
        from repro.configs import get_arch, reduced
        from repro.nn import model as model_lib
        from repro.nn.dims import compute_dims
        from repro.parallel.sharding import use_mesh

        for arch in ("tinyllama-1.1b", "llama4-scout-17b-a16e", "zamba2-1.2b"):
            cfg = reduced(get_arch(arch))
            dims = compute_dims(cfg, tp=4)
            params = model_lib.init_params(cfg, dims, jax.random.PRNGKey(0))
            toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab_size)
            ref = model_lib.forward(params, toks, cfg, dims, mode="train",
                                    remat=False)
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            with use_mesh(mesh):
                got = jax.jit(lambda p, t: model_lib.forward(
                    p, t, cfg, dims, mode="train", remat=False))(params, toks)
            d = np.abs(np.asarray(ref, np.float32)
                       - np.asarray(got, np.float32)).max()
            assert d < 0.15, (arch, d)
    """)


@pytest.mark.parametrize("arch_id", ["tinyllama-1.1b", "zamba2-1.2b"])
def test_kv8_prefill_decode_consistency(arch_id):
    """int8 KV cache: decode against a quantized prefill cache matches the
    full-precision forward within PTQ tolerance."""
    from repro.configs import get_arch, reduced
    from repro.nn import model as model_lib
    from repro.nn.dims import compute_dims
    cfg0 = reduced(get_arch(arch_id))
    cfg = dataclasses.replace(cfg0, kv_quant=True)
    dims = compute_dims(cfg, tp=1)
    params = model_lib.init_params(cfg, dims, jax.random.PRNGKey(0))
    b, s = 2, 33
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    full = model_lib.forward(params, toks, cfg0, dims, mode="train",
                             remat=False)
    _, cache = model_lib.forward(params, toks[:, :-1], cfg, dims,
                                 mode="prefill", s_max=s)
    # quantized cache layout
    leaves = jax.tree.leaves(cache)
    assert any(a.dtype == jnp.int8 for a in leaves)
    dec, new_cache = model_lib.decode(params, toks[:, -1:], cache,
                                      jnp.int32(s - 1), cfg, dims)
    a = np.asarray(full[:, -1], np.float32)
    c = np.asarray(dec[:, 0], np.float32)
    rel = np.abs(a - c).max() / (np.abs(a).max() + 1e-9)
    assert rel < 0.08, rel
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_lm_quant_roundtrip_and_axes():
    from repro.core import lm_quant
    from repro.configs import get_arch, reduced
    from repro.nn import model as model_lib
    from repro.nn.dims import compute_dims
    cfg = reduced(get_arch("qwen1.5-0.5b"), width=256)
    dims = compute_dims(cfg, tp=1)
    params = model_lib.init_params(cfg, dims, jax.random.PRNGKey(0))
    q = lm_quant.quantize_params(params)
    back = lm_quant.dequantize_params(q)
    assert jax.tree.structure(back) == jax.tree.structure(params)
    # big weights roundtrip within one quantization step (checked in f32 —
    # the bf16 output dtype adds its own representation rounding)
    back32 = lm_quant.dequantize_params(q, dtype=jnp.float32)
    emb = params["embed"]["embedding"].astype(jnp.float32)
    emb_q = q["embed"]["embedding"]
    assert emb_q["q"].dtype == jnp.int8
    err = jnp.abs(emb - back32["embed"]["embedding"]).max()
    assert float(err) <= float(emb_q["s"]) * 0.51 + 1e-6
    # axes tree mirrors the quantized structure (axes tuples are leaves)
    from repro.parallel.sharding import is_logical_leaf
    p_axes = model_lib.param_axes(cfg, dims)
    q_axes = lm_quant.quantized_axes(model_lib.abstract_model_params(cfg, dims),
                                     p_axes)
    norm_axes = jax.tree.map(lambda _: 0, q_axes, is_leaf=is_logical_leaf)
    norm_q = jax.tree.map(lambda _: 0, q)
    assert jax.tree.structure(norm_axes) == jax.tree.structure(norm_q)


def test_serving_rules_drop_fsdp():
    from repro.parallel.sharding import serving_rules, spec_for

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    mesh = FakeMesh()
    rules = serving_rules(mesh)
    assert rules["fsdp"] == ()
    spec = spec_for((4096, 4096), ("fsdp", "ffn"), mesh, rules)
    assert spec[0] is None and spec[1] == "model"
