"""Static arena planner tests (core/memory.py, DESIGN.md §10): liveness
correctness (no two live buffers overlap in the arena), budget respect,
spill + segment-boundary accounting, and the end-to-end property the
tentpole claims: fusion lowers a plan's modeled DDR bytes.
"""
import jax
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.memory import plan_arena
from repro.core.opgraph import Graph
from repro.core.plan import Segment, partition_segments
from repro.models import SPACE_MODELS
from repro.models.common import init_graph_params


def _chain_graph(n=5, width=64):
    g = Graph("chain")
    x = g.input("x", (width,))
    for i in range(n):
        x = g.add("relu", [x], name=f"n{i}")
    g.mark_output(x)
    return g


def _segments(g, backend="flex"):
    return partition_segments(g, {n: backend for n in g.order})


def test_no_live_buffers_overlap():
    g = _chain_graph(6)
    arena = plan_arena(g, _segments(g), bram_budget=10 ** 6)
    bufs = [b for b in arena.buffers.values() if b.tier == "bram"]
    for i, a in enumerate(bufs):
        for b in bufs[i + 1:]:
            lives_overlap = a.first <= b.last and b.first <= a.last
            mem_overlap = (a.offset < b.offset + b.nbytes
                           and b.offset < a.offset + a.nbytes)
            assert not (lives_overlap and mem_overlap), (a, b)


def test_chain_reuses_arena_space():
    """A pure chain only ever needs two live buffers — the arena peak
    must stay at 2 buffers, not grow with depth."""
    g = _chain_graph(8, width=32)
    arena = plan_arena(g, _segments(g), bram_budget=10 ** 6)
    assert arena.bram_peak <= 2 * 32 * 4
    assert arena.n_spilled == 0
    assert arena.spill_bytes == 0


def test_peak_never_exceeds_budget_and_spills_when_tight():
    from repro.core.opgraph import consumers
    g = _chain_graph(6, width=64)         # 256 B per buffer
    cons = consumers(g)
    tight = plan_arena(g, _segments(g), bram_budget=300)
    assert tight.bram_peak <= 300
    assert tight.n_spilled > 0
    # a consumed spilled value is charged write + read back; a
    # consumer-less spilled output is written once (downlink only)
    assert tight.spill_bytes == sum(
        b.nbytes * (2 if cons[b.name] else 1)
        for b in tight.buffers.values()
        if b.tier == "ddr" and b.reason == "spill")
    zero = plan_arena(g, _segments(g), bram_budget=0)
    assert all(b.tier == "ddr" for b in zero.buffers.values())


def test_segment_boundary_forces_ddr_roundtrip():
    g = _chain_graph(4, width=16)
    segs = [Segment("accel", ("n0", "n1")), Segment("flex", ("n2", "n3"))]
    arena = plan_arena(g, segs, bram_budget=10 ** 6)
    assert arena.buffers["n1"].tier == "ddr"
    assert arena.buffers["n1"].reason == "boundary"
    assert arena.boundary_bytes == 2 * 16 * 4
    # same graph, one segment: no boundary traffic at all
    one = plan_arena(g, _segments(g), bram_budget=10 ** 6)
    assert one.boundary_bytes == 0


def test_int8_dtype_halves_nothing_but_quarters_bytes():
    g = _chain_graph(3, width=128)
    f32 = plan_arena(g, _segments(g), 10 ** 6)
    i8 = plan_arena(g, _segments(g), 10 ** 6,
                    act_dtype_bytes={n: 1 for n in g.nodes})
    assert i8.bram_peak * 4 == f32.bram_peak
    assert i8.input_bytes * 4 == f32.input_bytes


def test_ddr_bytes_accounting_is_consistent():
    g = _chain_graph(5, width=64)
    arena = plan_arena(g, _segments(g), bram_budget=10 ** 6)
    assert arena.ddr_bytes_per_sample == (
        arena.input_bytes + arena.output_bytes
        + arena.spill_bytes + arena.boundary_bytes)
    # output (marked) is BRAM-resident, so its downlink write is charged
    assert arena.output_bytes == 64 * 4


@pytest.mark.parametrize("name", ["vae_encoder", "cnet_plus_scalar"])
def test_fused_plan_moves_fewer_ddr_bytes_than_opbyop(name):
    """The tentpole claim at plan level: for the conv-heavy models the
    fused plan's arena DDR bytes are well below the op-by-op model's
    every-activation-round-trips bytes (the paper's HLS-vs-DPU lever)."""
    m = SPACE_MODELS[name]
    e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
    e.calibrate([m.synthetic_input(jax.random.PRNGKey(i)) for i in range(2)])
    plan = e.planned("accel")
    fused_sig = plan.cost_signature(32)
    # compare against the op-by-op bytes model on the SAME fused graph
    from repro.core.energy import cost_signature
    opbyop = cost_signature(plan.graph, "accel", 32,
                            quantized=set(plan.qplans))
    assert fused_sig.bytes_moved < 0.7 * opbyop.bytes_moved, (
        fused_sig.bytes_moved, opbyop.bytes_moved)
    assert fused_sig.j_per_inference < opbyop.j_per_inference
