"""Serving-layer regression tests: pipeline ragged tails, the empty
stream, and the continuous-batching scheduler.

* Empty request stream returns a zero-request ServeStats (seed crashed
  with ``reqs[0]`` IndexError).
* Ragged-tail losslessness: for stream lengths NOT divisible by the
  batch size, pipeline/scheduler outputs are BIT-identical to the same
  compiled plan run on a manually padded batch — staging, padding, and
  slice-off introduce no numeric change whatsoever.
* Per-sample equivalence: pipeline/scheduler outputs match a loop of
  single-sample ``Engine.run`` calls. On the fully-int8 accel path this
  is bit-exact (static scales, int32 accumulation); fp32 flex matmuls
  reduce in a batch-size-dependent order, so the flex bound is float
  associativity (~1e-6 relative), with bitwise equality additionally
  asserted for the int8-exact model/backend cell.
* Scheduler: co-serves two models round-robin, drops/duplicates nothing,
  dispatches only ladder rungs, precompiles the ladder (serving never
  re-traces), and the async wall-clock mode completes every request.
* Power envelope: tightening the budget mid-trace degrades dispatch
  (smaller rungs, cpu/flex fallback, recorded deferrals) without ever
  dropping or duplicating a request and with a clean envelope audit; a
  peak cap below the DPU's power excludes it outright; a model no
  backend of which can ever fit is rejected at register time.
"""
import time

import jax
import numpy as np
import pytest

from repro.core.energy import PowerEnvelope
from repro.core.engine import Engine
from repro.core.pipeline import ServeStats, ServingPipeline, stage_batch
from repro.core.scheduler import (ContinuousBatchingScheduler,
                                  bursty_arrivals, poisson_arrivals)
from repro.models import SPACE_MODELS, synthetic_requests

# two cheap space models, one per paper toolchain family
MODELS = ("logistic_net", "multi_esperta")


@pytest.fixture(scope="module")
def engines():
    out = {}
    for name in MODELS:
        m = SPACE_MODELS[name]
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(2)])
        out[name] = (m, e)
    return out


def _requests(m, n, seed=3):
    return synthetic_requests(m, n, seed=seed)


# ---------------------------------------------------------------------------
# empty stream (seed regression: IndexError at reqs[0])
# ---------------------------------------------------------------------------


def test_empty_stream_returns_zero_stats(engines):
    _, e = engines["logistic_net"]
    pipe = ServingPipeline(e, backend="flex", batch_size=4)
    stats = pipe.run([])
    assert isinstance(stats, ServeStats)
    assert stats.n_requests == 0 and stats.n_kept == 0
    assert stats.fps == 0.0 and stats.phases.wall == 0.0
    assert stats.downlink_reduction == 1.0  # nothing sent


def test_stage_batch_rejects_empty_and_oversize(engines):
    m, _ = engines["logistic_net"]
    with pytest.raises(ValueError):
        stage_batch([], 4)
    with pytest.raises(ValueError):
        stage_batch(_requests(m, 5), 4)


# ---------------------------------------------------------------------------
# ragged tails
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["flex", "accel"])
@pytest.mark.parametrize("name", MODELS)
def test_ragged_tail_bit_identical_to_padded_plan(name, backend, engines):
    """Pipeline output for a ragged stream == the SAME compiled plan fed a
    manually padded batch, bit for bit: the serving layer's staging,
    padding, and slicing add zero numeric perturbation."""
    m, e = engines[name]
    B, L = 4, 7                                   # 7 % 4 != 0
    reqs = _requests(m, L)
    pipe = ServingPipeline(e, backend=backend, batch_size=B)

    for lo in range(0, L, B):
        chunk = reqs[lo:lo + B]
        got = pipe.execute_batch(chunk).outputs
        padded = chunk + [chunk[-1]] * (B - len(chunk))
        ref = e.run_batch(
            {k: np.stack([np.asarray(r[k], np.float32) for r in padded])
             for k in padded[0]}, backend)
        for k in ref:
            np.testing.assert_array_equal(
                got[k], np.asarray(ref[k])[:len(chunk)],
                err_msg=f"{name}/{backend}/{k} chunk@{lo}")


@pytest.mark.parametrize("backend", ["flex", "accel"])
@pytest.mark.parametrize("name", MODELS)
def test_ragged_tail_matches_per_sample_engine_run(name, backend, engines):
    """Pipeline over a ragged stream == a loop of per-sample Engine.run.
    Bit-for-bit on the fully-int8 cell; float-associativity tolerance on
    fp32 cells (batched gemms reduce in batch-size-dependent order)."""
    m, e = engines[name]
    B, L = 4, 7
    reqs = _requests(m, L)
    pipe = ServingPipeline(e, backend=backend, batch_size=B)
    outs = []
    for lo in range(0, L, B):
        res = pipe.execute_batch(reqs[lo:lo + B])
        outs += [{k: v[i] for k, v in res.outputs.items()}
                 for i in range(len(res.keep))]
    assert len(outs) == L
    bit_exact = name == "multi_esperta" and backend == "accel"
    for i, req in enumerate(reqs):
        single = e.run(req, backend)
        for k in single:
            a, b = outs[i][k], np.asarray(single[k])
            if bit_exact:
                np.testing.assert_array_equal(a, b,
                                              err_msg=f"{name}/{backend}/{k}")
            else:
                np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                           err_msg=f"{name}/{backend}/{k}")


@pytest.mark.parametrize("backend", ["flex", "accel"])
@pytest.mark.parametrize("name", MODELS)
def test_scheduler_ragged_stream_matches_per_sample(name, backend, engines):
    """Scheduler-served outputs (ladder dispatch + deadline flushes over a
    non-rung-aligned stream) match per-sample Engine.run, request by
    request."""
    m, e = engines[name]
    L = 11                                        # not on any rung boundary
    reqs = _requests(m, L)
    sched = ContinuousBatchingScheduler()
    sched.register(name, e, backend=backend, ladder=(1, 4),
                   warmup_sample=reqs[0])
    trace = [(0.001 * i, name, r) for i, r in enumerate(reqs)]
    sched.serve_trace(trace)

    comps = {c.rid: c for c in sched.completions}
    assert len(comps) == L
    bit_exact = name == "multi_esperta" and backend == "accel"
    for rid, req in enumerate(reqs):
        single = e.run(req, backend)
        for k in single:
            a, b = comps[rid].outputs[k], np.asarray(single[k])
            if bit_exact:
                np.testing.assert_array_equal(a, b,
                                              err_msg=f"{name}/{backend}/{k}")
            else:
                np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6,
                                           err_msg=f"{name}/{backend}/{k}")


# ---------------------------------------------------------------------------
# staging-buffer reuse (DESIGN.md §12)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["flex", "accel"])
@pytest.mark.parametrize("name", MODELS)
def test_reused_arena_bit_exact_vs_fresh_allocation(name, backend, engines):
    """Ragged tails staged into a REUSED host arena slot produce outputs
    bit-identical to the freshly-allocating `stage_batch` path — including
    a shrinking batch reusing a slot still holding a longer batch's rows
    (every row is rewritten: real samples + repeat-last padding)."""
    m, e = engines[name]
    B = 4
    reqs = _requests(m, 9)
    arena_pipe = ServingPipeline(e, backend=backend, batch_size=B,
                                 staging_buffers=1)
    fresh_pipe = ServingPipeline(e, backend=backend, batch_size=B,
                                 staging_buffers=1)
    fresh_pipe.arena.acquire()      # hog the slot -> always falls back

    # full batch, then shrinking ragged tails through the SAME slot
    for lo, hi in ((0, 4), (4, 6), (6, 7)):
        chunk = reqs[lo:hi]
        got = arena_pipe.execute_batch(chunk, rng=jax.random.PRNGKey(lo))
        ref = fresh_pipe.execute_batch(chunk, rng=jax.random.PRNGKey(lo))
        assert got.keep == ref.keep
        for k in ref.outputs:
            np.testing.assert_array_equal(
                got.outputs[k], ref.outputs[k],
                err_msg=f"{name}/{backend}/{k} chunk [{lo}:{hi}]")
    assert arena_pipe.arena.n_staged == 3       # all via the one slot
    assert arena_pipe.arena.n_fallback == 0
    assert arena_pipe.arena.n_free == 1         # every slot returned
    assert fresh_pipe.arena.n_fallback == 3     # reference path never staged


def test_arena_slot_contents_match_stage_batch(engines):
    """Buffer-level check of the bit-exactness contract: a reused slot's
    contents equal `stage_batch`'s fresh stack for the same requests."""
    m, e = engines["logistic_net"]
    B = 4
    reqs = _requests(m, 6)
    pipe = ServingPipeline(e, backend="flex", batch_size=B,
                           staging_buffers=1)
    slot = pipe.arena.acquire()
    for chunk in (reqs[:4], reqs[4:]):          # reuse, incl. ragged tail
        bufs = pipe.arena.stage(slot, chunk)
        ref = stage_batch(chunk, B)
        assert set(bufs) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(bufs[k], np.asarray(ref[k]))
    pipe.arena.release(slot)


@pytest.mark.parametrize("backend", ["flex", "accel"])
def test_arena_reuse_never_retraces(backend, engines):
    """Reused staging buffers hit the SAME compiled executable: no plan
    re-trace across slot reuse, ragged lengths, or the fallback path."""
    m, e = engines["multi_esperta"]
    reqs = _requests(m, 11)
    pipe = ServingPipeline(e, backend=backend, batch_size=4,
                           staging_buffers=1)
    before = e.planned(backend).n_traces
    tickets = [pipe.execute_batch_async(reqs[:4]),
               pipe.execute_batch_async(reqs[4:8])]   # 2nd one falls back
    for t in tickets:
        t.retire()
    pipe.execute_batch(reqs[8:])                      # ragged slot reuse
    assert e.planned(backend).n_traces == before
    assert pipe.arena.n_fallback == 1


# ---------------------------------------------------------------------------
# scheduler behavior
# ---------------------------------------------------------------------------


def _co_serve(engines, trace_fn, n=40):
    sched = ContinuousBatchingScheduler()
    trace = []
    for mi, name in enumerate(MODELS):
        m, e = engines[name]
        reqs = _requests(m, n, seed=7 + mi)
        sched.register(name, e, backend="flex", ladder=(1, 4, 16),
                       warmup_sample=reqs[0])
        trace += [(t, name, r)
                  for t, r in zip(trace_fn(n, seed=30 + mi), reqs)]
    sched.serve_trace(trace)
    return sched, trace


def test_scheduler_co_serves_two_models_no_drop_no_dup(engines):
    sched, trace = _co_serve(
        engines, lambda n, seed: poisson_arrivals(400.0, n, seed=seed))
    rids = [c.rid for c in sched.completions]
    assert len(rids) == len(trace)                # nothing dropped
    assert len(set(rids)) == len(rids)            # nothing duplicated
    per_model = {name: sum(1 for c in sched.completions if c.model == name)
                 for name in MODELS}
    assert all(v == len(trace) // 2 for v in per_model.values())


def test_scheduler_bursty_trace_integrity(engines):
    sched, trace = _co_serve(
        engines,
        lambda n, seed: bursty_arrivals(n, burst_size=8, gap_s=0.02,
                                        seed=seed))
    rids = sorted(c.rid for c in sched.completions)
    assert rids == list(range(len(trace)))


def test_scheduler_dispatches_only_ladder_rungs(engines):
    sched, _ = _co_serve(
        engines, lambda n, seed: poisson_arrivals(300.0, n, seed=seed), n=37)
    assert sched.dispatches
    for d in sched.dispatches:
        assert d.rung in (1, 4, 16)
        assert 1 <= d.n_real <= d.rung


def test_scheduler_precompiles_ladder_and_never_retraces(engines):
    m, e = engines["logistic_net"]
    reqs = _requests(m, 25)
    sched = ContinuousBatchingScheduler()
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4, 16),
                   warmup_sample=reqs[0])
    traces_before = e.planned("flex").n_traces
    trace = [(0.002 * i, "logistic_net", r) for i, r in enumerate(reqs)]
    sched.serve_trace(trace)
    assert e.planned("flex").n_traces == traces_before   # zero serving traces
    assert len(sched.completions) == len(reqs)


def test_scheduler_telemetry_fields(engines):
    sched, trace = _co_serve(
        engines, lambda n, seed: poisson_arrivals(500.0, n, seed=seed))
    tel = sched.telemetry()
    assert set(tel) == set(MODELS)
    for name, t in tel.items():
        assert t.n_completed == t.n_submitted == len(trace) // 2
        assert t.p99_latency_ms >= t.p50_latency_ms >= 0.0
        assert 0.0 < t.mean_batch_fill <= 1.0
        assert t.n_dispatches == sum(
            h["dispatches"] for h in t.fill_hist.values())
        d = t.to_dict()                           # JSON-ready
        import json
        json.dumps(d)


def test_scheduler_keep_predicate_threads_through(engines):
    m, e = engines["multi_esperta"]
    reqs = _requests(m, 20)
    sched = ContinuousBatchingScheduler()
    sched.register("multi_esperta", e, backend="flex", ladder=(1, 4),
                   keep_predicate=lambda out: False,
                   warmup_sample=reqs[0])
    sched.serve_trace([(0.001 * i, "multi_esperta", r)
                       for i, r in enumerate(reqs)])
    tel = sched.telemetry()["multi_esperta"]
    assert tel.n_kept == 0 and tel.downlink_reduction == 1.0
    assert all(not c.kept for c in sched.completions)


def test_scheduler_execution_error_requeues_batch(engines):
    """A batch that fails mid-execute is put back at the queue head (no
    silent loss) and the error surfaces to the caller."""
    m, e = engines["logistic_net"]
    good = _requests(m, 3)
    bad = {"wrong_key": np.zeros((2, 2), np.float32)}   # stage KeyError
    sched = ContinuousBatchingScheduler()
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   warmup_sample=good[0])
    with pytest.raises(Exception):
        sched.serve_trace([(0.0, "logistic_net", good[0]),
                           (0.001, "logistic_net", bad),
                           (0.002, "logistic_net", good[1])])
    done = len(sched.completions)
    assert done + sched.pending() == 3                  # nothing dropped
    svc = sched._svcs["logistic_net"]
    assert any(r.inputs is bad for r in svc.queue)      # poison still queued


def test_scheduler_async_error_requeues_and_reraises(engines):
    m, e = engines["logistic_net"]
    good = _requests(m, 2)
    bad = {"wrong_key": np.zeros((2, 2), np.float32)}
    sched = ContinuousBatchingScheduler()
    sched.register("logistic_net", e, backend="flex", ladder=(1,),
                   warmup_sample=good[0])
    sched.start(poll_s=0.0005)
    sched.submit("logistic_net", bad)
    deadline = time.time() + 10.0
    while sched._thread_error is None and time.time() < deadline:
        time.sleep(0.001)                               # wait for the thread
    with pytest.raises(Exception):
        sched.stop(drain=False)
    assert sched.pending() == 1                         # poison re-queued


# ---------------------------------------------------------------------------
# power-envelope degradation
# ---------------------------------------------------------------------------


def test_envelope_tightening_mid_trace_no_loss_and_deferrals(engines):
    """The budget collapses mid-trace (sunlight -> eclipse step scheduled
    on the envelope): dispatch must degrade — smaller rungs, fallback
    backend, recorded deferrals — but NEVER drop or duplicate a request,
    and the envelope ledger must audit clean."""
    m, e = engines["logistic_net"]
    reqs = _requests(m, 48)
    env = PowerEnvelope(6.0, window_s=0.001)
    env.set_budget(0.005, sustained_w=0.5)      # the mid-trace tightening
    sched = ContinuousBatchingScheduler(envelope=env, clock="modeled")
    sched.register("logistic_net", e, backend=("accel", "cpu"),
                   ladder=(1, 4, 16), warmup_sample=reqs[0])
    trace = [(0.0002 * i, "logistic_net", r) for i, r in enumerate(reqs)]
    sched.serve_trace(trace)

    rids = [c.rid for c in sched.completions]
    assert len(rids) == len(trace)                   # nothing dropped
    assert len(set(rids)) == len(rids)               # nothing duplicated
    tel = sched.telemetry()["logistic_net"]
    assert tel.n_deferrals > 0                       # degradation recorded
    assert tel.n_deferrals == len(sched.deferrals)
    assert tel.backend_counts.get("cpu", 0) > 0      # fell back off the DPU
    assert tel.energy_j > 0 and tel.j_per_inference > 0
    audit = sched.envelope_report()
    assert audit["n_violations"] == 0, audit
    # post-tightening, only the admissible low-power backend dispatches
    late = [d for d in sched.dispatches if d.started > 0.01]
    assert late and all(d.backend == "cpu" for d in late)


def test_envelope_peak_cap_excludes_primary_backend(engines):
    """A peak cap below the DPU's busy power forces every dispatch onto
    the fallback backend, with identical results integrity."""
    m, e = engines["multi_esperta"]
    reqs = _requests(m, 12)
    env = PowerEnvelope(10.0, peak_w=3.0, window_s=0.01)
    sched = ContinuousBatchingScheduler(envelope=env, clock="modeled")
    sched.register("multi_esperta", e, backend=("accel", "flex"),
                   ladder=(1, 4), warmup_sample=reqs[0])
    sched.serve_trace([(0.0005 * i, "multi_esperta", r)
                       for i, r in enumerate(reqs)])
    assert len(sched.completions) == len(reqs)
    assert sched.dispatches
    assert all(d.backend == "flex" for d in sched.dispatches)
    assert sched.envelope_report()["n_violations"] == 0


def test_envelope_infeasible_model_rejected_at_register(engines):
    """An envelope that could never admit any backend of a model fails
    loudly at register time, not by starving the queue later."""
    m, e = engines["logistic_net"]
    env = PowerEnvelope(1e-6, peak_w=1e-3, window_s=0.01)
    sched = ContinuousBatchingScheduler(envelope=env)
    with pytest.raises(ValueError, match="envelope"):
        sched.register("logistic_net", e, backend=("accel", "cpu"),
                       ladder=(1, 4))


def test_envelope_never_admissible_mid_schedule_raises(engines):
    """A schedule that passes register-time feasibility (via its early
    regime) but can never admit once the budget collapses must surface a
    RuntimeError from serve_trace — not return with requests stranded."""
    m, e = engines["logistic_net"]
    reqs = _requests(m, 8)
    env = PowerEnvelope(6.0, window_s=0.001)
    env.set_budget(0.005, sustained_w=1e-9, peak_w=1e-6)
    sched = ContinuousBatchingScheduler(envelope=env, clock="modeled")
    sched.register("logistic_net", e, backend=("flex", "cpu"),
                   ladder=(1, 4), warmup_sample=reqs[0])
    trace = [(0.006 + 0.0002 * i, "logistic_net", r)
             for i, r in enumerate(reqs)]        # all after the collapse
    with pytest.raises(RuntimeError, match="envelope"):
        sched.serve_trace(trace)
    assert sched.pending() == len(reqs)          # queued, not dropped


def test_envelope_deferrals_deduped_per_blocked_head(engines):
    """Re-polling a blocked queue must not grow the deferral ledger: one
    record per blocked batch-head, however often step() is called."""
    m, e = engines["logistic_net"]
    reqs = _requests(m, 4)
    env = PowerEnvelope(6.0, window_s=0.001)
    env.set_budget(0.005, sustained_w=1e-9, peak_w=1e-6)
    sched = ContinuousBatchingScheduler(envelope=env, clock="modeled")
    sched.register("logistic_net", e, backend=("flex", "cpu"),
                   ladder=(1, 4), warmup_sample=reqs[0])
    for i, r in enumerate(reqs):
        sched.submit("logistic_net", r, arrival=0.006 + 0.0001 * i)
    for k in range(50):                          # async-style re-polling
        assert sched.step(0.01 + 1e-5 * k) is None
    assert len(sched.deferrals) == 1
    assert sched.telemetry()["logistic_net"].n_deferrals == 1


def test_envelope_dispatch_records_energy_fields(engines):
    m, e = engines["multi_esperta"]
    reqs = _requests(m, 6)
    sched = ContinuousBatchingScheduler(envelope=PowerEnvelope(6.0),
                                        clock="modeled")
    sched.register("multi_esperta", e, backend="flex", ladder=(1, 4),
                   warmup_sample=reqs[0])
    sched.serve_trace([(0.0005 * i, "multi_esperta", r)
                       for i, r in enumerate(reqs)])
    for d in sched.dispatches:
        assert d.backend == "flex"
        assert d.energy_j > 0 and d.power_w > 0
        assert d.energy_j == pytest.approx(d.power_w * d.modeled_latency_s)
    # the envelope ledger saw exactly one draw per dispatch
    assert sched.envelope_report()["n_draws"] == len(sched.dispatches)


def test_scheduler_async_mode_completes_everything(engines):
    m, e = engines["logistic_net"]
    reqs = _requests(m, 13)
    sched = ContinuousBatchingScheduler()
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   warmup_sample=reqs[0])
    sched.start(poll_s=0.0005)
    try:
        rids = [sched.submit("logistic_net", r) for r in reqs]
    finally:
        sched.stop(drain=True)
    got = sorted(c.rid for c in sched.completions)
    assert got == sorted(rids)


# ---------------------------------------------------------------------------
# EWMA seeding from plan-time cost signatures (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


def test_service_estimates_seeded_from_cost_signature(engines):
    """Registration alone (no warmup, no dispatch) seeds every
    (backend, rung) service-time estimate from the plan's modeled
    CostSignature latency, so the very FIRST ragged-tail flush decision
    has a cadence-correct margin instead of the old cold-start 0."""
    m, e = engines["logistic_net"]
    sched = ContinuousBatchingScheduler()
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4))
    svc = sched._svcs["logistic_net"]
    assert svc.est_service                      # non-empty before warmup
    for (backend, rung), est in svc.est_service.items():
        assert est == pytest.approx(svc.costs[(backend, rung)].latency_s)
    assert svc.flush_margin() > 0.0


def test_seed_is_prior_first_observation_replaces(engines):
    m, e = engines["logistic_net"]
    sched = ContinuousBatchingScheduler()
    sched.register("logistic_net", e, backend="flex", ladder=(1,))
    svc = sched._svcs["logistic_net"]
    seeded = svc.est_service[("flex", 1)]
    # first observation REPLACES the modeled prior outright (scales can
    # differ wildly between host wall time and the modeled ZCU104)...
    svc.observe_service("flex", 1, 0.5)
    assert svc.est_service[("flex", 1)] == pytest.approx(0.5)
    assert svc.est_service[("flex", 1)] != seeded
    # ...and later observations EWMA as before
    svc.observe_service("flex", 1, 0.1)
    assert svc.est_service[("flex", 1)] == pytest.approx(0.3)


def test_warmup_observation_overrides_seed(engines):
    m, e = engines["logistic_net"]
    reqs = _requests(m, 1)
    sched = ContinuousBatchingScheduler()
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   warmup_sample=reqs[0])
    svc = sched._svcs["logistic_net"]
    # warmed keys carry measured host time, not the modeled seed
    for key in svc.est_service:
        assert key not in svc._seeded


def test_modeled_clock_estimates_stay_modeled_after_warmup(engines):
    m, e = engines["logistic_net"]
    reqs = _requests(m, 1)
    sched = ContinuousBatchingScheduler(clock="modeled")
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   warmup_sample=reqs[0])
    svc = sched._svcs["logistic_net"]
    for key, est in svc.est_service.items():
        assert est == pytest.approx(svc.costs[key].latency_s)


def test_first_flush_decision_uses_seeded_margin(engines):
    """With a seeded margin the first ragged request is flushed BEFORE
    its deadline (deadline - margin), not at it: pick() fires at the
    seeded flush time with no dispatch history at all."""
    m, e = engines["logistic_net"]
    reqs = _requests(m, 1)
    sched = ContinuousBatchingScheduler(clock="modeled", flush_safety=2.0)
    sched.register("logistic_net", e, backend="flex", ladder=(1, 4),
                   deadline_s=0.15)
    svc = sched._svcs["logistic_net"]
    sched.submit("logistic_net", reqs[0], arrival=0.0)
    ft = svc.flush_time()
    assert ft == pytest.approx(0.15 - svc.flush_margin())
    assert svc.flush_margin() > 0.0
    assert svc.pick(ft - 1e-6) is None          # not due yet
    assert svc.pick(ft + 1e-6) is not None      # due at the seeded time


# ---------------------------------------------------------------------------
# error-path bugfixes (ISSUE 7 satellites): staging-slot leak on failed
# retirement, and failed-dispatch records poisoning telemetry
# ---------------------------------------------------------------------------


def test_failed_retirement_releases_slot_and_poisons_ticket(engines):
    """A keep-predicate crash during retire() must hand the staging slot
    back to the pool (the seed leaked it: a few failures starved the
    arena into permanent fallback allocation) and must poison the
    ticket — a second retire() of the abandoned batch raises instead of
    silently returning garbage."""
    from repro.core.pipeline import ServingPipeline
    m, e = engines["logistic_net"]
    boom = {"armed": False}

    def exploding_keep(out):
        if boom["armed"]:
            raise RuntimeError("keep predicate exploded")
        return True

    pipe = ServingPipeline(e, backend="flex", batch_size=4,
                           keep_predicate=exploding_keep)
    reqs = _requests(m, 4)
    pipe.execute_batch(reqs)                     # warm path, keep fine
    boom["armed"] = True
    n_free = pipe.arena.n_free
    ticket = pipe.execute_batch_async(reqs)
    assert pipe.arena.n_free == n_free - 1       # slot owned in flight
    with pytest.raises(RuntimeError, match="exploded"):
        ticket.retire()
    assert pipe.arena.n_free == n_free           # the leak: slot returned
    assert not pipe._inflight                    # and the ticket unlinked
    with pytest.raises(RuntimeError, match="failed retirement"):
        ticket.retire()
    boom["armed"] = False
    repeat = pipe.execute_batch(reqs)            # pool intact afterwards
    assert repeat.keep == [True] * 4
    assert pipe.arena.n_fallback == 0


def test_failed_dispatch_record_excluded_from_telemetry(engines):
    """When an async retirement fails, the already-appended dispatch
    record must be marked failed so the re-dispatch of the SAME batch
    does not double-count it in fill/latency/energy telemetry — and the
    requeued requests keep their ORIGINAL arrivals and deadlines."""
    m, e = engines["logistic_net"]
    reqs = _requests(m, 4)
    boom = {"armed": False}

    def exploding_keep(out):
        if boom["armed"]:
            boom["armed"] = False                # only the first batch
            raise RuntimeError("keep predicate exploded")
        return True

    sched = ContinuousBatchingScheduler(clock="modeled", pipeline=True)
    sched.register("logistic_net", e, backend="flex", ladder=(4,),
                   keep_predicate=exploding_keep, warmup_sample=reqs[0])
    boom["armed"] = True
    trace = [(0.001 * i, "logistic_net", r) for i, r in enumerate(reqs)]
    with pytest.raises(RuntimeError, match="exploded"):
        sched.serve_trace(trace)

    svc = sched._svcs["logistic_net"]
    assert [r.arrival for r in svc.queue] == [t for t, _, _ in trace]
    assert all(r.deadline == r.arrival + svc.deadline_s
               for r in svc.queue)               # originals, not re-stamped
    assert len(sched.dispatches) == 1 and sched.dispatches[0].failed

    sched.serve_trace([])                        # drain the requeued batch
    assert sorted(c.rid for c in sched.completions) == list(range(4))
    ok = [d for d in sched.dispatches if not d.failed]
    failed = [d for d in sched.dispatches if d.failed]
    assert len(ok) == 1 and len(failed) == 1
    tel = sched.telemetry()["logistic_net"]
    assert tel.n_dispatches == 1                 # seed double-counted: 2
    assert tel.n_failed_dispatches == 1
    assert tel.n_completed == 4
    assert tel.n_staging_fallbacks == 0          # and the slot came back
