"""Staged execution plans: batching equivalence, cache behavior, kernels.

* batched-vs-single-sample equivalence for every use-case model on flex
  and accel (fp32 within 1e-5; the int8 kernel path bit-exact).
* plan-cache behavior: compiling twice returns the same executable and
  does not re-trace; a new batch size traces exactly once more; calling
  a compiled plan never traces.
* Pallas conv2d (fp32 + int8) vs lax.conv_general_dilated across
  stride/padding combos.
* the PTQ fidelity gate demotes below-noise-floor layers to flex.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import Engine
from repro.core.opgraph import Graph
from repro.core.plan import partition_segments
from repro.kernels import ops as kops
from repro.models import SPACE_MODELS


@pytest.fixture(scope="module")
def engines():
    out = {}
    for name, m in SPACE_MODELS.items():
        e = Engine(m.build_graph(), m.init_params(jax.random.PRNGKey(0)))
        e.calibrate([m.synthetic_input(jax.random.PRNGKey(i))
                     for i in range(2)])
        out[name] = (m, e)
    return out


# ---------------------------------------------------------------------------
# batched == per-sample
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["flex", "accel"])
@pytest.mark.parametrize("name", sorted(SPACE_MODELS))
def test_batched_matches_single(name, backend, engines):
    m, e = engines[name]
    B = 3
    inputs = m.synthetic_batch(jax.random.PRNGKey(5), B)
    rngs = jax.random.split(jax.random.PRNGKey(11), B)
    batched = e.run_batch(inputs, backend, rngs)
    for i in range(B):
        single = e.run({k: v[i] for k, v in inputs.items()}, backend, rngs[i])
        for k in batched:
            a = np.asarray(batched[k][i], np.float32)
            b = np.asarray(single[k], np.float32)
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5,
                                       err_msg=f"{name}/{backend}/{k}")


def test_int8_path_bit_exact_across_batch():
    """A fully quantized conv+dense graph must be BIT-identical between
    batch-1 and batch-N execution (int32 accumulation, static scales)."""
    g = Graph("int8_exact")
    x = g.input("x", (16, 16, 4))
    c = g.add("conv2d", [x], name="conv", kernel=(3, 3), features=8)
    r = g.add("relu", [c], name="act")
    d = g.add("dense", [r], name="head", features=8)
    g.mark_output(d)
    e = Engine(g, _graph_params(g), ptq_demote_threshold=1e9)
    rng = np.random.default_rng(0)
    calib = [{"x": rng.standard_normal((16, 16, 4)).astype(np.float32)}
             for _ in range(2)]
    e.calibrate(calib)
    B = 5
    xs = rng.standard_normal((B, 16, 16, 4)).astype(np.float32)
    rngs = jax.random.split(jax.random.PRNGKey(0), B)
    batched = e.run_batch({"x": xs}, "accel", rngs)
    plan = e.planned("accel")
    # pass-pipeline structure: conv+relu fused under the act node's name,
    # then requant-chained straight into the dense head (int8 in-flight)
    assert set(plan.qplans) == {"act", "head"}
    assert plan.graph.nodes["act"].op == "fused"
    assert plan.graph.nodes["act"].attrs["param_of"] == "conv"
    assert plan.qplans["act"].requant_scale is not None
    assert plan.qplans["head"].int8_input
    for i in range(B):
        single = e.run({"x": xs[i]}, "accel", rngs[i])
        np.testing.assert_array_equal(np.asarray(batched["head"][i]),
                                      np.asarray(single["head"]))
    # the fuse=False escape hatch keeps the legacy per-node structure and
    # the exact same int8 outputs
    e0 = Engine(g, _graph_params(g), ptq_demote_threshold=1e9, fuse=False)
    e0.calibrate(calib)
    plan0 = e0.planned("accel")
    assert set(plan0.qplans) == {"conv", "head"}
    assert plan0.fused_into == {"act": "conv"}      # legacy epilogue alias
    legacy = e0.run_batch({"x": xs}, "accel", rngs)
    np.testing.assert_array_equal(np.asarray(batched["head"]),
                                  np.asarray(legacy["head"]))


def _graph_params(g):
    from repro.models.common import init_graph_params
    return init_graph_params(g, jax.random.PRNGKey(1))


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


def test_plan_cache_no_retrace_on_reuse(engines):
    m, e = engines["multi_esperta"]
    p4 = e.compile("flex", 4)
    n0 = p4.n_traces
    assert e.compile("flex", 4) is p4               # cache hit
    inputs = m.synthetic_batch(jax.random.PRNGKey(0), 4)
    rngs = jax.random.split(jax.random.PRNGKey(1), 4)
    p4(inputs, rngs)
    p4(inputs, rngs)
    assert p4.n_traces == n0                        # calls never re-trace
    p8 = e.compile("flex", 8)                       # new shape -> one trace
    assert p8.n_traces == n0 + 1
    assert e.compile("flex", 8) is p8


def test_plan_cache_is_per_instance():
    m = SPACE_MODELS["multi_esperta"]
    e1 = Engine(m.build_graph(), m.init_params())
    e2 = Engine(m.build_graph(), m.init_params())
    p1, p2 = e1.compile("flex", 2), e2.compile("flex", 2)
    assert p1 is not p2
    assert e1.planned("flex").n_traces == 1
    assert e2.planned("flex").n_traces == 1


def test_calibrate_invalidates_accel_plans():
    m = SPACE_MODELS["multi_esperta"]
    e = Engine(m.build_graph(), m.init_params())
    calib = [m.synthetic_input(jax.random.PRNGKey(i)) for i in range(2)]
    e.calibrate(calib)
    stale = e.compile("accel", 2)
    e.calibrate(calib)                              # new scales
    assert e.compile("accel", 2) is not stale


# ---------------------------------------------------------------------------
# segment partitioning + PTQ gate
# ---------------------------------------------------------------------------


def test_segments_cover_graph_in_order(engines):
    """Segments cover the (pass-rewritten) plan graph exactly, in order,
    as maximal same-backend runs."""
    for name, (m, e) in engines.items():
        plan = e.planned("accel")
        flat = [n for seg in plan.segments for n in seg.nodes]
        want = [n for n in plan.graph.order
                if plan.graph.nodes[n].op != "input"]
        assert flat == want, name
        for a, b in zip(plan.segments, plan.segments[1:]):
            assert a.backend != b.backend, name     # maximal runs


def test_partition_segments_groups_runs():
    g = SPACE_MODELS["vae_encoder"].build_graph()
    segs = partition_segments(
        g, {n: ("flex" if n == "sample" else "accel") for n in g.order})
    assert [s.backend for s in segs] == ["accel", "flex"]
    assert segs[1].nodes == ("sample",)


def test_ptq_gate_demotes_noise_floor_layers(engines):
    _, e = engines["logistic_net"]
    plan = e.planned("accel")
    # the 8192-in/4-out head's output sits below int8 activation noise;
    # the gate must route it to flex and the accel run must then match
    # flex exactly on that node
    assert "head" in plan.demoted
    m = SPACE_MODELS["logistic_net"]
    x = m.synthetic_input(jax.random.PRNGKey(3))
    a = e.run(x, "flex")
    b = e.run(x, "accel")
    np.testing.assert_array_equal(np.asarray(a["head"]),
                                  np.asarray(b["head"]))


# ---------------------------------------------------------------------------
# Pallas conv kernels vs lax reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride,padding", [
    (1, "SAME"), (2, "SAME"), (1, "VALID"), (2, "VALID"),
])
def test_pallas_conv2d_matches_lax(stride, padding):
    rng = np.random.default_rng(stride * 7 + len(padding))
    x = jnp.asarray(rng.standard_normal((2, 14, 18, 3)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 8)) * 0.2, jnp.float32)
    b = jnp.asarray(rng.standard_normal(8) * 0.1, jnp.float32)
    got = kops.conv2d(x, w, b, stride=stride, padding=padding)
    want = jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + b
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride,padding", [
    (1, "SAME"), (2, "SAME"), (1, "VALID"), (2, "VALID"),
])
@pytest.mark.parametrize("relu", [False, True])
def test_pallas_conv2d_int8_matches_lax_int32(stride, padding, relu):
    """int8 conv must reproduce the int32-exact lax conv + epilogue."""
    rng = np.random.default_rng(stride + len(padding) + relu)
    x_q = jnp.asarray(rng.integers(-127, 128, (2, 13, 17, 5)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (3, 3, 5, 7)), jnp.int8)
    ws = jnp.asarray(rng.random(7) * 0.1 + 1e-3, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(7), jnp.float32)
    xs = 0.031
    got = kops.conv2d_int8(x_q, w_q, ws, bias, x_scale=xs, stride=stride,
                           padding=padding, relu=relu)
    acc = jax.lax.conv_general_dilated(
        x_q.astype(jnp.int32), w_q.astype(jnp.int32), (stride, stride),
        padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))
    want = acc.astype(jnp.float32) * (ws * xs)[None, None, None, :] + bias
    if relu:
        want = jnp.maximum(want, 0.0)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m,k,n", [(5, 18, 3), (1, 200, 1), (130, 433, 92)])
def test_int8_matmul_pads_unaligned_shapes(m, k, n):
    """No more tiny-divisor blocks: awkward shapes pad to aligned tiles."""
    rng = np.random.default_rng(m + k + n)
    x_q = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int8)
    w_q = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.random(m) * 0.1 + 1e-3, jnp.float32)
    ws = jnp.asarray(rng.random(n) * 0.1 + 1e-3, jnp.float32)
    got = kops.int8_matmul(x_q, w_q, xs, ws)
    want = (np.asarray(x_q, np.int64) @ np.asarray(w_q, np.int64)
            ).astype(np.float32) * np.asarray(xs)[:, None] \
        * np.asarray(ws)[None, :]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-4)
